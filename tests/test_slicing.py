"""Slicing strategies: memory-bound invariant, minimality, overhead."""

import pytest
from hypothesis import given, strategies as st

from conftest import random_closed_network, random_tree
from repro.core.slicing import (
    ensure_width,
    find_slices,
    greedy_slicer,
    interval_optimal_slicer,
    slice_finder,
)
from repro.core.tensor_network import popcount


@given(
    n=st.integers(10, 30),
    seed=st.integers(0, 9999),
    drop=st.integers(1, 6),
    method=st.sampled_from(["lifetime", "greedy", "interval"]),
)
def test_memory_bound_always_satisfied(n, seed, drop, method):
    """Every strategy + ensure_width must satisfy the hard memory bound:
    max sliced tensor dim <= target."""
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    target = max(tree.width() - drop, 2)
    S = find_slices(tree, target, method=method, seed=seed)
    assert tree.sliced_width(S) <= target


@given(n=st.integers(10, 30), seed=st.integers(0, 9999))
def test_overhead_at_least_one(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    target = max(tree.width() - 3, 2)
    S = find_slices(tree, target, method="lifetime", seed=seed)
    assert tree.slicing_overhead(S) >= 1.0 - 1e-9


@given(n=st.integers(12, 30), seed=st.integers(0, 9999))
def test_slicefinder_not_larger_than_greedy(n, seed):
    """Fig. 9's claim: the lifetime sliceFinder finds equal-or-smaller
    slicing sets than single-shot greedy in most cases.  We assert the
    soft version: never more than greedy + 2 (structural noise on random
    non-stem-dominant graphs), and compare exactly on stem-dominant
    instances in the benchmarks."""
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    target = max(tree.width() - 3, 2)
    s_l = popcount(find_slices(tree, target, method="lifetime", seed=seed))
    s_g = popcount(find_slices(tree, target, method="greedy", seed=seed))
    assert s_l <= s_g + 2


@given(n=st.integers(10, 24), seed=st.integers(0, 9999))
def test_interval_slicer_no_larger_on_stem(n, seed):
    """The interval sweep is optimal for the stem-restricted relaxation:
    on the stem it uses no more indices than Algorithm 1."""
    from repro.core.lifetime import detect_stem

    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    target = max(tree.width() - 3, 2)
    stem = detect_stem(tree)
    s_alg1 = popcount(slice_finder(tree, target, stem=stem))
    s_int = popcount(interval_optimal_slicer(tree, target, stem=stem))
    assert s_int <= s_alg1


def test_greedy_repeats_improve_or_equal():
    tn = random_closed_network(26, 3, 42)
    tree = random_tree(tn, 3)
    target = max(tree.width() - 4, 2)
    s1 = greedy_slicer(tree, target, repeats=1, seed=0)
    s16 = greedy_slicer(tree, target, repeats=16, seed=0, temperature=0.2)
    assert tree.sliced_cost(s16) <= tree.sliced_cost(s1) * 1.0 + 1e-9


def test_ensure_width_handles_off_stem_tensors():
    tn = random_closed_network(24, 4, 7)
    tree = random_tree(tn, 11)
    target = max(tree.width() - 5, 2)
    S = ensure_width(tree, 0, target)
    assert tree.sliced_width(S) <= target
