"""Pipeline parallelism: GPipe schedule equals sequential layer apply."""

import subprocess
import sys

from conftest import subprocess_kwargs
from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(8, 2) == 1 / 9
    assert bubble_fraction(1, 4) == 3 / 4


PIPE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_host_mesh
from repro.parallel.pipeline import pipeline_forward

mesh = make_host_mesh((4, 2), ("pod", "data"))
L, D = 8, 16
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (L, D, D)) * 0.3,
          "b": jax.random.normal(key, (L, D)) * 0.1}

def layer_apply(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])

x = jax.random.normal(key, (6, 4, D))  # (n_micro, mb, D)

# sequential reference
ref = x
for i in range(L):
    ref = layer_apply({"w": params["w"][i], "b": params["b"][i]}, ref)

out = pipeline_forward(layer_apply, params, x, mesh, axis="pod")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                           atol=2e-4)

# differentiability: GPipe backward via autodiff
def loss(p):
    return jnp.sum(pipeline_forward(layer_apply, p, x, mesh) ** 2)

g = jax.grad(loss)(params)
assert all(jnp.isfinite(l).all() for l in jax.tree.leaves(g))
print("DONE")
"""


def test_pipeline_matches_sequential():
    r = subprocess.run(
        [sys.executable, "-c", PIPE],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
