"""Differential suite for the unified-engine refactor.

The four slice drivers (``contract_all`` / ``contract_sharded`` /
``contract_resumable`` / ``contract_multihost``) became thin strategy
adapters over :class:`repro.engine.session.ContractionSession`.  The
refactor's contract is *bitwise* identity: the jitted program bodies
moved verbatim, so the adapters must reproduce the pre-refactor outputs
exactly — not approximately — on the same plans.

Each legacy driver below is a frozen copy of the pre-refactor
implementation (taken from the last pre-engine revision), with its jit
memoization keys renamed ``legacy_*`` so it traces + compiles its OWN
program rather than sharing the adapter's — the comparison is between
two independently compiled executables, which is what makes equality
meaningful.

Legs: {REPRO_MEGAKERNEL 0/1} x {hoist off/on} x {fp32/bf16} on the
lowered GEMM backend, plus an einsum leg and the unsliced dense path.
The pinned circuit is the 12-qubit syc-12 family the benchmarks use,
planned at a width that forces slicing with a slice count that is NOT a
multiple of the slice batch — the ragged masked lanes are exactly where
a refactor of the padding/masking logic would diverge first.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import plan_compiled
from repro.core.distributed import (
    SliceRangeCheckpoint,
    contract_resumable,
    contract_sharded,
)
from repro.core.executor import simplify_network
from repro.engine.session import ContractionSession
from repro.quantum.circuits import circuit_to_network, sycamore_like

ROWS, COLS, CYCLES, SEED = 3, 4, 8, 2
TARGET_DIM = 8
SLICE_BATCH = 3  # must not divide the slice count (ragged final batch)


@functools.lru_cache(maxsize=None)
def _leg(mega: str, backend: str, precision: str):
    """Plan the pinned syc-12 circuit under one env leg (uncached — each
    leg gets its own plan object so no jitted programs leak between
    legs)."""
    old = os.environ.get("REPRO_MEGAKERNEL")
    os.environ["REPRO_MEGAKERNEL"] = mega
    try:
        circuit = sycamore_like(ROWS, COLS, CYCLES, seed=SEED)
        tn, arrays = circuit_to_network(
            circuit, bitstring="0" * circuit.num_qubits
        )
        tn, arrays = simplify_network(tn, arrays)
        plan, _ = plan_compiled(
            tn, TARGET_DIM, backend=backend, precision=precision,
            use_cache=False,
        )
    finally:
        if old is None:
            os.environ.pop("REPRO_MEGAKERNEL", None)
        else:
            os.environ["REPRO_MEGAKERNEL"] = old
    assert plan.num_sliced > 0  # the leg must exercise real slicing
    assert (1 << plan.num_sliced) % SLICE_BATCH != 0
    return plan, tuple(arrays)


LEGS = [
    ("0", "gemm", "fp32"),
    ("1", "gemm", "fp32"),
    ("0", "gemm", "bf16"),
    ("1", "gemm", "bf16"),
    ("0", "einsum", "fp32"),
]


# ----------------------------------------------------------------------
# frozen pre-refactor drivers (jit keys renamed legacy_*)
# ----------------------------------------------------------------------
def legacy_contract_all(plan, arrays, slice_batch=8, hoist=None):
    from repro.core.executor import default_hoist

    n_slices = 1 << plan.num_sliced
    if plan.num_sliced == 0:
        key = ("legacy_dense",)
        fn = plan._compiled.get(key) or plan._compiled.setdefault(
            key, jax.jit(lambda a: plan.contract_slice(a, 0))
        )
        return fn(list(arrays))
    hoist = default_hoist() if hoist is None else bool(hoist)
    hoist = hoist and plan.can_hoist
    slice_batch = max(1, min(slice_batch, n_slices))
    n_batches = -(-n_slices // slice_batch)
    total = n_batches * slice_batch
    padded = total != n_slices
    key = ("legacy_all", slice_batch, hoist)
    fn = plan._compiled.get(key)
    if fn is None:
        ids = jnp.asarray(
            np.arange(total, dtype=np.int32) % n_slices
        ).reshape(n_batches, slice_batch)
        w = jnp.asarray(np.arange(total) < n_slices).reshape(
            n_batches, slice_batch
        )

        @jax.jit
        def run(arrs, hbufs):
            batched = jax.vmap(
                lambda sid: plan.contract_slice(
                    arrs, sid, hbufs if hoist else None
                )
            )

            def body(acc, chunk_w):
                chunk, wk = chunk_w
                contrib = batched(chunk)
                if padded:
                    contrib = jnp.where(
                        wk.reshape((-1,) + (1,) * (contrib.ndim - 1)),
                        contrib,
                        jnp.zeros((), contrib.dtype),
                    )
                return acc + jnp.sum(contrib, axis=0), None

            out_shape = jax.eval_shape(
                lambda: jnp.sum(batched(ids[0]), axis=0)
            )
            acc0 = jnp.zeros(out_shape.shape, out_shape.dtype)
            acc, _ = jax.lax.scan(body, acc0, (ids, w))
            return acc

        fn = plan._compiled.setdefault(key, run)
    hoisted = plan.contract_prologue(arrays) if hoist else []
    return fn(list(arrays), list(hoisted))


def legacy_contract_sharded(
    plan, arrays, mesh, axis_names=("data",), slice_batch=1, hoist=None
):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.executor import default_hoist

    ndev = 1
    for ax in axis_names:
        ndev *= mesh.shape[ax]
    n_slices = 1 << plan.num_sliced
    slice_batch = max(1, min(slice_batch, n_slices))
    chunk = ndev * slice_batch
    total = -(-n_slices // chunk) * chunk
    ids = np.arange(total, dtype=np.int32) % n_slices
    valid = np.arange(total) < n_slices

    hoist = default_hoist() if hoist is None else bool(hoist)
    hoist = hoist and plan.can_hoist
    hoisted = (
        plan.contract_prologue_replicated(arrays, mesh) if hoist else []
    )
    spec = P(axis_names)
    key = ("legacy_sharded", mesh, tuple(axis_names), slice_batch, hoist)
    fn = plan._compiled.get(key)
    if fn is None:

        @jax.jit
        def run(arrs, hbufs, ids_, valid_):
            def worker(ids_local, valid_local):
                contract = lambda sid: plan.contract_slice(  # noqa: E731
                    arrs, sid, hbufs if hoist else None
                )
                batched = jax.vmap(contract)
                idb = ids_local.reshape(-1, slice_batch)
                vb = valid_local.reshape(-1, slice_batch)
                out_shape = jax.eval_shape(lambda: contract(jnp.int32(0)))
                wshape = (-1,) + (1,) * len(out_shape.shape)

                def body(acc, iv):
                    sids, ok = iv
                    contrib = batched(sids)
                    contrib = jnp.where(
                        ok.reshape(wshape),
                        contrib,
                        jnp.zeros((), contrib.dtype),
                    )
                    return acc + jnp.sum(contrib, axis=0), None

                acc0 = jnp.zeros(out_shape.shape, out_shape.dtype)
                acc, _ = jax.lax.scan(body, acc0, (idb, vb))
                return jax.lax.psum(acc, axis_names)

            return shard_map(
                worker,
                mesh=mesh,
                in_specs=(spec, spec),
                out_specs=P(),
                check_rep=False,
            )(ids_, valid_)

        fn = plan._compiled.setdefault(key, run)
    return fn(
        list(arrays), list(hoisted), jnp.asarray(ids), jnp.asarray(valid)
    )


def legacy_contract_resumable(plan, arrays, chunk=4, hoist=None):
    from repro.core.executor import default_hoist

    hoist = default_hoist() if hoist is None else bool(hoist)
    hoist = hoist and plan.can_hoist
    hoisted = plan.contract_prologue(arrays) if hoist else []
    n_slices = 1 << plan.num_sliced
    out_shape = jax.eval_shape(
        lambda: plan.contract_slice(list(arrays), jnp.int32(0))
    )
    state = SliceRangeCheckpoint(
        n_slices, set(), np.zeros(out_shape.shape, out_shape.dtype)
    )
    ck = ("legacy_resumable", hoist)
    contract = plan._compiled.get(ck) or plan._compiled.setdefault(
        ck,
        jax.jit(
            lambda arrs, hbufs, sid: plan.contract_slice(
                arrs, sid, hbufs if hoist else None
            )
        ),
    )
    for s, e in state.missing(chunk):
        acc = None
        for sid in range(s, e):
            r = contract(list(arrays), list(hoisted), jnp.int32(sid))
            acc = r if acc is None else acc + r
        state.partial = state.partial + np.asarray(acc)
        state.add_range(s, e)
    return state.partial, state


def legacy_mh_batch(plan, arrays, sb, hoist):
    """The pre-refactor multi-host per-range program (key mh_batch):
    masked vmap over one claimed range of slice ids."""
    hoisted = plan.contract_prologue(arrays) if hoist else []
    ck = ("legacy_mh_batch", sb, hoist)
    fn = plan._compiled.get(ck)
    if fn is None:

        @jax.jit
        def fn(arrs, hbufs, ids_, valid_):
            contract = lambda sid: plan.contract_slice(  # noqa: E731
                arrs, sid, hbufs if hoist else None
            )
            contrib = jax.vmap(contract)(ids_)
            contrib = jnp.where(
                valid_.reshape((-1,) + (1,) * (contrib.ndim - 1)),
                contrib,
                jnp.zeros((), contrib.dtype),
            )
            return jnp.sum(contrib, axis=0)

        fn = plan._compiled.setdefault(ck, fn)
    return lambda ids, valid: fn(
        list(arrays), list(hoisted), jnp.asarray(ids), jnp.asarray(valid)
    )


# ----------------------------------------------------------------------
# adapter vs frozen legacy: bitwise
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mega,backend,precision", LEGS)
@pytest.mark.parametrize("hoist", [False, True])
def test_contract_all_bitwise(mega, backend, precision, hoist):
    plan, arrays = _leg(mega, backend, precision)
    ref = legacy_contract_all(
        plan, list(arrays), slice_batch=SLICE_BATCH, hoist=hoist
    )
    new = plan.contract_all(
        list(arrays), slice_batch=SLICE_BATCH, hoist=hoist
    )
    assert np.array_equal(np.asarray(new), np.asarray(ref))


@pytest.mark.parametrize("mega,backend,precision", LEGS)
@pytest.mark.parametrize("hoist", [False, True])
def test_contract_sharded_bitwise(mega, backend, precision, hoist):
    from jax.sharding import Mesh

    plan, arrays = _leg(mega, backend, precision)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    ref = legacy_contract_sharded(
        plan, list(arrays), mesh, slice_batch=SLICE_BATCH, hoist=hoist
    )
    new = contract_sharded(
        plan, list(arrays), mesh, slice_batch=SLICE_BATCH, hoist=hoist
    )
    assert np.array_equal(np.asarray(new), np.asarray(ref))


@pytest.mark.parametrize("mega,backend,precision", LEGS[:2] + LEGS[3:])
@pytest.mark.parametrize("hoist", [False, True])
def test_contract_resumable_bitwise(mega, backend, precision, hoist):
    plan, arrays = _leg(mega, backend, precision)
    ref, ref_state = legacy_contract_resumable(
        plan, list(arrays), chunk=SLICE_BATCH, hoist=hoist
    )
    new, new_state = contract_resumable(
        plan, list(arrays), chunk=SLICE_BATCH, hoist=hoist
    )
    assert np.array_equal(np.asarray(new), np.asarray(ref))
    assert new_state.done_ids() == ref_state.done_ids()


@pytest.mark.parametrize("hoist", [False, True])
def test_run_slices_matches_legacy_mh_batch(hoist):
    """The engine's run_slices primitive is bitwise the pre-refactor
    multi-host per-range program on every claimed range (including the
    final wrapped/masked one).  contract_multihost's surrounding
    scheduler/transport/claims logic is unchanged by the refactor, so
    per-range identity is driver identity."""
    plan, arrays = _leg("1", "gemm", "fp32")
    sess = ContractionSession(plan, list(arrays), hoist=hoist)
    legacy = legacy_mh_batch(plan, list(arrays), SLICE_BATCH, sess.hoist)
    n = sess.n_slices
    for start in range(0, n, SLICE_BATCH):
        end = min(start + SLICE_BATCH, n)
        ids = np.arange(start, start + SLICE_BATCH, dtype=np.int32) % n
        valid = np.arange(start, start + SLICE_BATCH) < end
        new = sess.run_slices(ids, valid)
        ref = legacy(ids, valid)
        assert np.array_equal(np.asarray(new), np.asarray(ref))


def test_multihost_world1_matches_contract_all():
    from repro.distributed.multihost import contract_multihost

    plan, arrays = _leg("1", "gemm", "fp32")
    res = contract_multihost(plan, list(arrays), slice_batch=SLICE_BATCH)
    assert res.complete
    ref = plan.contract_all(list(arrays), slice_batch=SLICE_BATCH)
    np.testing.assert_allclose(
        np.asarray(res.value), np.asarray(ref), rtol=1e-5, atol=1e-7
    )


def test_dense_path_bitwise():
    """Unsliced plans take the dense fast path in both eras."""
    from repro.quantum.circuits import random_1d_circuit

    circuit = random_1d_circuit(8, 4, seed=3)
    tn, arrays = circuit_to_network(circuit, bitstring="0" * 8)
    tn, arrays = simplify_network(tn, arrays)
    plan, _ = plan_compiled(tn, 30, use_cache=False)
    assert plan.num_sliced == 0
    ref = legacy_contract_all(plan, list(arrays))
    new = plan.contract_all(list(arrays))
    assert np.array_equal(np.asarray(new), np.asarray(ref))


def test_session_shares_program_across_drivers():
    """All sessions over one plan converge on ONE traced batch program
    (the _compiled memoization the serving engine relies on)."""
    plan, arrays = _leg("1", "gemm", "fp32")
    s1 = ContractionSession(plan, list(arrays), hoist=True)
    s2 = ContractionSession(plan, list(arrays), hoist=True)
    s1.run_slices(np.arange(SLICE_BATCH, dtype=np.int32))
    fn1 = plan._compiled[("sess_batch", s1.hoist)]
    s2.run_slices(np.arange(SLICE_BATCH, dtype=np.int32))
    assert plan._compiled[("sess_batch", s2.hoist)] is fn1
