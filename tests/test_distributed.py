"""Distributed slice execution: shard_map + psum on 8 virtual devices, and
the resumable fault-tolerance contract."""

import subprocess
import sys

import numpy as np

from conftest import subprocess_kwargs
from repro.core import ContractionPlan, simplify_network
from repro.core.distributed import contract_resumable
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.quantum.circuits import circuit_to_network, random_1d_circuit

SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.distributed import contract_sharded
from repro.launch.mesh import make_host_mesh

c = random_1d_circuit(10, 8, seed=3)
tn, arrays = circuit_to_network(c, bitstring="0110100101")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 4, method="lifetime")
plan = ContractionPlan(tree, S)
dense = ContractionPlan(tree, 0).contract_all(arrays)
mesh = make_host_mesh((4, 2), ("data", "model"))
v = contract_sharded(plan, arrays, mesh, axis_names=("data",))
assert np.allclose(np.asarray(v), np.asarray(dense), atol=1e-4)
# slice axis spanning both mesh axes (the paper's full process grid)
v2 = contract_sharded(plan, arrays, mesh, axis_names=("data", "model"))
assert np.allclose(np.asarray(v2), np.asarray(dense), atol=1e-4)
print("DONE")
"""


def test_contract_sharded_8dev():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


def _plan():
    c = random_1d_circuit(9, 6, seed=5)
    tn, arrays = circuit_to_network(c, bitstring="011010010")
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    return ContractionPlan(tree, S), arrays, tree


def test_resumable_failure_recovery():
    plan, arrays, tree = _plan()
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    n_slices = 1 << plan.num_sliced
    fail_at = min(8, max(0, n_slices - 8))
    state = None
    try:
        _, state = contract_resumable(plan, arrays, chunk=8,
                                      fail_on={fail_at})
        raised = False
    except RuntimeError:
        raised = True
    assert raised or n_slices <= 8
    # restart from scratch state: completes and matches
    val, state = contract_resumable(plan, arrays, chunk=8)
    np.testing.assert_allclose(val, dense, atol=1e-4)
    # idempotent: a second resume does no work and returns the same value
    val2, _ = contract_resumable(plan, arrays, chunk=8, state=state)
    np.testing.assert_allclose(val2, val, atol=1e-6)
