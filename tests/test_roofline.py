"""Roofline analysis machinery: HLO collective parsing + analytic model."""

import pytest

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.parallel.sharding import count_params
from repro.roofline.analysis import collective_bytes, shape_bytes
from repro.roofline.analytic import cell_flops, cell_hbm_bytes, forward_flops


def test_shape_bytes():
    assert shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert shape_bytes("f32[8]{0}") == 32
    assert shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
    assert shape_bytes("pred[16]") == 16


def test_collective_parse():
    hlo = """
  %ag = bf16[1024,512]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[128]{0}, f32[128]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = bf16[32,32]{1,0} all-to-all(%w), dimensions={1}
  %ags = bf16[8,8]{1,0} all-gather-start(%v), dimensions={0}
  %agd = bf16[8,8]{1,0} all-gather-done(%ags)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 1024 * 512 * 2 + 8 * 8 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 2 * 128 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["all-to-all"] == 32 * 32 * 2


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-moe-16b",
                                  "mamba2-130m", "zamba2-7b",
                                  "seamless-m4t-medium"])
def test_analytic_flops_positive_and_ordered(arch):
    cfg = get_config(arch)
    train = cell_flops(cfg, SHAPES["train_4k"])
    prefill = cell_flops(cfg, SHAPES["prefill_32k"])
    decode = cell_flops(cfg, SHAPES["decode_32k"])
    assert train > 0 and prefill > 0 and decode > 0
    # training a 1M-token batch costs far more than one decode token
    assert train > decode * 100


def test_analytic_matches_6nd_for_dense():
    """For a dense decoder the analytic forward ≈ 2·N·tokens + attention
    (within 2x of the 6ND/3 rule)."""
    cfg = get_config("deepseek-7b")
    model = build_model(cfg)
    n = count_params(model.param_defs())
    B, S = 8, 4096
    fwd = forward_flops(cfg, B, S)
    rule = 2.0 * n * B * S
    assert 0.5 * rule < fwd < 2.0 * rule


def test_hbm_bytes_decode_dominated_by_cache():
    cfg = get_config("llama3-405b")
    model = build_model(cfg)
    n = count_params(model.param_defs())
    b = cell_hbm_bytes(cfg, SHAPES["decode_32k"], n)
    # 2.2TB KV cache + 0.8TB params
    assert b > 2e12
