"""Epilogue megakernel conformance suite (oracle-backed).

The fused VMEM-resident GEMM chain (:func:`repro.kernels.contract_gemm.
fused_chain_matmul` + the refiner's fusion-boundary pass) is gated here
on three independent oracles:

  1. randomized differential chains — the megakernel (kernel body forced,
     ``use_kernel=True, interpret=True``) against the einsum oracle to
     fp32 tolerance AND *bitwise* against the unfused per-step
     ``fused_transpose_matmul`` chain at matched (whole-array) tiles,
     real and complex-Karatsuba, plain and under ``jax.vmap``;
  2. chain-boundary invariants on planned circuits — certified live set
     within the VMEM budget, consecutive in-segment positions, carry
     adjacency, dense valid slot assignment, segment outputs never
     chain-interior, and the disjoint (no-double-charge) HBM-savings
     accounting;
  3. the statevector oracle end-to-end — amplitudes and sampling XEB
     across {backend} x {hoist} x {REPRO_MEGAKERNEL}, the anytime
     co-optimized path, the vmapped scan / sharded / resumable
     executors, and the plan-cache fingerprint separation of the
     ``REPRO_MEGAKERNEL`` switch.
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import subprocess_kwargs
from repro.core import ContractionPlan, simplify_network, simulate_amplitude
from repro.core.api import plan_compiled, sample_bitstrings
from repro.core.distributed import contract_resumable
from repro.core.executor import pair_contract_inds
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.kernels import ops
from repro.kernels.contract_gemm import chain_reference, fused_chain_matmul
from repro.lowering import (
    CHAIN_VMEM_BUDGET_BYTES,
    chain_segment_plan,
    lower_step,
    plan_tree_chains,
)
from repro.lowering.refiner import CHAIN_MAX_BATCH, default_megakernel
from repro.quantum import statevector
from repro.quantum.circuits import (
    circuit_to_network,
    random_1d_circuit,
    sycamore_like,
)


# ----------------------------------------------------------------------
# randomized chain construction (the property-based differential oracle)
# ----------------------------------------------------------------------
def _random_chain(rng, n_steps, *, with_batch):
    """Generate a random fused chain in the executor's conventions.

    Returns ``(forms, carry_side, externals)`` where ``externals`` are
    the per-operand index tuples (step 0's pair, then one non-carry
    operand per later step).  Step ``t``'s carry is step ``t-1``'s
    ``inds_out`` verbatim — the tree-native layout handoff the
    megakernel relies on.  ``with_batch`` threads one open (sampling)
    index through every operand so it rides as a batch axis.
    """
    sizes = {}
    counter = [0]

    def fresh(k):
        labs = []
        for _ in range(k):
            lab = f"x{counter[0]}"
            counter[0] += 1
            sizes[lab] = int(rng.integers(2, 5))
            labs.append(lab)
        return labs

    def shuffled(inds):
        return tuple(str(s) for s in rng.permutation(list(inds)))

    open_set = set()
    batch = []
    if with_batch:
        batch = fresh(1)
        open_set.add(batch[0])

    shared = fresh(int(rng.integers(1, 3)))
    a_inds = shuffled(batch + fresh(int(rng.integers(1, 3))) + shared)
    b_inds = shuffled(batch + shared + fresh(int(rng.integers(1, 3))))
    _, out = pair_contract_inds(a_inds, b_inds, frozenset(open_set))
    forms = [lower_step(a_inds, b_inds, out, sizes.__getitem__)]
    carry_side = [""]
    externals = [a_inds, b_inds]
    carry = out
    for _ in range(1, n_steps):
        cands = [ix for ix in carry if ix not in open_set]
        ncon = int(rng.integers(1, min(len(cands), 2) + 1))
        con = [str(s) for s in rng.choice(cands, size=ncon, replace=False)]
        ext = shuffled(batch + con + fresh(int(rng.integers(1, 3))))
        side = "l" if rng.random() < 0.5 else "r"
        pair = (carry, ext) if side == "l" else (ext, carry)
        _, out = pair_contract_inds(*pair, frozenset(open_set))
        forms.append(lower_step(*pair, out, sizes.__getitem__))
        carry_side.append(side)
        externals.append(ext)
        carry = out
    return tuple(forms), tuple(carry_side), externals, sizes


def _chain_slots(forms, carry_side):
    """Scratch-slot assignment for a synthetic chain via the same
    chain-local linear scan the refiner's ``_build_chain`` runs."""
    n_ext = len(forms) + 1
    ext_keys = list(range(n_ext))
    out_keys = [n_ext + t for t in range(len(forms))]
    steps, nbytes = [], {}
    for t, f in enumerate(forms):
        elems = f.B * f.M * f.N
        nbytes[out_keys[t]] = elems
        if t == 0:
            steps.append((ext_keys[0], ext_keys[1], out_keys[0]))
        elif carry_side[t] == "l":
            steps.append((out_keys[t - 1], ext_keys[t + 1], out_keys[t]))
        else:
            steps.append((ext_keys[t + 1], out_keys[t - 1], out_keys[t]))
    for t, f in enumerate(forms):
        if t == 0:
            nbytes[ext_keys[0]] = f.B * f.M * f.K
            nbytes[ext_keys[1]] = f.B * f.K * f.N
        else:
            mn = f.M if carry_side[t] == "r" else f.N
            nbytes[ext_keys[t + 1]] = f.B * f.K * mn
    seg = chain_segment_plan(
        "test-chain", tuple(ext_keys), tuple(steps), (out_keys[-1],), nbytes
    )
    interior = out_keys[:-1]
    used = sorted({seg.slot_of[v] for v in interior})
    remap = {s: d for d, s in enumerate(used)}
    slot_ids = tuple(remap[seg.slot_of[v]] for v in interior)
    slot_elems = [0] * len(used)
    for v in interior:
        d = remap[seg.slot_of[v]]
        slot_elems[d] = max(slot_elems[d], nbytes[v])
    return slot_ids, tuple(slot_elems)


def _chain_operands(rng, externals, sizes, *, complex_mode):
    arrs = []
    for inds in externals:
        shape = tuple(sizes[ix] for ix in inds)
        re = rng.standard_normal(shape).astype(np.float32)
        if complex_mode:
            im = rng.standard_normal(shape).astype(np.float32)
            arrs.append((re + 1j * im).astype(np.complex64))
        else:
            arrs.append(re)
    return arrs


def _einsum_chain(forms, carry_side, operands):
    """The chain as the executor's unfused einsum loop (allclose oracle)."""
    carry = None
    it = iter(operands)
    for t, f in enumerate(forms):
        if t == 0:
            a, b = next(it), next(it)
        else:
            ext = next(it)
            a, b = (carry, ext) if carry_side[t] == "l" else (ext, carry)
        carry = jnp.einsum(f.expr, jnp.asarray(a), jnp.asarray(b))
    return carry


def _unfused_component_chain(forms, carry_side, operands):
    """The chain as per-step ``fused_transpose_matmul`` calls at matched
    (whole-array) tiles, components kept split with the kernel's exact
    Karatsuba — the bitwise oracle for the megakernel body."""

    def one(form, x, y):
        out = ops.fused_matmul(
            x, y,
            perm_a=form.perm_a, perm_b=form.perm_b,
            nb=len(form.batch_inds), nm=len(form.m_inds),
            nn=len(form.n_inds), nk=len(form.k_inds),
            bm=1 << 20, bn=1 << 20, bk=1 << 20, interpret=True,
        )
        if form.out_perm != tuple(range(out.ndim)):
            out = jnp.transpose(out, form.out_perm)
        return out

    def step(form, a, b):
        if len(a) == 2:
            (ar, ai), (br, bi) = a, b
            p1 = one(form, ar, br)
            p2 = one(form, ai, bi)
            p3 = one(form, ar + ai, br + bi)
            return (p1 - p2, p3 - p1 - p2)
        return (one(form, a[0], b[0]),)

    def split(o):
        o = jnp.asarray(o)
        if jnp.iscomplexobj(o):
            return (
                jnp.real(o).astype(jnp.float32),
                jnp.imag(o).astype(jnp.float32),
            )
        return (o.astype(jnp.float32),)

    carry = None
    it = iter(operands)
    for t, f in enumerate(forms):
        if t == 0:
            a, b = split(next(it)), split(next(it))
        else:
            ext = split(next(it))
            a, b = (carry, ext) if carry_side[t] == "l" else (ext, carry)
        carry = step(f, a, b)
    return carry


CHAIN_CASES = [
    # (seed, n_steps, complex_mode, with_batch)
    (0, 2, False, False),
    (1, 3, False, True),
    (2, 3, True, False),
    (3, 4, True, True),
    (4, 2, True, True),
    (5, 4, False, False),
]


@pytest.mark.parametrize("seed,n_steps,cplx,batch", CHAIN_CASES)
def test_chain_matches_einsum(seed, n_steps, cplx, batch):
    """Kernel body (forced) and off-TPU reference both equal the einsum
    oracle on randomized chains."""
    rng = np.random.default_rng(seed)
    forms, carry_side, externals, sizes = _random_chain(
        rng, n_steps, with_batch=batch
    )
    slot_ids, slot_elems = _chain_slots(forms, carry_side)
    arrs = _chain_operands(rng, externals, sizes, complex_mode=cplx)
    want = np.asarray(_einsum_chain(forms, carry_side, arrs))

    got_kernel = ops.fused_chain(
        arrs, forms=forms, carry_side=carry_side,
        slot_ids=slot_ids, slot_elems=slot_elems,
        use_kernel=True, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got_kernel), want, rtol=1e-4, atol=1e-5
    )
    got_ref = ops.fused_chain(
        arrs, forms=forms, carry_side=carry_side,
        slot_ids=slot_ids, slot_elems=slot_elems, use_kernel=False,
    )
    np.testing.assert_allclose(
        np.asarray(got_ref), want, rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("seed,n_steps,cplx,batch", CHAIN_CASES[:4])
def test_chain_bitwise_vs_unfused(seed, n_steps, cplx, batch):
    """The megakernel is *bitwise* identical to the unfused per-step
    ``fused_transpose_matmul`` chain at matched tiles — same per-cell MXU
    dots, same component-split Karatsuba, same accumulation order; the
    VMEM scratch routing changes where intermediates live, never their
    bits."""
    rng = np.random.default_rng(100 + seed)
    forms, carry_side, externals, sizes = _random_chain(
        rng, n_steps, with_batch=batch
    )
    slot_ids, slot_elems = _chain_slots(forms, carry_side)
    arrs = _chain_operands(rng, externals, sizes, complex_mode=cplx)

    comps = []
    for o in arrs:
        o = jnp.asarray(o)
        if cplx:
            comps.append(jnp.real(o).astype(jnp.float32))
            comps.append(jnp.imag(o).astype(jnp.float32))
        else:
            comps.append(o.astype(jnp.float32))
    got = fused_chain_matmul(
        *comps, forms=forms, carry_side=carry_side,
        slot_ids=slot_ids, slot_elems=slot_elems,
        complex_mode=cplx, interpret=True,
    )
    want = _unfused_component_chain(forms, carry_side, arrs)
    assert len(got) == len(want) == (2 if cplx else 1)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            seed, n_steps, cplx, batch,
        )


def test_chain_under_vmap():
    """The megakernel dispatch is trace-safe under ``jax.vmap`` — the
    executor's slice-batch scan vmaps exactly this call."""
    rng = np.random.default_rng(42)
    forms, carry_side, externals, sizes = _random_chain(
        rng, 3, with_batch=False
    )
    slot_ids, slot_elems = _chain_slots(forms, carry_side)
    base = [
        _chain_operands(rng, externals, sizes, complex_mode=True)
        for _ in range(3)
    ]
    stacked = [
        jnp.stack([jnp.asarray(base[v][i]) for v in range(3)])
        for i in range(len(externals))
    ]

    def run(*operands):
        return ops.fused_chain(
            list(operands), forms=forms, carry_side=carry_side,
            slot_ids=slot_ids, slot_elems=slot_elems,
            use_kernel=True, interpret=True,
        )

    got = jax.vmap(run)(*stacked)
    for v in range(3):
        want = np.asarray(_einsum_chain(forms, carry_side, base[v]))
        np.testing.assert_allclose(
            np.asarray(got[v]), want, rtol=1e-4, atol=1e-5
        )


# ----------------------------------------------------------------------
# chain-boundary invariants on planned circuits
# ----------------------------------------------------------------------
def _tree_and_slices(circ, target):
    tn, arrays = circuit_to_network(circ, bitstring="0" * circ.num_qubits)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4, seed=0)
    S = find_slices(tree, target, method="lifetime")
    return tree, S, arrays


def test_chain_boundary_invariants():
    """Every planned chain respects the fusion boundaries: certified live
    set within budget, consecutive positions within one segment, carry
    adjacency between steps, dense valid scratch slots, and no segment
    output (root / hoisted frontier) ever chain-interior."""
    from repro.lowering.partition import partition_tree
    from repro.lowering.refiner import refine_tree_schedule

    circ = sycamore_like(4, 4, 10, seed=0)
    tree, S, _ = _tree_and_slices(circ, 12)
    cp = plan_tree_chains(tree, S)
    assert cp.num_multi >= 2  # acceptance: a syc instance really fuses

    order = tree.contract_order()
    pos = {v: k for k, v in enumerate(order)}
    step_nodes = {k: (*tree.children[v], v) for k, v in enumerate(order)}
    part = partition_tree(tree, S)
    segments = {
        "naive": tuple(range(len(order))),
        "prologue": tuple(pos[v] for v in part.invariant_nodes),
        "epilogue": tuple(pos[v] for v in part.epilogue_nodes),
    }
    sched = refine_tree_schedule(tree, S)

    for c in cp.chains:
        assert c.segment in segments
        seg_pos = segments[c.segment]
        # consecutive within the segment's execution order
        lo = seg_pos.index(c.positions[0])
        assert seg_pos[lo:lo + c.n_steps] == c.positions
        # carry adjacency + external bookkeeping
        assert c.carry_side[0] == "" and len(c.carry_side) == c.n_steps
        assert len(c.external_nodes) == c.n_steps + 1
        for t in range(1, c.n_steps):
            prev_out = step_nodes[c.positions[t - 1]][2]
            l, r, _ = step_nodes[c.positions[t]]
            assert (c.carry_side[t], prev_out) in (("l", l), ("r", r))
        assert c.out_node == step_nodes[c.positions[-1]][2]
        # segment outputs are never interior: interiors' consumers are
        # inside the chain by the adjacency above, and the chain sits in
        # a single segment's order, so the segment output can only be
        # the chain tail
        interior = {step_nodes[p][2] for p in c.positions[:-1]}
        assert c.out_node not in interior
        # VMEM certification + dense, capacious slots
        assert 0 < c.live_bytes <= CHAIN_VMEM_BUDGET_BYTES
        assert len(c.slot_ids) == c.n_steps - 1
        if c.slot_ids:
            assert set(c.slot_ids) == set(range(len(c.slot_elems)))
        itemsize = jnp.dtype(sched.dtype).itemsize
        for t, sid in enumerate(c.slot_ids):
            form = sched.specs[c.positions[t]].form
            assert c.slot_elems[sid] >= form.B * form.M * form.N
        # batch unroll stays bounded
        for p in c.positions:
            assert sched.specs[p].form.B <= CHAIN_MAX_BATCH
        # disjoint savings accounting: round-trips + transpose traffic,
        # never double-charged
        roundtrip = sum(
            2.0 * form.B * form.M * form.N * itemsize
            for form in (
                sched.specs[p].form for p in c.positions[:-1]
            )
        )
        assert c.roundtrip_bytes_saved == pytest.approx(roundtrip)
        transpose = sum(
            sched.specs[p].transpose_bytes for p in c.positions
        )
        assert c.transpose_bytes_saved == pytest.approx(transpose)
        assert c.hbm_bytes_saved == pytest.approx(roundtrip + transpose)

    for seg in ("naive", "prologue", "epilogue"):
        assert cp.hbm_bytes_saved(seg) == pytest.approx(
            sum(
                c.hbm_bytes_saved for c in cp.chains if c.segment == seg
            )
        )


# ----------------------------------------------------------------------
# statevector-oracle E2E conformance
# ----------------------------------------------------------------------
AMP_CIRC = random_1d_circuit(10, 8, seed=3)
AMP_BITS = "0110100101"


@pytest.fixture(scope="module")
def oracle_amp():
    return complex(statevector.amplitude(AMP_CIRC, AMP_BITS))


@pytest.mark.parametrize("mega", ["0", "1"])
@pytest.mark.parametrize("hoist", [False, True])
@pytest.mark.parametrize("backend", ["einsum", "gemm"])
def test_amplitude_matches_statevector(
    monkeypatch, oracle_amp, backend, hoist, mega
):
    """Full-stack amplitudes agree with the statevector oracle on every
    {backend} x {hoist} x {REPRO_MEGAKERNEL} combination."""
    monkeypatch.setenv("REPRO_MEGAKERNEL", mega)
    res = simulate_amplitude(
        AMP_CIRC, AMP_BITS, target_dim=8, backend=backend,
        hoist=hoist, use_cache=False,
    )
    assert abs(complex(res.value) - oracle_amp) < 1e-5
    if mega == "0":
        assert res.report.fused_chains == 0
        assert res.plan.chain_plan is None
    elif backend == "gemm":
        # the refined schedule exists on this path, so the fusion pass ran
        assert res.plan.chain_plan is not None


def test_amplitude_matches_statevector_anytime(monkeypatch, oracle_amp):
    """The anytime co-optimized plan stays oracle-exact with the
    megakernel enabled."""
    monkeypatch.setenv("REPRO_MEGAKERNEL", "1")
    res = simulate_amplitude(
        AMP_CIRC, AMP_BITS, target_dim=8, backend="gemm", hoist=True,
        use_cache=False, optimize="anytime", search_evals=8,
        search_workers=2,
    )
    assert abs(complex(res.value) - oracle_amp) < 1e-5


@pytest.mark.parametrize("mega", ["0", "1"])
def test_sampling_xeb_matches_statevector(monkeypatch, mega):
    """Correlated-sampling amplitudes and XEB agree with the statevector
    oracle with the megakernel on and off."""
    monkeypatch.setenv("REPRO_MEGAKERNEL", mega)
    c = random_1d_circuit(8, 6, seed=7)
    res = sample_bitstrings(
        c, num_samples=256, open_qubits=(1, 4, 6), target_dim=6,
        seed=2, backend="gemm", use_cache=False,
    )
    psi = np.asarray(statevector.simulate(c)).reshape([2] * 8)
    for i in range(res.batch.size):
        bs = res.batch.bitstring_for(i)
        ref = psi[tuple(int(b) for b in bs)]
        assert abs(res.batch.flat()[i] - ref) < 1e-4
    # the sampled entries' probabilities equal the statevector's — the
    # XEB estimate is a deterministic function of them, so it is
    # oracle-exact too (and finite)
    probs = np.array(
        [
            abs(psi[tuple(int(b) for b in bs)]) ** 2
            for bs in res.bitstrings
        ]
    )
    got = np.asarray([abs(a) ** 2 for a in res.amplitudes])
    np.testing.assert_allclose(got, probs, rtol=1e-4, atol=1e-7)
    assert np.isfinite(res.xeb)


def test_resumable_matches_contract_all(monkeypatch):
    """The resumable per-slice driver dispatches the same fused chains
    as the vmapped scan and stays exact across a simulated failure."""
    monkeypatch.setenv("REPRO_MEGAKERNEL", "1")
    tree, S, arrays = _tree_and_slices(random_1d_circuit(10, 8, seed=3), 8)
    plan = ContractionPlan(tree, S, backend="gemm")
    assert plan.chain_plan is not None and plan.chain_plan.num_multi >= 1
    want = np.asarray(plan.contract_all(arrays, slice_batch=4))
    value, state = contract_resumable(plan, arrays, chunk=2)
    np.testing.assert_allclose(
        np.asarray(value), want, rtol=1e-5, atol=1e-6
    )
    assert len(state.done_ids()) == 1 << plan.num_sliced


def test_megakernel_off_switch(monkeypatch):
    """REPRO_MEGAKERNEL=0 disables the fusion pass (no ChainPlan, no
    report fields) without changing values; invalid settings fail fast."""
    tree, S, arrays = _tree_and_slices(random_1d_circuit(10, 8, seed=3), 8)
    monkeypatch.setenv("REPRO_MEGAKERNEL", "1")
    on = ContractionPlan(tree, S, backend="gemm")
    assert on.chain_plan is not None and on.chain_plan.num_multi >= 1
    v_on = np.asarray(on.contract_all(arrays, slice_batch=4))
    monkeypatch.setenv("REPRO_MEGAKERNEL", "0")
    off = ContractionPlan(tree, S, backend="gemm")
    assert off.chain_plan is None and off._chain_dispatch == {}
    v_off = np.asarray(off.contract_all(arrays, slice_batch=4))
    np.testing.assert_allclose(v_on, v_off, rtol=1e-5, atol=1e-6)
    monkeypatch.setenv("REPRO_MEGAKERNEL", "2")
    with pytest.raises(ValueError):
        default_megakernel()


def test_plan_cache_separates_megakernel(monkeypatch):
    """REPRO_MEGAKERNEL joins the plan-cache fingerprint: toggling it
    can never serve a plan compiled under the other setting."""
    circ = random_1d_circuit(9, 7, seed=5)
    tn, arrays = circuit_to_network(circ, bitstring="0" * 9)
    tn, arrays = simplify_network(tn, arrays)
    monkeypatch.setenv("REPRO_MEGAKERNEL", "1")
    p1, r1 = plan_compiled(tn, 7, backend="gemm")
    monkeypatch.setenv("REPRO_MEGAKERNEL", "0")
    p2, r2 = plan_compiled(tn, 7, backend="gemm")
    assert p1 is not p2
    assert p2.chain_plan is None and r2.fused_chains == 0
    monkeypatch.setenv("REPRO_MEGAKERNEL", "1")
    p3, r3 = plan_compiled(tn, 7, backend="gemm")
    assert p3 is p1 and r3.cache_hit
    assert r3.fused_chains == r1.fused_chains


# ----------------------------------------------------------------------
# shard_map conformance (subprocess: multi-device host platform)
# ----------------------------------------------------------------------
SHARDED_MEGAKERNEL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["REPRO_MEGAKERNEL"] = "1"
import numpy as np
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.distributed import contract_sharded
from repro.launch.mesh import make_host_mesh

c = random_1d_circuit(10, 8, seed=3)
tn, arrays = circuit_to_network(c, bitstring="0110100101")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 8, method="lifetime")
dense = ContractionPlan(tree, 0).contract_all(arrays)
plan = ContractionPlan(tree, S, backend="gemm")
assert plan.chain_plan is not None and plan.chain_plan.num_multi >= 1, (
    plan.chain_plan)
mesh = make_host_mesh((4,), ("data",))
for hoist in (False, True):
    v = contract_sharded(plan, arrays, mesh, axis_names=("data",),
                         slice_batch=2, hoist=hoist)
    assert np.allclose(np.asarray(v), np.asarray(dense), atol=1e-5), hoist
# off-switch comparison inside the same sharded harness
os.environ["REPRO_MEGAKERNEL"] = "0"
plan0 = ContractionPlan(tree, S, backend="gemm")
assert plan0.chain_plan is None
v0 = contract_sharded(plan0, arrays, mesh, axis_names=("data",),
                      slice_batch=2, hoist=True)
assert np.allclose(np.asarray(v0), np.asarray(dense), atol=1e-5)
print("DONE")
"""


def test_contract_sharded_megakernel():
    """Fused chains dispatch identically under the shard_map executor
    (4 host devices), megakernel on and off."""
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_MEGAKERNEL],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
