"""Batched correlated-amplitude sampling: one sliced contraction yields the
whole 2^k batch, agrees with per-amplitude simulation on both executors, and
sampled frequencies follow |amplitude|^2."""

import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_kwargs
from repro.core import sample_bitstrings, simulate_amplitude
from repro.quantum import statevector
from repro.quantum.circuits import (
    circuit_to_network,
    random_1d_circuit,
    sycamore_like,
)
from repro.sampling import (
    AmplitudeBatch,
    frequency_sample,
    rejection_sample,
    top_k_indices,
)

OPEN = (12, 13, 14)  # ≥2 open qubits on the 4x4 grid (acceptance criterion)


@pytest.fixture(scope="module")
def syc_result():
    """One batched sampling run on the acceptance circuit: 4x4, 10 cycles."""
    circ = sycamore_like(4, 4, 10, seed=0)
    return circ, sample_bitstrings(
        circ, num_samples=4000, open_qubits=OPEN, target_dim=12, seed=5
    )


def test_batch_is_one_contraction(syc_result):
    """The batch really is 2^k amplitudes from a single planned contraction
    (k open output axes), not N re-executions."""
    circ, res = syc_result
    assert res.batch.k == len(OPEN)
    assert res.batch.amplitudes.shape == (2,) * len(OPEN)
    # the one plan that ran reports a single contraction's metrics
    assert res.report is not None and res.report.num_tensors > 0
    # open wires survive lowering as output indices of that one network
    tn, _ = circuit_to_network(
        circ, bitstring="0" * circ.num_qubits, open_qubits=OPEN
    )
    assert len(tn.open_inds) == len(OPEN)


def test_batched_matches_single_amplitude_sycamore(syc_result):
    """Every batch entry equals the scalar-amplitude engine's value."""
    circ, res = syc_result
    flat = res.batch.flat()
    for i in range(res.batch.size):
        bs = res.batch.bitstring_for(i)
        single = complex(
            simulate_amplitude(circ, bs, target_dim=12, seed=5).value
        )
        assert abs(single - flat[i]) < 1e-4, (i, bs)


def test_sampled_frequencies_match_probs(syc_result):
    """Empirical frequencies of the correlated samples track the exact
    conditional distribution |a|^2/Σ|a|^2 over the open qubits."""
    circ, res = syc_result
    p = res.batch.probs(normalize=True)
    counts = np.zeros(res.batch.size)
    lookup = {res.batch.bitstring_for(i): i for i in range(res.batch.size)}
    for bs in res.bitstrings:
        counts[lookup[bs]] += 1
    emp = counts / counts.sum()
    # multinomial with N=4000: ~4 sigma per-cell tolerance
    tol = 4.0 * np.sqrt(np.maximum(p * (1 - p), 1e-12) / len(res.bitstrings))
    assert np.all(np.abs(emp - p) <= tol + 5e-3), (emp, p)


def test_batch_matches_statevector_small():
    """Exhaustive oracle check on a circuit small enough to enumerate."""
    c = random_1d_circuit(8, 6, seed=7)
    res = sample_bitstrings(
        c, num_samples=64, open_qubits=(1, 4, 6), target_dim=6, seed=2
    )
    psi = np.asarray(statevector.simulate(c)).reshape([2] * 8)
    for i in range(res.batch.size):
        bs = res.batch.bitstring_for(i)
        ref = psi[tuple(int(b) for b in bs)]
        assert abs(res.batch.flat()[i] - ref) < 1e-4


def test_nonzero_base_bitstring():
    """Open-batch amplitudes condition on the projected (non-zero) prefix."""
    c = random_1d_circuit(7, 5, seed=1)
    res = sample_bitstrings(
        c,
        num_samples=16,
        open_qubits=(0, 3),
        base_bitstring="0110101",
        target_dim=5,
    )
    psi = np.asarray(statevector.simulate(c)).reshape([2] * 7)
    for i in range(res.batch.size):
        bs = res.batch.bitstring_for(i)
        assert bs[1:3] == "11" and bs[4] == "1" and bs[6] == "1"
        ref = psi[tuple(int(b) for b in bs)]
        assert abs(res.batch.flat()[i] - ref) < 1e-4


def test_samplers_agree_on_support():
    amps = np.array(
        [[0.6 + 0j, 0.0], [0.3j, 0.1]], dtype=np.complex64
    )
    batch = AmplitudeBatch(amps, (0, 1), "00", 2)
    f = frequency_sample(batch, 500, seed=0)
    r = rejection_sample(batch, 500, seed=0)
    assert 1 not in set(f.tolist()) and 1 not in set(r.tolist())
    t = top_k_indices(batch, 2)
    assert t.tolist() == [0, 2]
    assert batch.bitstring_for(2) == "10"


def test_rejection_matches_frequency_distribution():
    rng = np.random.default_rng(0)
    amps = (rng.normal(size=8) + 1j * rng.normal(size=8)).astype(
        np.complex64
    ).reshape(2, 2, 2)
    batch = AmplitudeBatch(amps, (0, 1, 2), "000", 3)
    p = batch.probs(normalize=True)
    r = rejection_sample(batch, 20000, seed=4)
    emp = np.bincount(r, minlength=8) / len(r)
    assert np.all(np.abs(emp - p) < 0.02)


SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import sample_bitstrings
from repro.launch.mesh import make_host_mesh
from repro.quantum.circuits import sycamore_like

circ = sycamore_like(4, 4, 10, seed=0)
kw = dict(num_samples=64, open_qubits=(12, 13, 14), target_dim=12, seed=5)
single = sample_bitstrings(circ, **kw)
mesh = make_host_mesh((4, 2), ("data", "model"))
shard = sample_bitstrings(circ, mesh=mesh, axis_names=("data",), **kw)
np.testing.assert_allclose(
    shard.batch.amplitudes, single.batch.amplitudes, atol=1e-4
)
# slice axis over the full process grid, with per-device slice batching
shard2 = sample_bitstrings(
    circ, mesh=mesh, axis_names=("data", "model"), slice_batch=2, **kw
)
np.testing.assert_allclose(
    shard2.batch.amplitudes, single.batch.amplitudes, atol=1e-4
)
print("DONE")
"""


def test_sampling_sharded_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
