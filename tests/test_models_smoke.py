"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; decode-vs-forward
consistency for the KV-cache paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, get_config, smoke_shrink
from repro.models import build_model
from repro.parallel.sharding import count_params, init_params

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, with_labels=True):
    b = {}
    if cfg.family == "encdec":
        b["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
        b["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    elif cfg.embed_inputs:
        b["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    else:
        b["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.mrope:
        b["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S)
        )
    if with_labels:
        b["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = smoke_shrink(get_config(arch))
    model = build_model(cfg)
    params = init_params(model.param_defs(), KEY)
    batch = make_batch(cfg)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), arch
    gleaves = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in gleaves), arch
    # at least one non-zero gradient
    assert any(float(jnp.abs(g).max()) > 0 for g in gleaves), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch):
    cfg = smoke_shrink(get_config(arch))
    model = build_model(cfg)
    params = init_params(model.param_defs(), KEY)
    batch = make_batch(cfg, with_labels=False)
    max_len = S + 32
    if cfg.window:
        max_len = -(-max_len // cfg.window) * cfg.window
    cache, logits = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len)
    )(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    mrope = jnp.full((3, B, 1), S, jnp.int32) if cfg.mrope else None
    logits2, cache2 = jax.jit(model.decode_step)(
        params, cache, tok, jnp.int32(S), mrope
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits2).all(), arch


@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen3-4b", "mamba2-130m"])
def test_decode_matches_forward(arch):
    """Prefill(S) + decode(S) logits == forward over S+1 tokens."""
    cfg = smoke_shrink(get_config(arch))
    model = build_model(cfg)
    params = init_params(model.param_defs(), KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    # full forward
    h, _ = model.hidden_states(params, {"tokens": toks})
    full_logits = jnp.einsum(
        "bd,dv->bv", h[:, -1].astype(jnp.float32),
        model.head_weights(params).astype(jnp.float32),
    )
    # prefill on S tokens, then decode token S
    cache, _ = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=S + 8)
    )(params, {"tokens": toks[:, :S]})
    logits, _ = jax.jit(model.decode_step)(
        params, cache, toks[:, S:], jnp.int32(S), None
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits), rtol=5e-2, atol=5e-2
    )


def test_cell_matrix_covers_40():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    # long_500k skipped exactly for the 8 non-sub-quadratic archs
    assert len(skipped) == 8
    assert all(c[1] == "long_500k" for c in skipped)
    assert len(runnable) == 32


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_instantiable_abstractly(arch):
    """Full configs are exercised via eval_shape only (no allocation)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    n = count_params(model.param_defs())
    assert n > 0.8 * 1e8  # every assigned arch is at least ~100M params
