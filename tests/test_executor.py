"""Executor correctness: sliced == dense == statevector oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ContractionPlan,
    simplify_network,
    simulate_amplitude,
)
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.quantum import statevector
from repro.quantum.circuits import (
    circuit_to_network,
    random_1d_circuit,
    sycamore_like,
)


@pytest.mark.parametrize("method", ["lifetime", "greedy", "interval"])
def test_amplitude_matches_statevector(method):
    c = random_1d_circuit(9, 7, seed=11)
    bs = "011010010"
    ref = statevector.amplitude(c, bs)
    res = simulate_amplitude(
        c, bs, target_dim=4, method=method, tune=(method == "lifetime")
    )
    assert abs(complex(res.value) - ref) < 1e-4
    # memory bound respected
    assert res.tree.sliced_width(res.smask) <= 4


@given(seed=st.integers(0, 500), nq=st.integers(6, 10))
@settings(max_examples=8)
def test_amplitude_property(seed, nq):
    c = random_1d_circuit(nq, 5, seed=seed)
    rng = np.random.default_rng(seed)
    bs = "".join(str(b) for b in rng.integers(0, 2, nq))
    ref = statevector.amplitude(c, bs)
    res = simulate_amplitude(c, bs, target_dim=5, seed=seed)
    assert abs(complex(res.value) - ref) < 1e-4


def test_sliced_equals_dense_2d_circuit():
    circ = sycamore_like(3, 4, 8, seed=3)
    tn, arrays = circuit_to_network(circ, bitstring="0" * 12)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    for method in ("lifetime", "greedy"):
        S = find_slices(tree, max(tree.width() - 3, 4), method=method)
        v = np.asarray(ContractionPlan(tree, S).contract_all(arrays, slice_batch=4))
        np.testing.assert_allclose(v, dense, rtol=1e-4, atol=1e-5)


def test_open_indices_batch_amplitudes():
    """Open final wires → the contraction returns the full statevector."""
    c = random_1d_circuit(6, 4, seed=2)
    tn, arrays = circuit_to_network(c, open_final=True)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    out = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    psi = np.asarray(statevector.simulate(c))
    # executor output axes follow tn.open_inds order = qubit order
    np.testing.assert_allclose(out, psi, rtol=1e-4, atol=1e-5)


def test_sliced_open_network():
    c = random_1d_circuit(6, 4, seed=9)
    tn, arrays = circuit_to_network(c, open_final=True)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    # open indices cannot be sliced: the bound cannot go below 6 here
    S = find_slices(tree, max(tree.width() - 2, 6), method="lifetime")
    v = np.asarray(ContractionPlan(tree, S).contract_all(arrays, slice_batch=2))
    np.testing.assert_allclose(v, dense, rtol=1e-4, atol=1e-5)


def test_simplify_preserves_value():
    c = random_1d_circuit(7, 5, seed=4)
    bs = "0101101"
    tn, arrays = circuit_to_network(c, bitstring=bs)
    tree_raw = random_greedy_tree(tn, repeats=4)
    raw = complex(np.asarray(ContractionPlan(tree_raw, 0).contract_all(arrays)))
    tn2, arrays2 = simplify_network(tn, arrays)
    tree2 = random_greedy_tree(tn2, repeats=4)
    simp = complex(np.asarray(ContractionPlan(tree2, 0).contract_all(arrays2)))
    assert abs(raw - simp) < 1e-4
    assert tn2.num_tensors < tn.num_tensors
