"""Contraction-tree cost algebra (Eqs. 2/3/4/6) and tree surgery."""

import math

import pytest
from hypothesis import given, strategies as st

from conftest import random_closed_network, random_tree
from repro.core.contraction_tree import (
    ContractionTree,
    linear_to_ssa,
    ssa_to_linear,
)
from repro.core.lifetime import detect_stem
from repro.core.tensor_network import popcount


@given(n=st.integers(5, 20), seed=st.integers(0, 9999))
def test_tree_structure_valid(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    tree.check_valid()
    assert len(tree.children) == tn.num_tensors - 1


@given(n=st.integers(5, 16), seed=st.integers(0, 9999))
def test_eq6_reduces_to_eq3_when_unsliced(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    assert math.isclose(tree.sliced_cost(0), tree.total_cost())
    assert math.isclose(tree.slicing_overhead(0), 1.0)


@given(n=st.integers(6, 16), seed=st.integers(0, 9999), k=st.integers(0, 5))
def test_eq6_brute_force(n, seed, k):
    """Eq. 6 equals brute-force: simulate every slice assignment by
    removing sliced bits and summing 2^|s_node| over all assignments."""
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    inds = list(range(min(tn.num_inds, 8)))
    smask = 0
    for i in inds[:k]:
        smask |= 1 << i
    s = popcount(smask)
    brute = 0.0
    for v in tree.children:
        nm = tree.node_mask(v)
        kept = popcount(nm & ~smask)
        brute += (2.0 ** s) * (2.0 ** kept)
    assert math.isclose(tree.sliced_cost(smask), brute, rel_tol=1e-9)


@given(n=st.integers(8, 24), seed=st.integers(0, 9999))
def test_exchange_preserves_leaves_and_masks(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    stem = detect_stem(tree)
    done = 0
    for i in range(len(stem.nodes) - 1):
        args = stem.exchange_args(i)
        if args is None:
            continue
        p, q, bq, bp = args
        if tree.parent.get(q) != p:
            continue
        tree.exchange_at(p, q, bq, bp)
        tree.check_valid()
        done += 1
        if done >= 3:
            break


@given(n=st.integers(8, 24), seed=st.integers(0, 9999))
def test_merge_preserves_leaves_and_masks(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    stem = detect_stem(tree)
    for i in range(len(stem.nodes) - 1):
        args = stem.exchange_args(i)
        if args is None:
            continue
        p, q, bq, bp = args
        if tree.parent.get(q) != p:
            continue
        tree.merge_branches_at(p, q, bq, bp)
        tree.check_valid()
        break


def test_ssa_linear_roundtrip():
    path = [(0, 1), (4, 2), (5, 3)]
    lin = ssa_to_linear(path, 4)
    back = linear_to_ssa(lin, 4)
    # pair order within a contraction is not semantic
    assert [tuple(sorted(p)) for p in back] == [
        tuple(sorted(p)) for p in path
    ]
