"""Property tests for the paper's core theory (Defs. 1-2, Lemma 1, Thm. 1)."""

import pytest
from hypothesis import given, strategies as st

from conftest import random_closed_network, random_tree
from repro.core.lifetime import (
    correlated_contractions,
    detect_stem,
    leaf_path,
    lifetime_edges,
)
from repro.core.tensor_network import bits, popcount


@given(
    n=st.integers(6, 24),
    deg=st.integers(3, 4),
    seed=st.integers(0, 10_000),
)
def test_theorem1_lifetime_is_leaf_path(n, deg, seed):
    """Thm. 1: the lifetime of any index equals the set of tree edges on
    the unique path between the two leaves owning that index."""
    tn = random_closed_network(n, deg, seed)
    tree = random_tree(tn, seed=seed)
    for b in range(min(tn.num_inds, 12)):
        owners = [i for i, m in enumerate(tn.masks) if m >> b & 1]
        if len(owners) != 2:
            continue
        tensors, nodes = leaf_path(tree, owners[0], owners[1])
        assert set(lifetime_edges(tree, b)) == set(tensors)
        assert set(correlated_contractions(tree, b)) == set(nodes)


@given(
    n=st.integers(6, 24),
    deg=st.integers(3, 4),
    seed=st.integers(0, 10_000),
)
def test_conservation_lemma(n, deg, seed):
    """Lemma 1: an index at a node appears in exactly the two contracted
    tensors; contractions never create indices."""
    tn = random_closed_network(n, deg, seed)
    tree = random_tree(tn, seed=seed)
    for v, (l, r) in tree.children.items():
        nm = tree.node_mask(v)
        em = tree.emask[v]
        # result indices all came from the children
        assert em & ~nm == 0


@given(
    n=st.integers(8, 30),
    seed=st.integers(0, 10_000),
)
def test_stem_is_max_cost_leaf_path_and_contiguous(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed=seed)
    stem = detect_stem(tree)
    stem.check_contiguous()
    # stem nodes form a connected path: consecutive tensors share a node
    assert len(stem.nodes) == len(stem.tensors) - 1
    # stem cost >= cost of 50 random leaf-to-leaf paths
    import random as _r

    rng = _r.Random(seed)
    leaves = list(range(tn.num_tensors))
    stem_cost = stem.total_cost()
    for _ in range(20):
        a, b = rng.sample(leaves, 2)
        _, nodes = leaf_path(tree, a, b)
        c = sum(2.0 ** popcount(tree.node_mask(x)) for x in nodes)
        assert c <= stem_cost + 1e-6


def test_lifetime_overlap_is_interval():
    """The stem-restricted lifetime of every index is one contiguous
    segment (intersection of two tree paths)."""
    tn = random_closed_network(40, 3, 123)
    tree = random_tree(tn, seed=5)
    stem = detect_stem(tree)
    iv = stem.index_intervals()
    masks = stem.masks()
    for b, (lo, hi) in iv.items():
        for p, m in enumerate(masks):
            inside = lo <= p <= hi
            assert bool(m >> b & 1) == inside or not inside
