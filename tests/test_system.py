"""End-to-end behaviour tests for the paper's system: the full
circuit → plan → slice → contract → XEB pipeline, with the paper's
headline claims checked at test scale."""

import numpy as np
import pytest

from repro.core import plan_contraction, simulate_amplitude, simplify_network
from repro.core.tensor_network import popcount
from repro.quantum import statevector, xeb
from repro.quantum.circuits import (
    circuit_to_network,
    random_1d_circuit,
    sycamore_like,
)


def test_full_pipeline_sycamore_like():
    """Plan + slice + contract a 4x4 sycamore-like circuit; the lifetime
    slicer must hit the memory bound with small overhead (paper: <1.2 on
    the real Sycamore network)."""
    circ = sycamore_like(4, 4, 10, seed=1)
    tn, arrays = circuit_to_network(circ, bitstring="0" * 16)
    tn, arrays = simplify_network(tn, arrays)
    target = 12
    tree, smask, report = plan_contraction(
        tn, target, method="lifetime", tune=True, merge=True
    )
    assert tree.sliced_width(smask) <= target
    assert report.slicing_overhead < 4.0  # small circuit; paper net: 1.255
    assert report.num_sliced >= 1


def test_xeb_validation_workflow():
    """Reproduce the paper's validation loop at test scale: simulate k
    sampled bitstring amplitudes with the sliced contraction engine and
    compute Linear XEB (Eq. 1)."""
    nq, k = 8, 24
    c = random_1d_circuit(nq, 8, seed=5)
    probs = statevector.probabilities(c)
    samples = xeb.sample_bitstrings(probs, k, seed=1)
    amp_probs = []
    for s in samples[:6]:  # budget: 6 amplitudes through the full engine
        bs = format(s, f"0{nq}b")
        res = simulate_amplitude(c, bs, target_dim=5, tune=False, merge=False)
        amp_probs.append(abs(complex(res.value)) ** 2)
    np.testing.assert_allclose(
        amp_probs, probs[samples[:6]], rtol=1e-3, atol=1e-6
    )
    f = xeb.linear_xeb(nq, probs[samples])
    assert f > 0.3  # sampled from the true distribution → positive XEB


def test_planner_improves_over_greedy_on_stemmy_network():
    """The paper's pipeline (lifetime slicing + tuning + merging) must not
    be worse than the greedy baseline on slicing overhead for a
    stem-dominant RQC network."""
    circ = sycamore_like(4, 5, 12, seed=2)
    tn, arrays = circuit_to_network(circ, bitstring="0" * 20)
    tn, _ = simplify_network(tn, arrays)
    target = 14
    _, s_greedy, rep_greedy = plan_contraction(
        tn, target, method="greedy", tune=False, merge=False, seed=0
    )
    _, s_life, rep_life = plan_contraction(
        tn, target, method="lifetime", tune=True, merge=False, seed=0
    )
    assert rep_life.slicing_overhead <= rep_greedy.slicing_overhead * 1.5
    assert popcount(s_life) <= popcount(s_greedy) + 1
