"""Mixed-precision contraction under an XEB error budget.

Gates the PR-9 stack:

  1. the forward error model + greedy demotion (``repro.lowering.
     precision``): monotone in the fidelity tolerance, zero-tolerance
     reproduces the fp32 plan *bitwise*;
  2. statevector-oracle conformance: auto plans stay within the
     requested Linear-XEB tolerance end-to-end, across hoist modes and
     the shard_map sampling path;
  3. the pinned syc-12 regression gate (CI ``-k xeb_gate``): modeled
     epilogue speedup >= 1.3x, total HBM traffic strictly lower, |S|
     never larger, measured amplitude error within tolerance;
  4. plan-cache fingerprints: the resolved precision mode always joins
     the key, the tolerance only off fp32;
  5. bf16 kernel parity: the chain megakernel is bitwise against its
     off-TPU reference at matched precisions, and the per-op bf16 paths
     stay within the bf16 forward-error envelope of fp32.

The heavyweight fixtures pin ``REPRO_MEGAKERNEL=1`` / ``REPRO_FUSED_
GEMM=1`` while *planning*: the syc-12 contraction is ~50x slower
unfused on CPU, and the gate's modeled numbers are only meaningful on
the schedule the refiner actually targets.  Execution-mode coverage
(hoist on/off, shard_map) still varies per test.
"""

import contextlib
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.api import plan_compiled, sample_bitstrings, simulate_amplitude
from repro.core.executor import ContractionPlan, simplify_network
from repro.core.tensor_network import popcount
from repro.lowering import (
    DEFAULT_FIDELITY_TOL,
    assign_precision,
    default_precision,
    node_amp_error,
    refine_tree_schedule,
    tree_storage_itemsizes,
)
from repro.lowering.precision import predicted_fidelity_loss
from repro.quantum import statevector
from repro.quantum.circuits import (
    circuit_to_network,
    random_1d_circuit,
    sycamore_like,
)
from repro.quantum.xeb import xeb_from_amplitudes

SYC_TD = 18  # pinned syc-12 planner config (matches bench_end_to_end)
GATE_TOL = 0.05  # the "realistic" XEB budget the gate certifies at


@contextlib.contextmanager
def _pinned_lowering_env():
    """Fix the lowering switches the heavy fixtures assume (see module
    docstring) without disturbing the CI matrix env for other tests."""
    saved = {
        k: os.environ.get(k) for k in ("REPRO_MEGAKERNEL", "REPRO_FUSED_GEMM")
    }
    os.environ["REPRO_MEGAKERNEL"] = "1"
    os.environ["REPRO_FUSED_GEMM"] = "1"
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


@pytest.fixture(scope="module")
def syc():
    circ = sycamore_like(4, 5, 12, seed=0)
    tn, arrays = circuit_to_network(circ, bitstring="0" * circ.num_qubits)
    tn, arrays = simplify_network(tn, arrays)
    return circ, tn, arrays


@pytest.fixture(scope="module")
def syc_oracle(syc):
    circ, _, _ = syc
    return complex(statevector.amplitude(circ, "0" * circ.num_qubits))


@pytest.fixture(scope="module")
def syc_fp32(syc):
    """(plan, report, amplitude) of the pinned fp32 baseline."""
    _, tn, arrays = syc
    with _pinned_lowering_env():
        plan, report = plan_compiled(
            tn, SYC_TD, backend="gemm", use_cache=False,
            slicing_mode="peak", precision="fp32",
        )
        amp = complex(np.asarray(plan.contract_all(arrays, slice_batch=8)))
    return plan, report, amp


@pytest.fixture(scope="module")
def syc_auto(syc):
    """(plan, report, amplitude) of the auto plan at the gate budget."""
    _, tn, arrays = syc
    with _pinned_lowering_env():
        plan, report = plan_compiled(
            tn, SYC_TD, backend="gemm", use_cache=False,
            slicing_mode="peak", precision="auto", fidelity_tol=GATE_TOL,
        )
        amp = complex(np.asarray(plan.contract_all(arrays, slice_batch=8)))
    return plan, report, amp


# ----------------------------------------------------------------------
# error model + assignment algebra (no execution)
# ----------------------------------------------------------------------
def test_default_precision_env(monkeypatch):
    monkeypatch.delenv("REPRO_PRECISION", raising=False)
    assert default_precision() == "fp32"
    monkeypatch.setenv("REPRO_PRECISION", "auto")
    assert default_precision() == "auto"
    monkeypatch.setenv("REPRO_PRECISION", "fp64")
    with pytest.raises(ValueError):
        default_precision()


def test_error_model_monotone_in_k_and_depth(syc):
    _, tn, _ = syc
    with _pinned_lowering_env():
        sched = refine_tree_schedule(_tree_of(syc), 0)
    forms = [s.form for s in sched.specs]
    by_k = sorted(forms, key=lambda f: f.K)
    errs = [node_amp_error(f) for f in by_k]
    assert all(e > 0 for e in errs)
    assert errs == sorted(errs)  # grows with K at depth 0
    f = forms[0]
    assert node_amp_error(f, depth=8) > node_amp_error(f, depth=0)


def _tree_of(syc_fixture):
    from repro.optimize import oneshot_plan

    _, tn, _ = syc_fixture
    shot = oneshot_plan(tn, SYC_TD, seed=0, slicing_mode="peak")
    return shot.tree


def test_assignment_monotone_and_certified(syc):
    """bf16 sets are nested as the tolerance grows (strict-prefix
    admission) and every assignment self-certifies within its budget."""
    with _pinned_lowering_env():
        tree = _tree_of(syc)
        sched = refine_tree_schedule(tree, 0)
        prev: set[int] = set()
        for tol in (0.0, 1e-3, 5e-3, 0.02, 0.05, 0.5):
            out = assign_precision(sched, mode="auto", fidelity_tol=tol)
            cur = {
                i for i, s in enumerate(out.specs) if s.precision == "bf16"
            }
            assert prev <= cur, f"tol={tol} dropped a prior demotion"
            assert predicted_fidelity_loss(out.predicted_amp_error) <= tol
            prev = cur
        assert assign_precision(sched, mode="auto", fidelity_tol=0.0).specs \
            == sched.specs
        forced = assign_precision(sched, mode="bf16", fidelity_tol=1e9)
        assert set(
            i for i, s in enumerate(forced.specs) if s.precision == "bf16"
        ) >= prev


def test_storage_itemsizes_halve_only_bf16_consumers(syc):
    with _pinned_lowering_env():
        tree = _tree_of(syc)
        iso = tree_storage_itemsizes(tree, 0, mode="bf16", fidelity_tol=1e9)
    assert iso  # the pinned syc-12 schedule has MXU steps to demote
    assert set(iso.values()) <= {4, 8}  # halved or full, nothing else
    assert 4 in iso.values()  # some node is actually stored bf16
    assert tree_storage_itemsizes(tree, 0, mode="fp32") is None


# ----------------------------------------------------------------------
# zero tolerance == fp32, bitwise
# ----------------------------------------------------------------------
def test_tol_zero_bitwise_fp32(syc, syc_fp32):
    _, tn, arrays = syc
    plan32, _, amp32 = syc_fp32
    with _pinned_lowering_env():
        p0, r0 = plan_compiled(
            tn, SYC_TD, backend="gemm", use_cache=False,
            slicing_mode="peak", precision="auto", fidelity_tol=0.0,
        )
        amp0 = complex(np.asarray(p0.contract_all(arrays, slice_batch=8)))
    assert p0.smask == plan32.smask
    assert p0.schedule.specs == plan32.schedule.specs
    assert (r0.precision_counts or {}).get("bf16", 0) == 0
    assert amp0 == amp32  # bitwise, not allclose


# ----------------------------------------------------------------------
# pinned syc-12 gate (CI: -k xeb_gate)
# ----------------------------------------------------------------------
def test_syc12_xeb_gate(syc_fp32, syc_auto, syc_oracle):
    plan32, rep32, amp32 = syc_fp32
    plana, repa, ampa = syc_auto

    # the fp32 baseline itself is oracle-exact
    assert abs(amp32 - syc_oracle) / abs(syc_oracle) < 1e-3

    # the auto plan demoted something and certified it
    n16 = (repa.precision_counts or {}).get("bf16", 0)
    assert n16 >= 1
    assert repa.precision == "auto" and repa.fidelity_tol == GATE_TOL
    assert predicted_fidelity_loss(repa.predicted_amp_error) <= GATE_TOL

    # |S| never larger under bf16 storage (peak-mode pruning)
    assert plana.num_sliced <= plan32.num_sliced

    # modeled epilogue time: >= 1.3x lower end-to-end
    def epi_total(plan):
        per_slice = sum(
            plan.schedule.specs[k].modeled_time_s for k in plan.epilogue_idx
        )
        return per_slice * (1 << plan.num_sliced)

    assert epi_total(plan32) >= 1.3 * epi_total(plana)

    # total modeled HBM traffic strictly lower
    def hbm_total(plan):
        return plan.schedule.hbm_traffic_bytes() * (1 << plan.num_sliced)

    assert hbm_total(plana) < hbm_total(plan32)

    # measured amplitude error within the XEB budget
    assert abs(ampa - syc_oracle) / abs(syc_oracle) <= GATE_TOL


def test_report_row_mentions_precision(syc_auto):
    _, repa, _ = syc_auto
    row = repa.row()
    assert "prec=auto" in row and "tol=0.05" in row


# ----------------------------------------------------------------------
# execution-mode matrix: hoist on/off + shard_map sampling
# ----------------------------------------------------------------------
@pytest.mark.parametrize("hoist", [False, True])
def test_auto_amplitude_within_tol_hoist_modes(
    syc, syc_auto, syc_oracle, hoist
):
    _, _, arrays = syc
    plana, _, _ = syc_auto
    amp = complex(
        np.asarray(plana.contract_all(arrays, slice_batch=8, hoist=hoist))
    )
    assert abs(amp - syc_oracle) / abs(syc_oracle) <= GATE_TOL


def test_sampling_xeb_within_tolerance_shard_map(syc):
    """Open-batch sampling (the shard_map path, 1-device mesh) agrees
    with its fp32 twin within the budget, amplitude-wise and XEB-wise."""
    from repro.launch.mesh import make_host_mesh

    circ, _, _ = syc
    mesh = make_host_mesh((1,), ("data",))
    kw = dict(
        num_samples=128, open_qubits=(16, 17, 18, 19), target_dim=SYC_TD,
        seed=1, backend="gemm", use_cache=False, slice_batch=4,
        slicing_mode="peak",
    )
    with _pinned_lowering_env():
        base = sample_bitstrings(circ, precision="fp32", **kw)
        mixed = sample_bitstrings(
            circ, mesh=mesh, axis_names=("data",),
            precision="auto", fidelity_tol=GATE_TOL, **kw,
        )
    a32 = np.asarray(base.batch.amplitudes)
    a16 = np.asarray(mixed.batch.amplitudes)
    scale = np.abs(a32).max()
    assert np.abs(a16 - a32).max() <= GATE_TOL * scale
    x32 = xeb_from_amplitudes(circ.num_qubits, a32.ravel())
    x16 = xeb_from_amplitudes(circ.num_qubits, a16.ravel())
    assert abs(x16 - x32) <= 3 * GATE_TOL * (1.0 + abs(x32))


def test_einsum_backend_precision_inert():
    """precision= is accepted (and inert) on the einsum backend."""
    circ = random_1d_circuit(8, 6, seed=1)
    want = complex(statevector.amplitude(circ, "0" * 8))
    res = simulate_amplitude(
        circ, "0" * 8, target_dim=6, backend="einsum", use_cache=False,
        precision="auto", fidelity_tol=GATE_TOL,
    )
    assert res.plan.schedule is None
    assert res.report.precision_counts is None
    assert abs(complex(res.value) - want) < 1e-5


# ----------------------------------------------------------------------
# plan-cache fingerprints
# ----------------------------------------------------------------------
def test_plan_cache_separates_precision(monkeypatch):
    circ = random_1d_circuit(9, 7, seed=5)
    tn, arrays = circuit_to_network(circ, bitstring="0" * 9)
    tn, arrays = simplify_network(tn, arrays)
    monkeypatch.setenv("REPRO_PRECISION", "fp32")
    p1, r1 = plan_compiled(tn, 7, backend="gemm")
    monkeypatch.setenv("REPRO_PRECISION", "auto")
    p2, r2 = plan_compiled(tn, 7, backend="gemm")
    assert p1 is not p2  # env mode joins the fingerprint
    p3, r3 = plan_compiled(tn, 7, backend="gemm")
    assert p3 is p2 and r3.cache_hit
    monkeypatch.delenv("REPRO_PRECISION")
    # off fp32 the tolerance separates plans ...
    pa, _ = plan_compiled(tn, 7, backend="gemm", precision="auto",
                          fidelity_tol=0.05)
    pb, _ = plan_compiled(tn, 7, backend="gemm", precision="auto",
                          fidelity_tol=0.1)
    pc, rc = plan_compiled(tn, 7, backend="gemm", precision="auto",
                           fidelity_tol=0.05)
    assert pa is not pb
    assert pc is pa and rc.cache_hit
    # ... while fp32 plans ignore it (no cache fragmentation)
    pf1, _ = plan_compiled(tn, 7, backend="gemm", precision="fp32",
                           fidelity_tol=0.05)
    pf2, rf2 = plan_compiled(tn, 7, backend="gemm", precision="fp32",
                             fidelity_tol=0.1)
    assert pf2 is pf1 and rf2.cache_hit


# ----------------------------------------------------------------------
# peak-mode |S| never larger
# ----------------------------------------------------------------------
@pytest.mark.parametrize("td", [16, 18, 20])
def test_peak_mode_slices_never_larger(syc, td):
    from repro.optimize import oneshot_plan

    _, tn, _ = syc
    with _pinned_lowering_env():
        s32 = oneshot_plan(tn, td, seed=0, slicing_mode="peak",
                           precision="fp32")
        s16 = oneshot_plan(tn, td, seed=0, slicing_mode="peak",
                           precision="auto", fidelity_tol=GATE_TOL)
    assert popcount(s16.smask) <= popcount(s32.smask)
    # prune-only second pass: the bf16 mask is a subset of the fp32 one
    assert s16.smask & ~s32.smask == 0


# ----------------------------------------------------------------------
# calibration splits precision classes
# ----------------------------------------------------------------------
def test_calibrate_precision_classes(syc, syc_auto):
    from repro.obs.calibrate import calibrate_plan

    _, _, arrays = syc
    plana, repa, _ = syc_auto
    rep = calibrate_plan(plana, arrays, slice_id=0, repeat=1)
    assert rep.backend == plana.backend
    classes = rep.ratio_by_class()
    assert classes
    # at least one row runs off full fp32 and is classed separately
    assert any("[" in cls for cls in classes), classes
    for r in rep.rows:
        assert r.precision in ("fp32", "bf16", "mixed")


# ----------------------------------------------------------------------
# kernel parity at bf16
# ----------------------------------------------------------------------
def test_matmul_bf16_within_forward_error():
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 192)).astype(np.float32)
    b = rng.standard_normal((192, 128)).astype(np.float32)
    full = np.asarray(ops.matmul(jnp.asarray(a), jnp.asarray(b),
                                 interpret=True))
    demoted = np.asarray(ops.matmul(jnp.asarray(a), jnp.asarray(b),
                                    interpret=True, precision="bf16"))
    want = np.matmul(
        np.asarray(jnp.asarray(a).astype(jnp.bfloat16), dtype=np.float64),
        np.asarray(jnp.asarray(b).astype(jnp.bfloat16), dtype=np.float64),
    )
    scale = np.abs(full).max()
    # demotion really happened, and stayed inside the bf16 envelope
    assert np.abs(demoted - full).max() > 0
    assert np.abs(demoted - want).max() <= 1e-2 * scale
    assert np.abs(demoted - full).max() <= 4 * node_amp_error_bound(192) * scale


def node_amp_error_bound(k: int) -> float:
    """Loose forward bound used by the kernel parity tests: 2u·sqrt(
    1 + log2(K)/8) — the model's depth-0 per-node term."""
    import math

    return 2.0 * 2.0 ** -9 * math.sqrt(1.0 + math.log2(max(k, 1)) / 8.0)


@pytest.mark.parametrize("case", [0, 2, 3])
def test_chain_kernel_bitwise_vs_reference_bf16(case):
    """The chain megakernel and its off-TPU reference agree *bitwise* at
    matched per-step precisions — the same contract the fp32 suite pins,
    extended to mixed schedules."""
    from test_megakernel import (
        CHAIN_CASES,
        _chain_operands,
        _chain_slots,
        _einsum_chain,
        _random_chain,
    )

    from repro.kernels import ops

    seed, n_steps, cplx, batch = CHAIN_CASES[case]
    rng = np.random.default_rng(seed)
    forms, carry_side, externals, sizes = _random_chain(
        rng, n_steps, with_batch=batch
    )
    slot_ids, slot_elems = _chain_slots(forms, carry_side)
    arrs = _chain_operands(rng, externals, sizes, complex_mode=cplx)
    want = np.asarray(_einsum_chain(forms, carry_side, arrs))

    for precisions in (
        ("bf16",) * n_steps,
        tuple("bf16" if t % 2 else "fp32" for t in range(n_steps)),
    ):
        kw = dict(
            forms=forms, carry_side=carry_side,
            slot_ids=slot_ids, slot_elems=slot_elems,
            precisions=precisions,
        )
        got_kernel = np.asarray(ops.fused_chain(
            arrs, use_kernel=True, interpret=True, **kw
        ))
        got_ref = np.asarray(ops.fused_chain(arrs, use_kernel=False, **kw))
        assert np.array_equal(got_kernel, got_ref), precisions
        scale = np.abs(want).max()
        assert np.abs(got_kernel - want).max() <= 0.05 * scale
