"""Sharding resolution: divisibility-aware axis dropping, param specs."""

import subprocess
import sys

import jax
import pytest

from conftest import subprocess_kwargs


def test_resolve_spec_drops_nondivisible(monkeypatch):
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import resolve_spec

    mesh = make_host_mesh((1,), ("data",))
    # with shape divisible: keeps axis
    assert resolve_spec(("fsdp",), mesh, (16,)) == P("data")
    # non-divisible: drops — only possible to see with >1-sized axes, so
    # emulate via a fake mesh below (subprocess covers the real case)
    assert resolve_spec((None, "fsdp"), mesh, (3, 8)) == P(None, "data")


DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_config, smoke_shrink
from repro.models import build_model
from repro.parallel.sharding import (
    abstract_params, param_shardings, logical_shardings, resolve_spec,
)
from repro.train import optimizer as opt
from repro.train.train_step import (
    abstract_state, make_train_step, state_logical, make_decode_step,
)
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))

# divisibility dropping: vocab 50280 % 2 == 0 keeps, odd dims drop
r = resolve_spec(("tp", "fsdp"), mesh, (7, 8))
assert r == P(None, "data"), r

for arch in ("llama3.2-3b", "deepseek-moe-16b", "mamba2-130m"):
    cfg = smoke_shrink(get_config(arch))
    model = build_model(cfg)
    ocfg = opt.OptimizerConfig()
    step = make_train_step(model, ocfg)
    st_abs = abstract_state(model, ocfg)
    st_sh = logical_shardings(st_abs, state_logical(model, ocfg), mesh)
    B, S = 8, 32
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    b_log = {"tokens": ("dp", None), "labels": ("dp", None)}
    b_sh = logical_shardings(batch_abs, b_log, mesh)
    lowered = jax.jit(
        step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None)
    ).lower(st_abs, batch_abs)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
    print("ok", arch)

# decode path on the multi-pod mini mesh
cfg = smoke_shrink(get_config("qwen3-4b"))
model = build_model(cfg)
from repro.parallel.sharding import abstract_params
defs = model.param_defs()
cache_spec = model.cache_spec(8, 64)
is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], jax.ShapeDtypeStruct)
cache_abs = jax.tree.map(lambda t: t[0], cache_spec, is_leaf=is_pair)
cache_log = jax.tree.map(lambda t: tuple(None if a == "layer" else a for a in t[1]), cache_spec, is_leaf=is_pair)
p_sh = param_shardings(defs, mesh)
c_sh = logical_shardings(cache_abs, cache_log, mesh)
fn = make_decode_step(model)
lowered = jax.jit(fn, in_shardings=(
    p_sh, c_sh,
    logical_shardings(jax.ShapeDtypeStruct((8, 1), jnp.int32), ("dp", None), mesh),
    NamedSharding(mesh, P()),
)).lower(
    abstract_params(defs), cache_abs,
    jax.ShapeDtypeStruct((8, 1), jnp.int32),
    jax.ShapeDtypeStruct((), jnp.int32),
)
compiled = lowered.compile()
print("ok decode")

# roofline extraction on the compiled artifact
from repro.roofline.analysis import analyze_compiled
roof = analyze_compiled(compiled, 8)
assert roof.flops > 0
print("collectives:", sorted(roof.coll_bytes))
print("DONE")
"""


def test_dryrun_machinery_small_mesh():
    """Full dry-run path (lower+compile+roofline) on an 8-device mini mesh
    — subprocess because device count locks at first jax init."""
    r = subprocess.run(
        [sys.executable, "-c", DRYRUN_SMALL],
        capture_output=True, text=True, timeout=1200,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
