"""Contraction-as-a-service tests: the in-process EngineServer.

Covers the serving contract end-to-end against the statevector oracle
(every amplitude a tenant gets back is exact, batched or not), plus the
deterministic group-level behaviours that are racy to assert through the
background dispatcher: amplitude coalescing into one open-qubit batch,
sample-group sharing, backpressure rejection with a retry hint, failure
propagation to every ticket of a failed group, and request validation at
submit time (before a bad request occupies queue capacity).
"""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.engine import (
    AmplitudeRequest,
    EngineServer,
    SampleRequest,
    ServerOverloaded,
    Ticket,
    circuit_fingerprint,
)
from repro.quantum import statevector
from repro.quantum.circuits import random_1d_circuit

CIRC = random_1d_circuit(8, 6, seed=1)
N = CIRC.num_qubits
TD = 10


def _oracle(bits: str) -> complex:
    return complex(statevector.amplitude(CIRC, bits))


def _bits(i: int) -> str:
    return format(i, f"0{N}b")


def _tickets(srv: EngineServer, reqs) -> list[Ticket]:
    """Build normalized tickets without going through the queue — lets a
    test hand one exact group to ``_run_group`` deterministically."""
    out = []
    for i, r in enumerate(reqs):
        srv._normalize(r)
        out.append(Ticket(id=i, request=r, t_submit=time.monotonic()))
    return out


# ----------------------------------------------------------------------
# end-to-end: mixed burst through submit/dispatch, oracle-exact
# ----------------------------------------------------------------------
def test_mixed_burst_oracle_exact():
    bitstrings = [_bits(i) for i in (0, 1, 2, 3, 130)]
    with EngineServer(max_batch=8, max_open=4) as srv:
        amp_tix = [
            srv.submit(AmplitudeRequest(CIRC, bs, target_dim=TD))
            for bs in bitstrings
        ]
        smp_tix = srv.submit(
            SampleRequest(CIRC, num_samples=256, target_dim=TD, seed=3)
        )
        for t in amp_tix:
            t.result(timeout=300)
        res = smp_tix.result(timeout=300)
    for bs, t in zip(bitstrings, amp_tix):
        assert t.status == "done" and t.done()
        np.testing.assert_allclose(t.value, _oracle(bs), atol=1e-6)
        # latency accounting is populated and consistent
        assert t.t_done >= t.t_start >= t.t_submit > 0
        assert t.total_s >= t.compute_s >= 0.0
        assert t.queue_s >= 0.0
        assert t.report is not None
    assert res.num_samples == 256
    assert np.isfinite(res.xeb)
    st = srv.stats()
    assert st["completed"] == len(amp_tix) + 1
    assert st["failed"] == 0 and st["rejected"] == 0
    assert st["queue_depth"] == 0
    assert st["warm_families"] >= 1


def test_warm_family_reuses_plan():
    """A second burst against the same family takes the warm path (the
    plan is cached) and stays oracle-exact."""
    with EngineServer(max_batch=4) as srv:
        srv.submit(
            AmplitudeRequest(CIRC, _bits(0), target_dim=TD)
        ).result(timeout=300)
        assert srv.stats()["warm_families"] == 1
        t = srv.submit(AmplitudeRequest(CIRC, _bits(5), target_dim=TD))
        np.testing.assert_allclose(
            t.result(timeout=300), _oracle(_bits(5)), atol=1e-6
        )
    st = srv.stats()
    assert st["warm_groups"] >= 1 and st["cold_groups"] >= 1


# ----------------------------------------------------------------------
# group-level behaviour (deterministic: one group handed to _run_group)
# ----------------------------------------------------------------------
def test_amplitude_group_coalesces_to_one_batch():
    """Bitstrings differing on <= max_open positions are served from ONE
    open-qubit batch contraction, each tenant exact at its flat index."""
    srv = EngineServer(max_open=3)
    bitstrings = [_bits(0), _bits(1), _bits(4), _bits(5), _bits(5)]
    reqs = [AmplitudeRequest(CIRC, bs, target_dim=TD) for bs in bitstrings]
    ts = _tickets(srv, reqs)
    srv._run_group(srv._family_key(reqs[0]), ts, warm=False)
    for bs, t in zip(bitstrings, ts):
        assert t.status == "done"
        assert t.batched  # answered from the shared contraction
        np.testing.assert_allclose(t.value, _oracle(bs), atol=1e-6)
    st = srv.stats()
    assert st["coalesced"] == len(ts)
    assert st["groups"] == 1 and st["completed"] == len(ts)


def test_amplitude_group_too_spread_falls_back_to_scalar():
    """Bitstrings differing on more than max_open positions cannot share
    a batch: each is served by a scalar contraction, still exact."""
    srv = EngineServer(max_open=2)
    bitstrings = [_bits(0), _bits(0b10101010)]  # differ on 4 positions
    reqs = [AmplitudeRequest(CIRC, bs, target_dim=TD) for bs in bitstrings]
    ts = _tickets(srv, reqs)
    srv._run_group(srv._family_key(reqs[0]), ts, warm=False)
    for bs, t in zip(bitstrings, ts):
        assert t.status == "done" and not t.batched
        np.testing.assert_allclose(t.value, _oracle(bs), atol=1e-6)
    assert srv.stats()["coalesced"] == 0


def test_duplicate_bitstrings_share_one_contraction():
    srv = EngineServer()
    reqs = [
        AmplitudeRequest(CIRC, _bits(7), target_dim=TD) for _ in range(3)
    ]
    ts = _tickets(srv, reqs)
    srv._run_group(srv._family_key(reqs[0]), ts, warm=False)
    vals = {t.value for t in ts}
    assert len(vals) == 1
    assert all(t.batched for t in ts)
    np.testing.assert_allclose(ts[0].value, _oracle(_bits(7)), atol=1e-6)


def test_sample_group_shares_one_contraction():
    """Sampling tenants on one family share the batch contraction and
    differ only in their per-tenant draw."""
    srv = EngineServer()
    reqs = [
        SampleRequest(
            CIRC, num_samples=128, open_qubits=(5, 6, 7),
            target_dim=TD, seed=s,
        )
        for s in (0, 1)
    ]
    ts = _tickets(srv, reqs)
    key = srv._family_key(reqs[0])
    assert key == srv._family_key(reqs[1])  # same family despite seeds
    srv._run_group(key, ts, warm=False)
    for t in ts:
        assert t.status == "done" and t.batched
        assert t.value.num_samples == 128
    # different seeds -> independent draws off the shared batch
    assert srv.stats()["coalesced"] == 2
    # draws land on the open qubits only (base bits fixed at 0)
    for t in ts:
        for s in t.value.bitstrings[:8]:
            assert s[: N - 3] == "0" * (N - 3)


def test_family_key_separates_plans_and_structures():
    srv = EngineServer()
    a = AmplitudeRequest(CIRC, _bits(0), target_dim=TD)
    b = AmplitudeRequest(CIRC, _bits(1), target_dim=TD)
    c = AmplitudeRequest(CIRC, _bits(0), target_dim=TD + 2)
    d = AmplitudeRequest(
        CIRC, _bits(0), target_dim=TD, plan_kwargs={"precision": "bf16"}
    )
    other = random_1d_circuit(8, 6, seed=9)
    e = AmplitudeRequest(other, _bits(0), target_dim=TD)
    assert srv._family_key(a) == srv._family_key(b)
    assert srv._family_key(a) != srv._family_key(c)
    assert srv._family_key(a) != srv._family_key(d)
    assert srv._family_key(a) != srv._family_key(e)
    assert circuit_fingerprint(CIRC) != circuit_fingerprint(other)


# ----------------------------------------------------------------------
# backpressure + failure + validation
# ----------------------------------------------------------------------
def test_backpressure_rejects_with_retry_hint(monkeypatch):
    with EngineServer(max_queue=2, max_batch=1) as srv:
        # warm the family so groups run inline on the dispatch thread
        srv.submit(
            AmplitudeRequest(CIRC, _bits(0), target_dim=TD)
        ).result(timeout=300)
        gate, started = threading.Event(), threading.Event()
        orig = srv._run_group

        def blocked(key, tickets, warm):
            started.set()
            gate.wait(timeout=60)
            orig(key, tickets, warm)

        monkeypatch.setattr(srv, "_run_group", blocked)
        held = srv.submit(AmplitudeRequest(CIRC, _bits(1), target_dim=TD))
        assert started.wait(timeout=60)  # dispatcher is now blocked
        queued = [
            srv.submit(AmplitudeRequest(CIRC, _bits(i), target_dim=TD))
            for i in (2, 3)
        ]
        with pytest.raises(ServerOverloaded) as exc:
            srv.submit(AmplitudeRequest(CIRC, _bits(4), target_dim=TD))
        assert exc.value.retry_after_s > 0
        assert exc.value.depth == 2
        gate.set()
        for t in [held, *queued]:
            t.result(timeout=300)
    assert srv.stats()["rejected"] == 1


def test_group_failure_propagates_to_every_ticket():
    srv = EngineServer()
    reqs = [
        AmplitudeRequest(
            CIRC, _bits(i), target_dim=TD,
            plan_kwargs={"backend": "no-such-backend"},
        )
        for i in (0, 1)
    ]
    ts = _tickets(srv, reqs)
    srv._run_group(srv._family_key(reqs[0]), ts, warm=False)
    for t in ts:
        assert t.status == "failed" and t.done()
        with pytest.raises(Exception):
            t.result(timeout=1)
    assert srv.stats()["failed"] == 2


def test_stop_drains_accepted_tickets():
    srv = EngineServer(max_batch=4)
    srv.start()
    ts = [
        srv.submit(AmplitudeRequest(CIRC, _bits(i), target_dim=TD))
        for i in (0, 1, 2)
    ]
    srv.stop()  # must serve (or fail) everything accepted before return
    for t in ts:
        assert t.done()
        t.result(timeout=1)
    with pytest.raises(RuntimeError):
        srv.submit(AmplitudeRequest(CIRC, _bits(0), target_dim=TD))


def test_submit_validates_before_enqueue():
    with EngineServer() as srv:
        with pytest.raises(ValueError):
            srv.submit(AmplitudeRequest(CIRC, "01"))  # wrong length
        with pytest.raises(ValueError):
            srv.submit(AmplitudeRequest(CIRC, "2" * N))  # bad alphabet
        with pytest.raises(ValueError):
            srv.submit(SampleRequest(CIRC, num_samples=0))
        with pytest.raises(ValueError):
            srv.submit(SampleRequest(CIRC, sampler="bogus"))
        with pytest.raises(ValueError):
            srv.submit(SampleRequest(CIRC, base_bitstring="1"))
        with pytest.raises(TypeError):
            srv.submit("not a request")
        assert srv.stats()["submitted"] == 0
