"""Multi-host slice parallelism: LPT + work-stealing scheduler, elastic
claim store, atomic slice checkpoints, and the contract_multihost driver
(world-size-1 invariance, emulated host failure + epoch resume, and a
real 2-process ``jax.distributed`` gloo run)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_kwargs
from repro.checkpoint.manager import (
    load_slice_checkpoint,
    save_slice_checkpoint,
)
from repro.core import ContractionPlan, simplify_network
from repro.core.distributed import SliceRangeCheckpoint
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices, partition_slice_ids
from repro.distributed import (
    ClaimStore,
    LocalArbiter,
    SliceRange,
    SliceScheduler,
    contract_multihost,
    imbalance,
    lpt_assignment,
    make_ranges,
    simulate,
    uniform_assignment,
)
from repro.optimize.search import per_slice_cost_vector
from repro.quantum.circuits import circuit_to_network, random_1d_circuit


def _ragged_costs(n, heavy_every=8, heavy=7.0):
    """Synthetic ragged per-slice costs: a heavy head region (the shape
    that breaks a contiguous uniform split worst)."""
    c = np.ones(n)
    c[: n // heavy_every] = heavy
    return c


def _missing(n, chunk):
    return SliceRangeCheckpoint(n, set(), 0.0).missing(chunk)


# ----------------------------------------------------------------------
# scheduler unit behavior
# ----------------------------------------------------------------------
class TestScheduler:
    def test_lpt_deterministic_across_runs(self):
        costs = _ragged_costs(64)
        miss = _missing(64, 4)
        for hosts in (1, 2, 3, 4, 7):
            a = lpt_assignment(make_ranges(miss, costs), hosts)
            b = lpt_assignment(make_ranges(miss, costs), hosts)
            assert [[r.key() for r in q] for q in a] == [
                [r.key() for r in q] for q in b
            ]

    def test_steal_order_deterministic(self):
        costs = _ragged_costs(64)
        for hosts in (2, 3, 4):
            sims = []
            for _ in range(2):
                sched = SliceScheduler(
                    _missing(64, 4), hosts, costs, seed=0
                )
                sims.append(
                    simulate(sched, host_speed=[1.0] + [0.5] * (hosts - 1))
                )
            assert sims[0].steal_order == sims[1].steal_order
            assert sims[0].executed == sims[1].executed
            assert sims[0].makespan == sims[1].makespan

    def test_lpt_beats_uniform_imbalance(self):
        costs = _ragged_costs(96)
        miss = _missing(96, 4)
        ranges = make_ranges(miss, costs)
        for hosts in (2, 4, 6):
            lpt = imbalance(lpt_assignment(ranges, hosts))
            uni = imbalance(uniform_assignment(ranges, hosts))
            assert lpt <= uni + 1e-12
            assert lpt < 1.2  # LPT is a 4/3-approximation
        assert imbalance(uniform_assignment(ranges, 4)) > 1.5

    def test_stealing_rebalances_heterogeneous_hosts(self):
        # perfect cost model but one slow host: only stealing can help
        costs = np.ones(64)
        sched = SliceScheduler(_missing(64, 2), 2, costs)
        res = simulate(sched, host_speed=[1.0, 0.25])
        assert res.steal_count > 0
        static = SliceScheduler(_missing(64, 2), 2, costs, policy="uniform")
        # forbid stealing to model the static split
        arb = LocalArbiter()
        clock = [0.0, 0.0]
        for h in (0, 1):
            while True:
                rng = static.next_range(h, arb, steal=False)
                if rng is None:
                    break
                clock[h] += rng.cost / (1.0 if h == 0 else 0.25)
        assert res.makespan < max(clock)

    def test_all_work_executed_exactly_once(self):
        costs = _ragged_costs(50)
        sched = SliceScheduler(_missing(50, 3), 3, costs)
        res = simulate(sched, host_speed=[1.0, 0.6, 0.3])
        seen = sorted(r for host in res.executed for r in host)
        assert seen == sorted(_missing(50, 3))

    def test_uniform_partition_slice_ids(self):
        assert partition_slice_ids(10, 4) == [
            (0, 3), (3, 6), (6, 8), (8, 10)
        ]
        parts = partition_slice_ids(7, 9)
        assert len(parts) == 9
        assert sum(e - s for s, e in parts) == 7


# ----------------------------------------------------------------------
# atomic checkpoint persistence (satellite: temp + fsync + os.replace)
# ----------------------------------------------------------------------
class TestSliceCheckpointPersistence:
    def test_roundtrip(self, tmp_path):
        st = SliceRangeCheckpoint(32, {(0, 4), (10, 12)}, 0.0)
        st.partial = st.partial + np.full((2,), 1 + 2j, np.complex64)
        p = str(tmp_path / "host_0.npz")
        save_slice_checkpoint(p, st)
        back = load_slice_checkpoint(p)
        assert back.n_slices == 32
        assert back._intervals() == st._intervals()
        np.testing.assert_array_equal(back.partial, st.partial)

    def test_scalar_partial_roundtrip(self, tmp_path):
        st = SliceRangeCheckpoint(8, set(), 0.0)
        p = str(tmp_path / "s.npz")
        save_slice_checkpoint(p, st)
        assert load_slice_checkpoint(p).partial == 0.0

    def test_replace_is_atomic_over_existing(self, tmp_path):
        p = str(tmp_path / "host_0.npz")
        good = SliceRangeCheckpoint(16, {(0, 8)}, 0.0)
        save_slice_checkpoint(p, good)
        # a crash mid-save leaves only a temp file; the published
        # checkpoint must still load as the previous complete state
        with open(p + ".tmp.999", "wb") as f:
            f.write(b"truncated garbage")
        back = load_slice_checkpoint(p)
        assert back._intervals() == [(0, 8)]
        # and a subsequent good save replaces cleanly
        good.add_range(8, 16)
        save_slice_checkpoint(p, good)
        assert load_slice_checkpoint(p)._intervals() == [(0, 16)]
        assert os.path.exists(p + ".tmp.999")  # untouched foreign tmp


# ----------------------------------------------------------------------
# elastic claim store
# ----------------------------------------------------------------------
class TestClaimStore:
    def test_claim_exclusive_across_stores(self, tmp_path):
        root = str(tmp_path)
        s0 = ClaimStore(root, 16, host=0)
        s1 = ClaimStore(root, 16, host=1)
        r = SliceRange(0, 4, 4.0, 0)
        assert s0.try_claim(r, 0)
        assert not s1.try_claim(r, 1)  # O_EXCL: exactly one winner
        assert s1.try_claim(SliceRange(4, 8, 4.0, 1), 1)

    def test_merge_unions_hosts(self, tmp_path):
        root = str(tmp_path)
        s0 = ClaimStore(root, 16, host=0)
        s1 = ClaimStore(root, 16, host=1)
        s0.complete(SliceRange(0, 4, 4.0, 0), np.complex64(1 + 1j))
        s1.complete(SliceRange(4, 8, 4.0, 1), np.complex64(2 - 1j))
        m = ClaimStore(root, 16, host=2).merged()
        assert m._intervals() == [(0, 8)]
        assert m.partial == np.complex64(3 + 0j)
        assert m.missing(8) == [(8, 16)]

    def test_stale_claim_reclaim_is_epoch_gated(self, tmp_path):
        root = str(tmp_path)
        dead = ClaimStore(root, 16, host=1, epoch=0)
        # dead host: one completed range, one claim taken to the grave
        assert dead.try_claim(SliceRange(0, 4, 4.0, 1), 1)
        dead.complete(SliceRange(0, 4, 4.0, 1), np.complex64(1j))
        assert dead.try_claim(SliceRange(4, 8, 4.0, 1), 1)
        # a same-epoch peer must NOT reclaim (owner may just be slow)
        peer = ClaimStore(root, 16, host=0, epoch=0)
        assert peer.reclaim_stale() == 0
        assert not peer.try_claim(SliceRange(4, 8, 4.0, 0), 0)
        # a bumped-epoch resume reclaims exactly the unfinished claim
        resumed = ClaimStore(root, 16, host=0, epoch=1)
        assert resumed.reclaim_stale() == 1
        assert resumed.try_claim(SliceRange(4, 8, 4.0, 0), 0)
        # the completed range's claim survives as a record
        assert not resumed.try_claim(SliceRange(0, 4, 4.0, 0), 0)


# ----------------------------------------------------------------------
# driver: world-size-1 invariance + emulated multi-host + failure resume
# ----------------------------------------------------------------------
def _plan(nq=9, depth=6, seed=5, target=4):
    c = random_1d_circuit(nq, depth, seed=seed)
    tn, arrays = circuit_to_network(c, bitstring="0" * nq)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, target, method="lifetime")
    return ContractionPlan(tree, S), arrays, tree


class TestContractMultihost:
    def test_world1_matches_contract_all(self):
        plan, arrays, tree = _plan()
        ref = np.asarray(plan.contract_all(arrays, slice_batch=4))
        res = contract_multihost(plan, arrays, slice_batch=4)
        np.testing.assert_allclose(res.value, ref, atol=1e-6)
        assert res.complete
        assert res.executed_slices == 1 << plan.num_sliced
        assert res.steal_count == 0

    def test_executed_vs_padded_accounting(self):
        # ragged batches: executed counts real ids, padded the masked
        # lanes — they must never be conflated (satellite fix)
        import repro.obs as obs

        plan, arrays, _ = _plan()
        n = 1 << plan.num_sliced
        sb = 3
        assert n % sb != 0
        obs.set_enabled(True)
        try:
            obs.reset()
            res = contract_multihost(plan, arrays, slice_batch=sb)
            snap = obs.telemetry_summary()["metrics"]
        finally:
            obs.set_enabled(False)
            obs.reset()
        assert res.executed_slices == n
        n_ranges = len(res.executed_ranges)
        assert res.padded_slices == n_ranges * sb - n
        assert snap["counters"]["exec.slices_executed"] == n
        assert snap["counters"]["exec.padded_slices"] == res.padded_slices

    def test_emulated_two_hosts_file_transport(self, tmp_path):
        plan, arrays, tree = _plan()
        dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
        root = str(tmp_path / "run")
        costs = per_slice_cost_vector(tree, plan.smask)
        kw = dict(
            slice_batch=2, costs=costs, transport="file",
            checkpoint_dir=root, world_size=2,
        )
        r0 = contract_multihost(plan, arrays, rank=0, **kw)
        # host 0 drained its queue then stole everything host 1 never ran
        assert r0.steal_count > 0
        assert r0.complete
        np.testing.assert_allclose(r0.value, dense, atol=1e-4)
        # host 1 arrives late: all claimed, nothing to do, same value
        r1 = contract_multihost(plan, arrays, rank=1, **kw)
        assert r1.executed_slices == 0
        np.testing.assert_allclose(r1.value, dense, atol=1e-4)

    def test_host_failure_and_epoch_resume(self, tmp_path):
        plan, arrays, tree = _plan()
        dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
        root = str(tmp_path / "run")
        kw = dict(
            slice_batch=2, transport="file", checkpoint_dir=root,
            world_size=2,
        )
        # host 1 executes one range, then dies holding its next claim
        with pytest.raises(RuntimeError, match="simulated host 1"):
            contract_multihost(plan, arrays, rank=1, fail_after=1, **kw)
        # host 0 (same epoch) completes everything it can claim — the
        # dead host's in-flight range stays claimed, so coverage has a
        # hole and the run reports incomplete
        r0 = contract_multihost(plan, arrays, rank=0, **kw)
        assert not r0.complete
        assert r0.state.missing(1)
        # a bumped-epoch resume reclaims the stale claim, executes only
        # the missing ids, and lands on the dense amplitude
        r2 = contract_multihost(
            plan, arrays, rank=0, slice_batch=2, transport="file",
            checkpoint_dir=root, world_size=1, epoch=1,
        )
        assert r2.complete
        missing_before = sum(e - s for s, e in r0.state.missing(1))
        assert r2.executed_slices == missing_before
        np.testing.assert_allclose(r2.value, dense, atol=1e-4)

    def test_report_fields_populated(self):
        from repro.core.api import plan_compiled

        c = random_1d_circuit(9, 6, seed=5)
        tn, arrs = circuit_to_network(c, bitstring="0" * 9)
        tn, arrs = simplify_network(tn, arrs)
        plan2, report = plan_compiled(tn, target_dim=4)
        res = contract_multihost(plan2, arrs, slice_batch=2, report=report)
        assert report.schedule_imbalance == res.schedule_imbalance > 0
        assert report.steal_count == res.steal_count
        assert "sched[" in report.row()


# ----------------------------------------------------------------------
# satellite: replicated hoisted-prologue reuse on the sharded path
# ----------------------------------------------------------------------
REPLICATED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import repro.obs as obs
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.distributed import contract_sharded
from repro.launch.mesh import make_host_mesh

c = random_1d_circuit(10, 8, seed=3)
tn, arrays = circuit_to_network(c, bitstring="0110100101")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 4, method="lifetime")
plan = ContractionPlan(tree, S)
assert plan.can_hoist
mesh = make_host_mesh((8,), ("data",))
arrays = [jax.numpy.asarray(a) for a in arrays]  # stable buffer identity
obs.set_enabled(True)
v1 = contract_sharded(plan, arrays, mesh, hoist=True)
v2 = contract_sharded(plan, arrays, mesh, hoist=True)
snap = obs.telemetry_summary()["metrics"]["counters"]
assert np.allclose(np.asarray(v1), np.asarray(v2))
# first call broadcasts once, second call reuses the placed buffers
assert snap.get("exec.hoist_replicated_put", 0) == 1, snap
assert snap.get("exec.hoist_replicated_reuse", 0) >= 1, snap
print("DONE")
"""


def test_replicated_prologue_reuse_8dev():
    r = subprocess.run(
        [sys.executable, "-c", REPLICATED],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


# ----------------------------------------------------------------------
# real 2-process jax.distributed run (gloo CPU collectives)
# ----------------------------------------------------------------------
MH_WORKER = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
pid = int(sys.argv[1]); port = sys.argv[2]
os.environ["REPRO_COORDINATOR"] = "localhost:" + port
os.environ["REPRO_NUM_PROCESSES"] = "2"
os.environ["REPRO_PROCESS_ID"] = str(pid)
import numpy as np
from repro.distributed import init_multi_host, contract_multihost
rank, size = init_multi_host()
assert size == 2, size
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices

c = random_1d_circuit(9, 6, seed=7)
tn, arrays = circuit_to_network(c, bitstring="011010010")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 4, method="lifetime")
plan = ContractionPlan(tree, S)
single = np.asarray(
    ContractionPlan(tree, S).contract_all(arrays, slice_batch=4)
)
res = contract_multihost(
    plan, arrays, slice_batch=2, reduce_rounds=3, reduce_chunks=2
)
assert np.allclose(np.asarray(res.value), single, atol=1e-4), (
    res.value, single
)
print("COVER" + json.dumps({
    "rank": rank, "n_slices": res.n_slices,
    "ranges": res.executed_ranges,
}))
print(f"rank={rank} MH_OK")
"""


def test_two_process_collective_matches_single():
    """2 plain subprocesses, jax.distributed + gloo psum: the reduced
    amplitude equals the single-process vmapped scan on every rank, and
    the two ranks' slice-id coverage is an exact disjoint partition."""
    import socket

    with socket.socket() as s:
        s.bind(("localhost", 0))
        port = str(s.getsockname()[1])
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", MH_WORKER, str(pid), port],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            **subprocess_kwargs(),
        )
        for pid in (0, 1)
    ]
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=900)
        outs.append((p.returncode, out, err))
    for rc, out, err in outs:
        assert rc == 0 and "MH_OK" in out, out + "\n" + err[-3000:]
    cover = {}
    n_slices = None
    for _, out, _ in outs:
        line = next(l for l in out.splitlines() if l.startswith("COVER"))
        rec = json.loads(line[len("COVER"):])
        cover[rec["rank"]] = rec["ranges"]
        n_slices = rec["n_slices"]
    ids0 = {i for s, e in cover[0] for i in range(s, e)}
    ids1 = {i for s, e in cover[1] for i in range(s, e)}
    assert ids0.isdisjoint(ids1)
    assert ids0 | ids1 == set(range(n_slices))
