"""End-to-end training behaviour: loss decreases, checkpoint/resume is
exact, optimizer + data + compression substrate invariants."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticTextDataset
from repro.train import optimizer as opt
from repro.train.grad_compress import (
    compress_with_feedback,
    dequantize,
    init_residuals,
    quantize,
)


def test_loss_decreases_small_lm():
    from repro.launch.train import train

    # 80 steps, not 60: with these deterministic seeds the loss drop at 60
    # steps is 0.094 — under the 0.1 bar (the test predates a working
    # collection and had never actually run); at 80 the drop is ~0.19.
    losses = train(
        "llama3.2-3b", steps=80, smoke=True, global_batch=4, seq_len=32,
        lr=5e-3,
    )
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_checkpoint_resume_exact(tmp_path):
    from repro.launch.train import train

    d1 = str(tmp_path / "a")
    # run 10 steps straight (schedule pinned to 10 in all runs)
    l_full = train("llama3.2-3b", steps=10, global_batch=2, seq_len=16,
                   ckpt_dir=None, lr=1e-3, schedule_steps=10)
    # run 5, checkpoint, resume to 10
    l_a = train("llama3.2-3b", steps=5, global_batch=2, seq_len=16,
                ckpt_dir=d1, ckpt_every=5, lr=1e-3, schedule_steps=10)
    l_b = train("llama3.2-3b", steps=10, global_batch=2, seq_len=16,
                ckpt_dir=d1, ckpt_every=5, lr=1e-3, schedule_steps=10)
    np.testing.assert_allclose(l_b[-1], l_full[-1], rtol=1e-4)


# ------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = opt.OptimizerConfig(learning_rate=0.1, warmup_steps=0,
                              total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_int8_moments_still_converge():
    cfg = opt.OptimizerConfig(learning_rate=0.1, warmup_steps=0,
                              total_steps=200, weight_decay=0.0,
                              moment_dtype="int8")
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_schedule_warmup_cosine():
    cfg = opt.OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                              total_steps=100, min_lr_ratio=0.1)
    assert float(opt.schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(opt.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(opt.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


# ------------------------------------------------------------------ data
def test_data_deterministic_and_resumable():
    ds = SyntheticTextDataset(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    b1 = ds.batch(7)
    ds2, step = SyntheticTextDataset.from_state(
        ds.state_dict(7), vocab_size=100, seq_len=8, global_batch=4
    )
    b2 = ds2.batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])


def test_data_host_slice():
    ds = SyntheticTextDataset(vocab_size=100, seq_len=8, global_batch=8)
    full = ds.batch(0)
    half = ds.batch(0, host_slice=slice(0, 4))
    np.testing.assert_array_equal(full["tokens"][:4], half["tokens"])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(6).reshape(2, 3), "b": {"c": np.float32(1.5)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, blocking=True)
    assert mgr.steps() == [2, 3]
    out = mgr.restore(jax.tree.map(np.zeros_like, tree))
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert float(out["b"]["c"]) == 1.5


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": np.ones((128, 128))}
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


# ------------------------------------------------------- grad compression
def test_quantize_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the *cumulative* compressed sum tracks the
    cumulative true sum (EF-SGD guarantee)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 1e-3
    grads = {"w": g_true}
    res = init_residuals(grads)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        q, s, res = compress_with_feedback(grads, res)
        acc = acc + dequantize(q["w"], s["w"])
    total_true = 50 * g_true
    rel = float(jnp.linalg.norm(acc - total_true) / jnp.linalg.norm(total_true))
    assert rel < 0.05
