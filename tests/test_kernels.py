"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.contract_gemm import tiled_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import ssd_intra_chunk

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------ GEMM
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (384, 256, 128), (512, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_matmul_shapes(m, k, n, dtype):
    a = jnp.asarray(RNG.normal(size=(m, k)), dtype)
    b = jnp.asarray(RNG.normal(size=(k, n)), dtype)
    out = tiled_matmul(a, b, bm=128, bn=128, bk=128, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,k,n", [(100, 60, 130), (1, 128, 128), (37, 41, 53)])
def test_matmul_padding_path(m, k, n):
    a = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    out = ops.matmul(a, b, min_kernel_dim=1)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.matmul_ref(a, b)), rtol=1e-5, atol=1e-4
    )


def test_complex_karatsuba_matmul():
    a = RNG.normal(size=(130, 140)) + 1j * RNG.normal(size=(130, 140))
    b = RNG.normal(size=(140, 150)) + 1j * RNG.normal(size=(140, 150))
    a, b = jnp.asarray(a, jnp.complex64), jnp.asarray(b, jnp.complex64)
    out = ops.matmul(a, b, min_kernel_dim=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("sq,sk,h,hkv,d", [
    (256, 256, 4, 4, 64),
    (256, 256, 8, 2, 64),   # GQA
    (128, 512, 4, 1, 32),   # MQA decode-ish chunk with offset
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(sq, sk, h, hkv, d, causal):
    q = jnp.asarray(RNG.normal(size=(2, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, sk, hkv, d)), jnp.float32)
    off = sk - sq if causal and sk > sq else 0
    out = ops.attention(q, k, v, causal=causal, q_offset=off, bq=128, bk=128)
    want = ops.attention(q, k, v, causal=causal, q_offset=off,
                         use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    # kernel layout: (batch·heads, seq, head_dim)
    q = jnp.asarray(RNG.normal(size=(16, 128, 32)), dtype)
    k = jnp.asarray(RNG.normal(size=(16, 128, 32)), dtype)
    v = jnp.asarray(RNG.normal(size=(16, 128, 32)), dtype)
    out = flash_attention(q, k, v, bq=128, bk=128, causal=True,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_blockwise_attention_matches_ref():
    from repro.models.layers import blockwise_attention

    q = jnp.asarray(RNG.normal(size=(2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 256, 2, 32)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ops.attention(q, k, v, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_blockwise_attention_window():
    from repro.models.layers import blockwise_attention

    q = jnp.asarray(RNG.normal(size=(1, 256, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 256, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 256, 2, 16)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, window=64, bq=64, bk=64)
    # reference with explicit banded mask
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / 4.0
    qp = jnp.arange(256)[:, None]
    kp = jnp.arange(256)[None, :]
    mask = (qp >= kp) & (kp > qp - 64)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ SSD
@pytest.mark.parametrize("T,D,S,chunk", [(64, 16, 8, 16), (128, 32, 16, 32),
                                         (96, 8, 4, 32)])
def test_ssd_kernel_sweep(T, D, S, chunk):
    BH = 3
    x = jnp.asarray(RNG.normal(size=(BH, T, D)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, size=(BH, T)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.01, 0.5, size=(BH, T)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(BH, T, S)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(BH, T, S)), jnp.float32)
    y, h = ops.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_scan_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_with_initial_state():
    BH, T, D, S = 2, 64, 8, 4
    x = jnp.asarray(RNG.normal(size=(BH, T, D)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, size=(BH, T)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.01, 0.5, size=(BH, T)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(BH, T, S)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(BH, T, S)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(BH, S, D)), jnp.float32)
    y, h = ops.ssd_scan(x, dt, a, b, c, chunk=16, state0=h0, interpret=True)
    y_ref, h_ref = ref.ssd_scan_ref(x, dt, a, b, c, state0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_decode_consistency():
    """Chunked prefill then step-by-step ref decode continues the state."""
    BH, T, D, S = 2, 32, 8, 4
    x = jnp.asarray(RNG.normal(size=(BH, T + 4, D)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 1.0, size=(BH, T + 4)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.01, 0.5, size=(BH, T + 4)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(BH, T + 4, S)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(BH, T + 4, S)), jnp.float32)
    y_full, h_full = ref.ssd_scan_ref(x, dt, a, b, c)
    _, h_pre = ops.ssd_scan(x[:, :T], dt[:, :T], a[:, :T], b[:, :T],
                            c[:, :T], chunk=16, interpret=True)
    y_inc, h_inc = ref.ssd_scan_ref(
        x[:, T:], dt[:, T:], a[:, T:], b[:, T:], c[:, T:], state0=h_pre
    )
    np.testing.assert_allclose(np.asarray(h_inc), np.asarray(h_full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(y_inc), np.asarray(y_full[:, T:]),
                               rtol=2e-3, atol=2e-3)
