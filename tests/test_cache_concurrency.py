"""Threaded regression tests for the plan cache's single-flight path.

The serving engine dispatches groups on background threads, so the
process-global plan cache sees concurrent traffic: N tenants hitting a
new circuit family at once must cost ONE planning run (single-flight),
hits must stay safe under simultaneous eviction, and a leader whose
planning run raises must not wedge the key for everyone behind it.
These tests hammer :meth:`repro.lowering.cache.PlanCache.single_flight`
directly with barrier-released threads, then once through the real
``plan_compiled`` path.
"""

from __future__ import annotations

import threading
import time

import pytest

from repro.lowering.cache import HoistCache, PlanCache, PlanEntry


def _hammer(n_threads: int, fn):
    """Release ``n_threads`` through a barrier into ``fn(i)``; re-raise
    the first worker exception in the test thread."""
    barrier = threading.Barrier(n_threads)
    results: list = [None] * n_threads
    errors: list = []

    def work(i):
        try:
            barrier.wait()
            results[i] = fn(i)
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [
        threading.Thread(target=work, args=(i,)) for i in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results


def test_single_flight_one_factory_run():
    cache = PlanCache(maxsize=8)
    calls = []

    def factory():
        calls.append(threading.get_ident())
        time.sleep(0.05)  # widen the in-flight window
        return PlanEntry(plan="the-plan", report=None)

    results = _hammer(16, lambda i: cache.single_flight("fam", factory))
    assert len(calls) == 1  # one leader planned; 15 waiters were served
    assert all(r is results[0] for r in results)
    assert cache.misses == 1 and cache.hits == 15
    assert cache.single_flight("fam", factory) is results[0]
    assert len(calls) == 1


def test_single_flight_distinct_keys_run_concurrently():
    """Leaders for different families must not serialize on each other:
    the factory runs outside the cache lock."""
    cache = PlanCache(maxsize=8)
    inside = threading.Barrier(4, timeout=30)

    def factory():
        inside.wait()  # only passes if all 4 leaders are inside at once
        return PlanEntry(plan=object(), report=None)

    results = _hammer(
        4, lambda i: cache.single_flight(f"fam-{i}", factory)
    )
    assert len({id(r) for r in results}) == 4
    assert cache.misses == 4


def test_single_flight_leader_failure_promotes_waiter():
    cache = PlanCache(maxsize=8)
    attempts = []

    def factory():
        attempts.append(None)
        time.sleep(0.02)
        if len(attempts) == 1:
            raise RuntimeError("transient planning failure")
        return PlanEntry(plan="recovered", report=None)

    def req(i):
        try:
            return cache.single_flight("fam", factory)
        except RuntimeError:
            return None  # the failed leader's own exception propagates

    results = _hammer(8, req)
    ok = [r for r in results if r is not None]
    assert results.count(None) == 1  # exactly the failed leader
    assert len(ok) == 7 and all(r.plan == "recovered" for r in ok)
    assert len(attempts) == 2  # failure + one retry, not a stampede
    # key is not wedged afterwards
    assert cache.single_flight("fam", factory).plan == "recovered"


def test_hits_safe_under_concurrent_eviction():
    """Readers churning one key while writers overflow the LRU: every
    read returns either a valid entry or triggers exactly one rebuild —
    never a torn/None result or a crash."""
    cache = PlanCache(maxsize=2)
    stop = threading.Event()

    def churn(i):
        if i < 2:  # writers: force evictions of everything else
            k = 0
            while not stop.is_set():
                cache.put(f"w{i}-{k % 8}", PlanEntry(plan=k, report=None))
                k += 1
            return None
        out = []
        for _ in range(300):
            ent = cache.single_flight(
                "hot", lambda: PlanEntry(plan="hot", report=None)
            )
            out.append(ent.plan)
        if i == 2:
            stop.set()
        return out

    results = _hammer(6, churn)
    for r in results[2:]:
        assert r is not None and all(p == "hot" for p in r)
    assert len(cache) <= 2


def test_hoist_cache_single_flight_byte_accounting():
    """HoistCache inherits single_flight; its put() must keep the byte
    ledger consistent under threaded inserts + evictions."""
    import numpy as np

    cache = HoistCache(maxsize=4, max_bytes=4 * 800)

    def factory(i):
        return ([np.zeros(100, np.float64)], (), {})  # 800 bytes

    _hammer(12, lambda i: cache.single_flight(f"k{i % 6}", lambda: factory(i)))
    st = cache.stats()
    assert st["size"] <= 4
    assert st["total_bytes"] == st["size"] * 800
    assert st["total_bytes"] <= cache.max_bytes


def test_plan_compiled_threaded_single_flight():
    """End-to-end: N threads requesting the same new family through
    ``plan_compiled`` produce one miss, N-1 hits, and the same live plan
    object (shared jit memoization)."""
    from repro.core.api import plan_compiled
    from repro.core.executor import simplify_network
    from repro.lowering.cache import PLAN_CACHE
    from repro.quantum.circuits import circuit_to_network, random_1d_circuit

    c = random_1d_circuit(8, 6, seed=11)
    tn, arrays = circuit_to_network(c, bitstring="0" * 8)
    tn, arrays = simplify_network(tn, arrays)
    h0, m0 = PLAN_CACHE.hits, PLAN_CACHE.misses

    results = _hammer(8, lambda i: plan_compiled(tn, 10))
    plans = {id(p) for p, _ in results}
    assert len(plans) == 1  # everyone shares the one planned artifact
    assert PLAN_CACHE.misses == m0 + 1
    assert PLAN_CACHE.hits == h0 + 7
    reports = [r for _, r in results]
    assert sum(1 for r in reports if not r.cache_hit) == 1
    assert sum(1 for r in reports if r.cache_hit) == 7
