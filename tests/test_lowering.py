"""GEMM lowering subsystem: normalization/refiner equivalence vs einsum,
end-to-end backend agreement, schedule execution under shard_map, and the
compiled-plan cache contract."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import subprocess_kwargs
from repro.core import (
    ContractionPlan,
    default_backend,
    simplify_network,
    simulate_amplitude,
)
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.lowering import (
    GemmSpec,
    lower_step,
    refine_schedule,
    refine_step,
)
from repro.lowering import gemm_form
from repro.lowering.cache import PLAN_CACHE, PlanCache, network_fingerprint
from repro.quantum import statevector
from repro.quantum.circuits import circuit_to_network, random_1d_circuit

RNG = np.random.default_rng(0)


def _arrays_for(inds_a, inds_b, sizes, dtype):
    sa = tuple(sizes[ix] for ix in inds_a)
    sb = tuple(sizes[ix] for ix in inds_b)
    a = RNG.normal(size=sa)
    b = RNG.normal(size=sb)
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        a = a + 1j * RNG.normal(size=sa)
        b = b + 1j * RNG.normal(size=sb)
    return a.astype(dtype), b.astype(dtype)


def _check_equivalent(inds_a, inds_b, inds_out, sizes, dtype, spec=None,
                      tol=1e-4):
    form = lower_step(inds_a, inds_b, inds_out, sizes.__getitem__)
    if spec is None:
        spec = refine_step(form, dtype)
    else:
        spec = GemmSpec(form, spec, 128, 128, 128, 0.0, 0.0)
    a, b = _arrays_for(inds_a, inds_b, sizes, dtype)
    want = np.einsum(form.expr, a, b)
    got = np.asarray(gemm_form.apply(spec, jnp.asarray(a), jnp.asarray(b)))
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=0, atol=tol * scale)
    return spec


# ------------------------------------------------------- normalization
def test_index_classification():
    sizes = dict(b=2, m1=2, m2=3, n1=4, k1=2, k2=5)
    form = lower_step(
        ("b", "m1", "k1", "m2", "k2"),
        ("k2", "b", "n1", "k1"),
        ("b", "m1", "m2", "n1"),
        sizes.__getitem__,
    )
    assert form.batch_inds == ("b",)
    assert form.m_inds == ("m1", "m2")
    assert form.n_inds == ("n1",)
    assert form.k_inds == ("k1", "k2")
    assert (form.B, form.M, form.N, form.K) == (2, 6, 4, 10)
    assert form.flops == 2.0 * 2 * 6 * 4 * 10


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize(
    "inds_a,inds_b,inds_out,sizes",
    [
        # plain MxK @ KxN
        (("m", "k"), ("k", "n"), ("m", "n"), dict(m=4, k=8, n=4)),
        # batch (open sampling index shared by both operands)
        (("b", "m", "k"), ("k", "b", "n"), ("b", "m", "n"),
         dict(b=2, m=3, k=4, n=5)),
        # outer product: no contracted index (K = 1)
        (("m1", "m2"), ("n1",), ("m1", "m2", "n1"), dict(m1=2, m2=3, n1=4)),
        # full reduction to a scalar
        (("k1", "k2"), ("k2", "k1"), (), dict(k1=3, k2=4)),
        # interleaved output order (exercises out_perm)
        (("m", "k", "b"), ("n", "b", "k"), ("m", "b", "n"),
         dict(m=3, k=4, b=2, n=5)),
        # rank-0 operand against a matrix
        ((), ("n1", "n2"), ("n1", "n2"), dict(n1=2, n2=3)),
    ],
)
def test_lowered_step_matches_einsum(inds_a, inds_b, inds_out, sizes, dtype):
    _check_equivalent(inds_a, inds_b, inds_out, sizes, dtype)


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
@pytest.mark.parametrize("backend", ["dot", "einsum"])
def test_forced_backends_match_einsum(dtype, backend):
    sizes = dict(b=2, m1=5, m2=7, n=33, k1=4, k2=9)
    _check_equivalent(
        ("b", "m1", "k1", "m2", "k2"), ("k2", "b", "n", "k1"),
        ("b", "m1", "m2", "n"), sizes, dtype, spec=backend,
    )


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_pallas_backend_non_aligned(dtype):
    """Non-tile-aligned MXU-sized GEMM → Pallas with padding (+ Karatsuba
    for complex), interpret mode on CPU."""
    sizes = dict(m=130, k=140, n=150)
    spec = _check_equivalent(
        ("m", "k"), ("k", "n"), ("m", "n"), sizes, dtype, tol=1e-5
    )
    assert spec.backend == "pallas"
    assert spec.bm % 128 == 0 and spec.bn % 128 == 0 and spec.bk % 128 == 0
    assert 0.0 < spec.pad_waste < 1.0


def test_pallas_step_under_vmap():
    """The refined Pallas step must run inside the executor's slice-batch
    vmap."""
    sizes = dict(m=130, k=140, n=150)
    form = lower_step(("m", "k"), ("k", "n"), ("m", "n"), sizes.__getitem__)
    spec = refine_step(form, np.complex64)
    assert spec.backend == "pallas"
    a, b = _arrays_for(("m", "k"), ("k", "n"), sizes, np.complex64)
    va = jnp.stack([jnp.asarray(a), 2.0 * jnp.asarray(a)])
    vb = jnp.stack([jnp.asarray(b), jnp.asarray(b)])
    got = jax.vmap(lambda x, y: gemm_form.apply(spec, x, y))(va, vb)
    np.testing.assert_allclose(
        np.asarray(got[1]), 2.0 * (a @ b), rtol=0,
        atol=1e-5 * np.abs(a @ b).max(),
    )


def test_pallas_spec_adapts_to_64bit_arrays():
    """A schedule refined for complex64 handed complex128 arrays at
    runtime must not silently truncate through the fp32 Pallas path."""
    jax.config.update("jax_enable_x64", True)
    try:
        sizes = dict(m=130, k=140, n=150)
        form = lower_step(("m", "k"), ("k", "n"), ("m", "n"),
                          sizes.__getitem__)
        spec = refine_step(form, np.complex64)
        assert spec.backend == "pallas"
        a, b = _arrays_for(("m", "k"), ("k", "n"), sizes, np.complex128)
        got = np.asarray(
            gemm_form.apply(spec, jnp.asarray(a), jnp.asarray(b))
        )
        assert got.dtype == np.complex128
        np.testing.assert_allclose(got, a @ b, rtol=0,
                                   atol=1e-10 * np.abs(a @ b).max())
    finally:
        jax.config.update("jax_enable_x64", False)


def test_refiner_routes_64bit_off_pallas():
    sizes = dict(m=256, k=256, n=256)
    form = lower_step(("m", "k"), ("k", "n"), ("m", "n"), sizes.__getitem__)
    assert refine_step(form, np.float32).backend == "pallas"
    assert refine_step(form, np.float64).backend == "dot"
    assert refine_step(form, np.complex128).backend == "dot"


@given(
    seed=st.integers(0, 10_000),
    nb=st.integers(0, 2),
    nm=st.integers(0, 2),
    nn=st.integers(0, 2),
    nk=st.integers(0, 2),
    complex_=st.booleans(),
)
@settings(max_examples=40)
def test_lowering_property(seed, nb, nm, nn, nk, complex_):
    """Random pairwise contractions (random role counts, sizes 1..5,
    shuffled axis orders, complex + real dtypes) — lowered GEMM path ==
    einsum."""
    rng = np.random.default_rng(seed)
    batch = [f"b{i}" for i in range(nb)]
    ms = [f"m{i}" for i in range(nm)]
    ns = [f"n{i}" for i in range(nn)]
    ks = [f"k{i}" for i in range(nk)]
    sizes = {ix: int(rng.integers(1, 6)) for ix in batch + ms + ns + ks}
    inds_a = batch + ms + ks
    inds_b = batch + ks + ns
    rng.shuffle(inds_a)
    rng.shuffle(inds_b)
    from repro.core.executor import pair_contract_inds

    _, inds_out = pair_contract_inds(
        tuple(inds_a), tuple(inds_b), frozenset(batch)
    )
    dtype = np.complex64 if complex_ else np.float32
    _check_equivalent(tuple(inds_a), tuple(inds_b), inds_out, sizes, dtype)


# ------------------------------------------------------- schedule + e2e
def test_refine_schedule_summary():
    sizes = dict(m=130, k=140, n=150, p=8)
    sched = refine_schedule(
        [
            (("m", "k"), ("k", "n"), ("m", "n")),
            (("m", "p"), ("p",), ("m",)),
        ],
        sizes.__getitem__,
        dtype=np.complex64,
    )
    s = sched.summary()
    assert s["nodes"] == 2
    assert s["backends"]["pallas"] == 1
    assert s["backends"]["einsum"] == 1
    assert 0.0 < s["pad_waste"] < 1.0
    assert sched.modeled_time_s > 0
    assert "pallas=1" in sched.summary_row()


def test_simulate_backend_agreement():
    """simulate(backend='gemm') == simulate(backend='einsum') == oracle,
    sliced + vmapped slice batching included."""
    c = random_1d_circuit(9, 7, seed=11)
    bs = "011010010"
    ref = statevector.amplitude(c, bs)
    r_e = simulate_amplitude(c, bs, target_dim=4, backend="einsum",
                             use_cache=False)
    r_g = simulate_amplitude(c, bs, target_dim=4, backend="gemm",
                             use_cache=False)
    assert r_g.report.backend == "gemm"
    assert r_g.report.num_sliced > 0  # vmapped slice batching exercised
    assert r_g.plan is not None and r_g.plan.schedule is not None
    assert sum(r_g.plan.schedule.backend_counts().values()) == len(
        r_g.plan.schedule.specs
    )
    assert abs(complex(r_g.value) - complex(r_e.value)) < 1e-5
    assert abs(complex(r_g.value) - ref) < 1e-4
    assert "backend=gemm" in r_g.report.row()


def test_gemm_plan_dense_and_sliced_agree():
    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = circuit_to_network(c, bitstring="0110100101")
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    S = find_slices(tree, 4, method="lifetime")
    v = np.asarray(
        ContractionPlan(tree, S, backend="gemm").contract_all(
            arrays, slice_batch=4
        )
    )
    np.testing.assert_allclose(v, dense, rtol=1e-4, atol=1e-5)


SHARDED_GEMM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.distributed import contract_sharded
from repro.launch.mesh import make_host_mesh

c = random_1d_circuit(10, 8, seed=3)
tn, arrays = circuit_to_network(c, bitstring="0110100101")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 4, method="lifetime")
dense = ContractionPlan(tree, 0).contract_all(arrays)
plan = ContractionPlan(tree, S, backend="gemm")
assert plan.schedule is not None
mesh = make_host_mesh((4,), ("data",))
v = contract_sharded(plan, arrays, mesh, axis_names=("data",), slice_batch=2)
assert np.allclose(np.asarray(v), np.asarray(dense), atol=1e-5)
# second call reuses the memoized shard_map program
v2 = contract_sharded(plan, arrays, mesh, axis_names=("data",), slice_batch=2)
assert np.allclose(np.asarray(v2), np.asarray(dense), atol=1e-5)
assert any(k[0] == "sharded" for k in plan._compiled)
print("DONE")
"""


def test_contract_sharded_gemm_schedule():
    """The lowered schedule threads through shard_map unchanged."""
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_GEMM],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


def test_sampling_backend_agreement():
    from repro.core import sample_bitstrings

    c = random_1d_circuit(8, 6, seed=5)
    r_e = sample_bitstrings(c, num_samples=32, open_qubits=(5, 6, 7),
                            target_dim=5, backend="einsum", use_cache=False)
    r_g = sample_bitstrings(c, num_samples=32, open_qubits=(5, 6, 7),
                            target_dim=5, backend="gemm", use_cache=False)
    np.testing.assert_allclose(
        r_g.batch.amplitudes, r_e.batch.amplitudes, rtol=0, atol=1e-5
    )
    assert r_g.report.backend == "gemm"


# ------------------------------------------------------------- caching
def test_fingerprint_relabel_invariance():
    from repro.core import TensorNetwork

    tn1 = TensorNetwork([("a", "b"), ("b", "c")], open_inds=("c",))
    tn2 = TensorNetwork([("x", "y"), ("y", "z")], open_inds=("z",))
    tn3 = TensorNetwork([("a", "b"), ("b", "c")], open_inds=())
    assert network_fingerprint(tn1, "complex64") == network_fingerprint(
        tn2, "complex64"
    )
    assert network_fingerprint(tn1, "complex64") != network_fingerprint(
        tn3, "complex64"
    )
    assert network_fingerprint(tn1, "complex64") != network_fingerprint(
        tn1, "float32"
    )
    assert network_fingerprint(tn1, "complex64", extra=("gemm",)) != (
        network_fingerprint(tn1, "complex64", extra=("einsum",))
    )


def test_plan_cache_hit_miss():
    """Repeated simulate on the same circuit: first call misses, second
    hits, plan wall time drops, and the identical plan object is reused."""
    PLAN_CACHE.clear()
    c = random_1d_circuit(9, 7, seed=23)
    bs1, bs2 = "010110100", "111000101"
    r1 = simulate_amplitude(c, bs1, target_dim=4, backend="gemm")
    assert not r1.report.cache_hit
    assert r1.report.cache_misses >= 1
    # different bitstring, same structure → still a hit
    r2 = simulate_amplitude(c, bs2, target_dim=4, backend="gemm")
    assert r2.report.cache_hit
    assert r2.report.cache_hits >= 1
    assert r2.plan is r1.plan
    assert r2.report.plan_wall_s < r1.report.plan_wall_s
    # cached plan still yields correct values
    ref = statevector.amplitude(c, bs2)
    assert abs(complex(r2.value) - ref) < 1e-4
    # backend is part of the key: einsum request must not reuse gemm plan
    r3 = simulate_amplitude(c, bs1, target_dim=4, backend="einsum")
    assert not r3.report.cache_hit
    # opting out bypasses the cache entirely
    r4 = simulate_amplitude(c, bs1, target_dim=4, backend="gemm",
                            use_cache=False)
    assert not r4.report.cache_hit


def test_plan_cache_lru_eviction():
    cache = PlanCache(maxsize=2)
    cache.put("a", "A")
    cache.put("b", "B")
    assert cache.get("a").__class__ is str  # touch a → b becomes LRU
    cache.put("c", "C")
    assert cache.get("b") is None
    assert len(cache) == 2
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


# ---------------------------------------------------------- satellites
def test_kernels_package_root_exports():
    from repro.kernels import (  # noqa: F401
        attention,
        flash_attention,
        matmul,
        ssd_intra_chunk,
        ssd_scan,
        tiled_matmul,
    )


def test_default_backend_env(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert default_backend() == "einsum"
    monkeypatch.setenv("REPRO_BACKEND", "gemm")
    assert default_backend() == "gemm"
    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError):
        default_backend()
