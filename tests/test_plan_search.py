"""Anytime path–slice co-optimizer (repro.optimize.plan_search):
determinism, the anytime-monotone contract, budget enforcement, and
execution equivalence with the one-shot pipeline."""

import math

import numpy as np
import pytest

from conftest import random_closed_network
from repro.core import ContractionPlan, simplify_network
from repro.core.api import plan_contraction, simulate_amplitude
from repro.core.tensor_network import popcount
from repro.lowering.memory import certified_peak
from repro.optimize import oneshot_plan, plan_search
from repro.quantum.circuits import circuit_to_network, random_1d_circuit

TARGET = 8


def _tn(n=30, seed=2):
    return random_closed_network(n, 3, seed)


# ----------------------------------------------------------------------
# determinism + anytime contract
# ----------------------------------------------------------------------
@pytest.mark.parametrize("workers", [1, 2, 4])
def test_seeded_determinism(workers):
    tn = _tn()
    a = plan_search(tn, TARGET, max_evals=24, num_workers=workers, seed=3)
    b = plan_search(tn, TARGET, max_evals=24, num_workers=workers, seed=3)
    assert a.smask == b.smask
    assert a.objective == b.objective
    assert a.evaluations == b.evaluations
    assert [t.objective for t in a.trace] == [t.objective for t in b.trace]
    assert a.tree.total_cost() == b.tree.total_cost()
    assert sorted(a.tree.emask.items()) == sorted(b.tree.emask.items())


def test_anytime_monotone_trace():
    tn = _tn(34, 7)
    res = plan_search(tn, TARGET, max_evals=48, num_workers=4, seed=1)
    objs = [t.objective for t in res.trace]
    assert objs, "search must record at least the seed"
    assert objs == sorted(objs, reverse=True)
    assert len(set(objs)) == len(objs), "best-so-far must strictly improve"
    assert res.objective == objs[-1]
    # a longer run of the same seeded search never ends worse
    longer = plan_search(tn, TARGET, max_evals=96, num_workers=4, seed=1)
    assert longer.objective <= res.objective


def test_budgets_respected():
    tn = _tn(28, 5)
    res = plan_search(tn, TARGET, max_evals=17, num_workers=3, seed=0)
    assert res.evaluations <= 17
    assert res.feasible
    assert res.peak_bytes <= res.budget_bytes
    # the returned pair re-certifies against the returned budget
    assert certified_peak(res.tree, res.smask, 8) <= res.budget_bytes
    res.tree.check_valid()
    # an explicit (tight) budget is enforced on the result too
    tight = plan_search(
        tn, TARGET, max_evals=17, num_workers=3, seed=0,
        budget_bytes=res.budget_bytes,
    )
    assert tight.peak_bytes <= res.budget_bytes


def test_matches_or_beats_oneshot_at_equal_budget():
    """The acceptance claim: seeded with the one-shot pipeline, the
    co-optimizer never returns a worse hoist-aware executed-FLOPs
    objective under the same certified-peak budget."""
    for seed in range(4):
        tn = _tn(30, seed)
        res = plan_search(tn, TARGET, max_evals=32, num_workers=4, seed=seed)
        assert res.baseline_objective is not None
        assert res.objective <= res.baseline_objective * (1 + 1e-12)
        assert res.improvement >= 1.0


# ----------------------------------------------------------------------
# execution equivalence
# ----------------------------------------------------------------------
def test_evals_1_returns_oneshot_exactly_bitwise():
    """With a single evaluation the search returns the one-shot seed
    unchanged, so the two plans contract bitwise-equal amplitudes."""
    c = random_1d_circuit(9, 6, seed=5)
    tn, arrays = circuit_to_network(c, bitstring="011010010")
    tn, arrays = simplify_network(tn, arrays)
    res = plan_search(tn, 6, max_evals=1, num_workers=1, seed=0,
                      slicing_mode="width")
    shot = oneshot_plan(tn, 6, seed=0, slicing_mode="width")
    assert res.smask == shot.smask
    assert sorted(res.tree.children.items()) == sorted(
        shot.tree.children.items()
    )
    v_search = np.asarray(
        ContractionPlan(res.tree, res.smask).contract_all(arrays)
    )
    v_shot = np.asarray(
        ContractionPlan(shot.tree, shot.smask).contract_all(arrays)
    )
    np.testing.assert_array_equal(v_search, v_shot)


def test_searched_plan_contracts_correct_amplitude():
    c = random_1d_circuit(9, 6, seed=5)
    tn, arrays = circuit_to_network(c, bitstring="011010010")
    tn, arrays = simplify_network(tn, arrays)
    res = plan_search(tn, 5, max_evals=24, num_workers=2, seed=4)
    res.tree.check_valid()
    val = np.asarray(
        ContractionPlan(res.tree, res.smask).contract_all(arrays)
    )
    shot = oneshot_plan(tn, 5, seed=4)
    ref = np.asarray(ContractionPlan(shot.tree, 0).contract_all(arrays))
    np.testing.assert_allclose(val, ref, atol=1e-5)


# ----------------------------------------------------------------------
# API integration (the CI smoke entry point: both backends via
# REPRO_BACKEND, tiny evaluation budget)
# ----------------------------------------------------------------------
def test_plan_search_smoke():
    c = random_1d_circuit(8, 6, seed=7)
    bits = "0" * 8
    one = simulate_amplitude(c, bits, target_dim=6, use_cache=False)
    res = simulate_amplitude(
        c, bits, target_dim=6, use_cache=False,
        optimize="anytime", search_evals=8, search_workers=2,
    )
    np.testing.assert_allclose(
        np.asarray(res.value), np.asarray(one.value), atol=1e-5
    )
    assert res.report.optimize == "anytime"
    assert 0 < res.report.search_evals <= 8
    assert res.report.search_trace
    first = res.report.search_trace[0]
    assert {"evaluation", "objective", "num_sliced", "peak_bytes"} <= set(
        first
    )


def test_plan_contraction_anytime_report():
    tn = _tn(24, 9)
    tree, smask, report = plan_contraction(
        tn, TARGET, optimize="anytime", search_evals=12, search_workers=2
    )
    assert report.optimize == "anytime"
    assert report.search_evals <= 12
    assert tree.sliced_width(smask) <= TARGET or popcount(smask) == 0
    assert "opt=anytime" in report.row()
    with pytest.raises(ValueError):
        plan_contraction(tn, TARGET, optimize="nope")


def test_objective_modeled_time():
    tn = _tn(26, 11)
    res = plan_search(
        tn, TARGET, max_evals=6, num_workers=2, seed=0,
        objective="modeled_time",
    )
    assert res.objective > 0.0
    assert math.isfinite(res.objective)
    assert res.objective_kind == "modeled_time"
