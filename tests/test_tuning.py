"""Algorithm 2 (tuningSliceFinder) and branch merging (Sec. V)."""

import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_closed_network, random_tree
from repro.core.merging import (
    gemm_efficiency,
    merge_branches,
    modeled_tree_time,
    orient_gemms,
)
from repro.core.slicing import find_slices
from repro.core.tuning import tuning_slice_finder


@given(n=st.integers(12, 26), seed=st.integers(0, 9999))
@settings(max_examples=15)
def test_tuning_never_worse_than_initial(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    target = max(tree.width() - 3, 2)
    S0 = find_slices(tree, target, method="lifetime")
    c0 = tree.sliced_cost(S0)
    res = tuning_slice_finder(tree, target, max_rounds=6)
    assert res.sliced_cost <= c0 + 1e-9
    res.tree.check_valid()
    assert res.tree.sliced_width(res.smask) <= target


def test_tuning_improves_on_adversarial_tree():
    """A high-temperature (bad) greedy tree leaves room: tuning should
    strictly reduce C(B)·O(B,S) on at least this instance."""
    tn = random_closed_network(40, 3, 99)
    tree = random_tree(tn, seed=1)  # temperature path
    target = max(tree.width() - 4, 2)
    S0 = find_slices(tree, target, method="lifetime")
    res = tuning_slice_finder(tree, target, max_rounds=20)
    assert res.sliced_cost <= tree.sliced_cost(S0)


# ---------------------------------------------------------------- merging
def test_gemm_efficiency_surface_shape():
    # aligned big GEMM ≈ peak; narrow K collapses
    assert gemm_efficiency(10, 10, 10) > 0.8
    assert gemm_efficiency(10, 10, 1) < 0.15
    # sunway surface reproduces the paper's narrow-GEMM pathology (<4%)
    assert gemm_efficiency(20, 2, 2, surface="sunway") < 0.05


@given(n=st.integers(14, 28), seed=st.integers(0, 9999))
@settings(max_examples=10)
def test_merging_never_increases_modeled_time(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed)
    target = max(tree.width() - 3, 2)
    S = find_slices(tree, target, method="lifetime")
    res = merge_branches(tree, S)
    assert res.time_after <= res.time_before + 1e-12
    res.tree.check_valid()


def test_orient_gemms_valid():
    tn = random_closed_network(20, 3, 5)
    tree = random_tree(tn, 5)
    t2 = orient_gemms(tree)
    t2.check_valid()
    from repro.core.tensor_network import popcount

    for v, (l, r) in t2.children.items():
        assert popcount(t2.emask[l]) >= popcount(t2.emask[r])
