"""Observability layer: off-path contract, span integrity, metrics,
cache counters, logging, calibration.

The load-bearing guarantee is the off path: with ``REPRO_TRACE=0`` (the
default) tracing must be no-op stubs — results bitwise-identical, plan
fingerprints unchanged, no spans recorded.  With tracing on, span trees
must be well-formed (properly nested, non-overlapping per thread) and
the metrics counters must agree with the caches' own ``stats()``.
"""

from __future__ import annotations

import json
import subprocess
import sys
import threading

import numpy as np
import pytest

from conftest import subprocess_kwargs

import repro.obs as obs
from repro.obs import log as obs_log, metrics, trace


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Save/restore the process-global tracing flag and wipe recorded
    telemetry around every test — CI runs this module under both
    REPRO_TRACE=0 and =1, so tests must not assume the env default."""
    prev = trace.enabled()
    obs.reset()
    yield
    trace.set_enabled(prev)
    obs.reset()


def _contract_setup(backend="gemm", seed=0):
    from repro.core.api import plan_compiled
    from repro.core.executor import simplify_network
    from repro.quantum.circuits import circuit_to_network, sycamore_like

    c = sycamore_like(3, 3, 8, seed=seed)
    tn, arrays = circuit_to_network(c, bitstring="0" * 9)
    tn, arrays = simplify_network(tn, arrays)
    plan, report = plan_compiled(tn, 6, backend=backend, use_cache=False)
    return plan, report, arrays


# ----------------------------------------------------------------------
# off-path contract
# ----------------------------------------------------------------------
def test_off_path_is_noop_stub():
    trace.set_enabled(False)
    s = trace.span("anything", key="value")
    assert s is trace._NOOP  # shared stub, no allocation per call
    with s:
        pass
    metrics.inc("should.not.exist")
    metrics.observe("should.not.exist.h", 1.0)
    assert trace.get_spans() == []
    snap = metrics.snapshot()
    assert "should.not.exist" not in snap["counters"]
    assert "should.not.exist.h" not in snap["histograms"]


def test_off_path_results_bitwise_equal():
    plan, _, arrays = _contract_setup()
    trace.set_enabled(False)
    off = np.asarray(plan.contract_all(arrays, slice_batch=4))
    trace.set_enabled(True)
    on = np.asarray(plan.contract_all(arrays, slice_batch=4))
    trace.set_enabled(False)
    again = np.asarray(plan.contract_all(arrays, slice_batch=4))
    # bitwise, not allclose: the traced path must run the identical
    # compiled artifact
    assert off.tobytes() == on.tobytes()
    assert off.tobytes() == again.tobytes()


def test_plan_fingerprint_unchanged_by_telemetry():
    """The telemetry toggle must not join the plan-cache key: a traced
    call hits the entry a non-traced call planted, and vice versa."""
    from repro.core.api import plan_compiled
    from repro.quantum.circuits import circuit_to_network, sycamore_like

    c = sycamore_like(3, 3, 6, seed=3)
    tn, _ = circuit_to_network(c, bitstring="0" * 9)
    plan_a, rep_a = plan_compiled(tn, 6, telemetry=False)
    plan_b, rep_b = plan_compiled(tn, 6, telemetry=True)
    assert plan_b is plan_a  # same cached object == same fingerprint
    assert rep_b.cache_hit
    assert rep_a.telemetry is None
    assert rep_b.telemetry is not None


def test_telemetry_report_through_api(small_circuit):
    from repro.core.api import simulate_amplitude

    n = small_circuit.num_qubits
    r_off = simulate_amplitude(
        small_circuit, "0" * n, target_dim=8, telemetry=False
    )
    r_on = simulate_amplitude(
        small_circuit, "0" * n, target_dim=8, telemetry=True
    )
    assert r_off.report.telemetry is None
    t = r_on.report.telemetry
    assert np.asarray(r_off.value).tobytes() == np.asarray(
        r_on.value
    ).tobytes()
    assert "exec.contract_all" in t["spans"]
    assert t["metrics"]["counters"]["exec.slices_executed"] >= 1


# ----------------------------------------------------------------------
# span integrity
# ----------------------------------------------------------------------
def _check_well_formed(spans):
    """Per thread: spans properly nested, siblings non-overlapping."""
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        assert s.t_end >= s.t_start
        if s.parent_id:
            p = by_id[s.parent_id]
            assert p.thread == s.thread
            assert p.t_start <= s.t_start and s.t_end <= p.t_end
    from collections import defaultdict

    children = defaultdict(list)
    for s in spans:
        children[(s.thread, s.parent_id)].append(s)
    for sibs in children.values():
        sibs.sort(key=lambda s: s.t_start)
        for a, b in zip(sibs, sibs[1:]):
            assert a.t_end <= b.t_start  # non-overlapping


def test_span_tree_well_formed_nested():
    trace.set_enabled(True)
    with trace.span("outer"):
        with trace.span("mid"):
            with trace.span("inner"):
                pass
        with trace.span("mid2"):
            pass
    spans = trace.get_spans()
    assert [s.name for s in spans] == ["inner", "mid", "mid2", "outer"]
    _check_well_formed(spans)
    outer = spans[-1]
    assert outer.parent_id == 0
    assert {s.parent_id for s in spans if s.name.startswith("mid")} == {
        outer.span_id
    }


def test_span_stacks_are_thread_local():
    trace.set_enabled(True)
    # all threads alive at once: OS thread ids are reused otherwise
    barrier = threading.Barrier(4)

    def work(tag):
        barrier.wait()
        with trace.span(f"t-{tag}"):
            with trace.span(f"t-{tag}-child"):
                pass
        barrier.wait()

    threads = [
        threading.Thread(target=work, args=(i,)) for i in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = trace.get_spans()
    assert len(spans) == 8
    _check_well_formed(spans)
    # every top-level span sits on its own thread
    tops = [s for s in spans if s.parent_id == 0]
    assert len(tops) == 4
    assert len({s.thread for s in tops}) == 4


def test_span_trees_agree_scan_vs_resumable():
    from repro.core.distributed import contract_resumable

    plan, _, arrays = _contract_setup(seed=1)
    trace.set_enabled(True)
    scan_val = np.asarray(plan.contract_all(arrays, slice_batch=2))
    scan_spans = {s.name for s in trace.get_spans()}
    obs.reset()
    res_val, _state = contract_resumable(plan, arrays, chunk=2)
    res_spans = {s.name for s in trace.get_spans()}
    _check_well_formed(trace.get_spans())
    assert np.allclose(scan_val, np.asarray(res_val))
    assert "exec.contract_all" in scan_spans
    assert "exec.resumable" in res_spans
    if plan.num_sliced:
        assert "exec.slice_range" in res_spans
    # both paths report the same executed-slice count
    n = 1 << plan.num_sliced
    assert (
        metrics.snapshot()["counters"]["exec.slices_executed"] == n
    )


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------
def test_metrics_snapshot_reset_roundtrip():
    trace.set_enabled(True)
    metrics.inc("a.count")
    metrics.inc("a.count", 2)
    metrics.set_gauge("b.gauge", 7.5)
    metrics.observe("c.hist", 1.0)
    metrics.observe("c.hist", 3.0)
    snap = metrics.snapshot()
    assert snap["counters"]["a.count"] == 3
    assert snap["gauges"]["b.gauge"] == 7.5
    h = snap["histograms"]["c.hist"]
    assert h["count"] == 2 and h["min"] == 1.0 and h["max"] == 3.0
    assert h["mean"] == 2.0
    json.dumps(snap)  # snapshot must be JSON-serializable
    metrics.reset()
    empty = metrics.snapshot()
    assert empty == {"counters": {}, "gauges": {}, "histograms": {}}


def test_metrics_labeled_series_and_cardinality_cap():
    """Per-request labels materialize as ``name{label}`` series, but the
    registry caps distinct labels per base name — the overflow collapses
    into ``{_other}`` so request-keyed labels cannot grow a snapshot
    without bound."""
    reg = metrics.Registry(max_labels=3)
    for fam in ("fam-a", "fam-b", "fam-c"):
        reg.counter("serve.family_requests", label=fam).inc()
    # beyond the cap: new labels all collapse into the overflow series
    for fam in ("fam-d", "fam-e", "fam-f", "fam-g"):
        reg.counter("serve.family_requests", label=fam).inc()
    # an already-admitted label keeps its own series
    reg.counter("serve.family_requests", label="fam-a").inc()
    snap = reg.snapshot()["counters"]
    assert snap["serve.family_requests{fam-a}"] == 2
    assert snap["serve.family_requests{fam-b}"] == 1
    assert snap[f"serve.family_requests{{{metrics.OVERFLOW_LABEL}}}"] == 4
    assert "serve.family_requests{fam-d}" not in snap
    # the cap is per base name, not global
    reg.counter("other.series", label="fam-z").inc()
    assert "other.series{fam-z}" in reg.snapshot()["counters"]
    # unlabeled helpers keep the plain name
    assert reg.labeled("plain", None) == "plain"
    reg.reset()
    assert reg.snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}
    }
    # reset clears the label ledger too: fam-d can be admitted now
    reg.counter("serve.family_requests", label="fam-d").inc()
    assert (
        "serve.family_requests{fam-d}" in reg.snapshot()["counters"]
    )


def test_metrics_snapshot_consistent_under_concurrent_writers():
    """A snapshot is a point-in-time view: with writer threads
    mid-flight, a histogram's (count, total, min, max, mean) must never
    be torn and counter totals must never be lost.  Every observation is
    the constant V, so any consistent snapshot satisfies
    ``total == count * V`` exactly — a torn read breaks the identity."""
    reg = metrics.Registry()
    V = 0.5  # exactly representable: count * V has no rounding slack
    stop = threading.Event()
    PER_THREAD, N_WRITERS = 4000, 4

    def writer():
        h = reg.histogram("w.hist")
        c = reg.counter("w.count")
        for _ in range(PER_THREAD):
            h.observe(V)
            c.inc()

    writers = [
        threading.Thread(target=writer) for _ in range(N_WRITERS)
    ]
    torn = []

    def reader():
        while not stop.is_set():
            snap = reg.snapshot()
            h = snap["histograms"].get("w.hist")
            if h is None or h["count"] == 0:
                continue
            if h["total"] != h["count"] * V:
                torn.append(h)
            if h["mean"] != V or h["min"] != V or h["max"] != V:
                torn.append(h)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in writers + readers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not torn
    snap = reg.snapshot()
    total = N_WRITERS * PER_THREAD
    assert snap["counters"]["w.count"] == total  # no lost increments
    assert snap["histograms"]["w.hist"]["count"] == total


def test_cache_counters_match_plan_cache_stats():
    from repro.lowering.cache import PlanCache, PlanEntry

    trace.set_enabled(True)
    cache = PlanCache(maxsize=4)
    cache.get("missing")
    cache.put("k", PlanEntry(None, None))
    cache.get("k")
    cache.get("k")
    stats = cache.stats()
    snap = metrics.snapshot()["counters"]
    assert stats["hits"] == 2 and stats["misses"] == 1
    assert snap["plan_cache.hits"] == stats["hits"]
    assert snap["plan_cache.misses"] == stats["misses"]


def test_hoist_cache_eviction_counters_match_stats():
    from repro.lowering.cache import HoistCache

    trace.set_enabled(True)
    cache = HoistCache(maxsize=8, max_bytes=100)
    a = np.zeros(10, np.float64)  # 80 bytes per entry
    cache.put("k1", ((a,), ()))
    cache.put("k2", ((a,), ()))  # over max_bytes -> evicts k1
    assert cache.get("k1") is None
    assert cache.get("k2") is not None
    stats = cache.stats()
    snap = metrics.snapshot()["counters"]
    assert stats["evictions"] == 1
    assert stats["evicted_bytes"] == 80
    assert snap["hoist_cache.evictions"] == stats["evictions"]
    assert snap["hoist_cache.evicted_bytes"] == stats["evicted_bytes"]
    assert snap["hoist_cache.hits"] == stats["hits"]
    assert snap["hoist_cache.misses"] == stats["misses"]


# ----------------------------------------------------------------------
# export / merge
# ----------------------------------------------------------------------
def test_dump_trace_jsonl_chrome_and_merge(tmp_path):
    trace.set_enabled(True)
    with trace.span("alpha", cat="test", answer=42):
        pass
    p1 = tmp_path / "t1.jsonl"
    n = trace.dump_trace(str(p1))
    assert n == 1
    ev = json.loads(p1.read_text().strip())
    assert ev["name"] == "alpha" and ev["ph"] == "X"
    assert ev["args"]["answer"] == 42
    pc = tmp_path / "t.chrome.json"
    trace.dump_trace(str(pc), fmt="chrome")
    wrapped = json.loads(pc.read_text())
    assert wrapped["traceEvents"][0]["name"] == "alpha"
    obs.reset()
    with trace.span("beta"):
        pass
    p2 = tmp_path / "t2.jsonl"
    trace.dump_trace(str(p2))
    merged = tmp_path / "merged.jsonl"
    total = trace.merge_traces([str(p1), str(p2)], str(merged))
    assert total == 2
    names = [
        json.loads(line)["name"]
        for line in merged.read_text().splitlines()
    ]
    assert sorted(names) == ["alpha", "beta"]
    with pytest.raises(ValueError):
        trace.dump_trace(str(p1), fmt="nope")


# ----------------------------------------------------------------------
# structured logging
# ----------------------------------------------------------------------
def test_log_level_filter_and_verbatim_stdout(capsys, monkeypatch):
    trace.set_enabled(False)  # stdout filtering must not depend on env
    monkeypatch.setenv("REPRO_LOG_LEVEL", "WARNING")
    obs_log.info("you should not see this")
    obs_log.warning("CACHED tag-1")
    out = capsys.readouterr().out
    # text printed verbatim (sweep-resume parser greps these lines)
    assert out == "CACHED tag-1\n"
    monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
    obs_log.debug("now visible")
    assert capsys.readouterr().out == "now visible\n"
    # structured side-record rides on the trace as an instant event
    trace.set_enabled(True)
    obs_log.error("boom", code=3)
    recs = [s for s in trace.get_spans() if s.cat == "log"]
    assert len(recs) == 1
    assert recs[0].name == "boom"
    assert recs[0].attrs == {"level": "ERROR", "code": 3}


# ----------------------------------------------------------------------
# env gating
# ----------------------------------------------------------------------
def test_repro_trace_env_gating_subprocess():
    code = (
        "import repro.obs as obs\n"
        "with obs.span('s'):\n"
        "    pass\n"
        "print(len(obs.get_spans()))\n"
    )
    kw = subprocess_kwargs()
    for flag, expect in (("0", "0"), ("1", "1")):
        env = dict(kw["env"], REPRO_TRACE=flag, JAX_PLATFORMS="cpu")
        r = subprocess.run(
            [sys.executable, "-c", code],
            env=env, cwd=kw["cwd"], capture_output=True, text=True,
        )
        assert r.returncode == 0, r.stderr
        assert r.stdout.strip() == expect
    env = dict(kw["env"], REPRO_TRACE="yes", JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-c", "import repro.obs"],
        env=env, cwd=kw["cwd"], capture_output=True, text=True,
    )
    assert r.returncode != 0 and "REPRO_TRACE" in r.stderr


# ----------------------------------------------------------------------
# calibration
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["einsum", "gemm"])
def test_calibrate_plan_joins_model_and_measured(backend):
    plan, report, arrays = _contract_setup(backend=backend, seed=2)
    cal = obs.calibrate_plan(plan, arrays, repeat=1)
    assert cal.backend == plan.backend
    assert cal.num_steps == len(plan.steps)
    assert cal.peak_bytes == report.peak_bytes
    by_class = cal.ratio_by_class()
    assert by_class  # at least one backend class exercised
    # every class used by the plan appears with a finite positive ratio
    for cls, agg in by_class.items():
        assert agg["measured_s"] > 0.0
        assert agg["modeled_s"] > 0.0, cls
        assert np.isfinite(agg["ratio"]) and agg["ratio"] > 0.0
    if backend == "einsum":
        assert set(by_class) == {"einsum"}
    # steps covered exactly once (chains count n_steps each)
    chains = plan._chain_dispatch.get("naive", {})
    expect_rows = len(plan.steps) - sum(
        ch.n_steps - 1 for ch in chains.values()
    )
    assert len(cal.rows) == expect_rows
    table = cal.table()
    assert "meas/model" in table and table.count("\n") >= 2
    json.dumps(cal.summary())
