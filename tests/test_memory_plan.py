"""Lifetime-based memory planning + fused transpose-GEMM kernels.

Covers: linear-scan live-set peaks vs a brute-force executor simulation
on random trees (naive and prologue/epilogue segments), slot-assignment
validity, fused-kernel equivalence with the einsum oracle and *bitwise*
agreement with the permute + ``tiled_matmul`` reference at matched tile
blocking (complex Karatsuba included), refiner selection + the
``REPRO_FUSED_GEMM`` off-switch, the peak-aware slicer contract
(|S_peak| <= |S_width|, explicit byte budgets honored), the
device-identity prologue cache key, hoisted-buffer donation, and the
pinned syc-12 peak-bytes regression gate."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_closed_network, random_tree
from repro.core import ContractionPlan, simplify_network, simulate_amplitude
from repro.core.executor import pair_contract_inds
from repro.core.lifetime import step_lifetimes
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import (
    find_slices,
    peak_budget_for_width,
    refine_slices_for_peak,
)
from repro.core.tensor_network import popcount
from repro.lowering import gemm_form, lower_step, refine_schedule, refine_step
from repro.lowering.cache import leaf_key
from repro.lowering.memory import node_nbytes, peak_bytes, plan_memory
from repro.lowering.partition import partition_tree
from repro.lowering.refiner import GemmSpec, default_fused
from repro.kernels import ops
from repro.kernels.contract_gemm import suffix_tile_split
from repro.quantum.circuits import circuit_to_network, random_1d_circuit

RNG = np.random.default_rng(0)
ITEMSIZE = 8  # complex64


# ----------------------------------------------------------------------
# brute-force oracle: replay the executor's env discipline and record the
# max over live sets (independent of the planner's event sweep)
# ----------------------------------------------------------------------
def _simulate_segment_peak(tree, smask, entry, steps, pinned=()):
    """Max live bytes over an executor replay: all entry buffers resident
    up front, each step's output allocated while both inputs are still
    live, non-pinned inputs dropped after their (single) consumption."""
    live = {v: node_nbytes(tree, v, smask, ITEMSIZE) for v in entry}
    peak = sum(live.values())
    pinned = set(pinned)
    for lhs, rhs, out in steps:
        live[out] = node_nbytes(tree, out, smask, ITEMSIZE)
        peak = max(peak, sum(live.values()))
        for u in (lhs, rhs):
            if u not in pinned:
                del live[u]
    return peak


def _random_smask(tree, rng, max_bits=4):
    closed = [
        b
        for b in range(tree.tn.num_inds)
        if not (tree.tn.open_mask >> b) & 1
    ]
    k = int(rng.integers(1, max_bits + 1))
    chosen = rng.choice(closed, size=min(k, len(closed)), replace=False)
    m = 0
    for b in chosen:
        m |= 1 << int(b)
    return m


def _check_plan_against_bruteforce(tree, smask):
    mem = plan_memory(tree, smask, itemsize=ITEMSIZE)
    order = tree.contract_order()
    steps = [(*tree.children[v], v) for v in order]
    want = _simulate_segment_peak(
        tree, smask, range(tree.tn.num_tensors), steps
    )
    assert mem.naive.peak_bytes == want
    if mem.prologue is not None:
        part = partition_tree(tree, smask)
        pro = [(*tree.children[v], v) for v in part.invariant_nodes]
        assert mem.prologue.peak_bytes == _simulate_segment_peak(
            tree, smask, part.prologue_leaves, pro
        )
    if mem.epilogue is not None:
        part = partition_tree(tree, smask)
        epi = [(*tree.children[v], v) for v in part.epilogue_nodes]
        assert mem.epilogue.peak_bytes == _simulate_segment_peak(
            tree, smask,
            part.epilogue_leaves + part.hoisted_nodes, epi,
            pinned=part.hoisted_nodes,
        )
    return mem


def test_peak_matches_bruteforce_fixed():
    for seed in range(8):
        tn = random_closed_network(6 + seed, 3, seed)
        tree = random_tree(tn, seed=seed)
        rng = np.random.default_rng(seed)
        _check_plan_against_bruteforce(tree, 0)
        _check_plan_against_bruteforce(tree, _random_smask(tree, rng))


@given(n=st.integers(6, 20), seed=st.integers(0, 10_000))
@settings(max_examples=25)
def test_peak_matches_bruteforce_property(n, seed):
    """Linear-scan peak == brute-force max over live sets on random
    trees, all three segments, random slicing masks."""
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed=seed)
    rng = np.random.default_rng(seed)
    _check_plan_against_bruteforce(tree, _random_smask(tree, rng))


@given(n=st.integers(6, 20), seed=st.integers(0, 10_000))
@settings(max_examples=25)
def test_certified_peak_matches_full_plan(n, seed):
    """The allocator-free fast path the slicer/co-optimizer score with
    agrees exactly with the full MemoryPlan's certified peak."""
    from repro.lowering.memory import certified_peak

    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed=seed)
    rng = np.random.default_rng(seed)
    for smask in (0, _random_smask(tree, rng)):
        mem = plan_memory(tree, smask, itemsize=8)
        assert certified_peak(tree, smask, 8) == max(
            mem.peak_bytes, mem.peak_bytes_hoisted
        )


def test_slot_assignment_valid():
    """Buffers sharing a slot have disjoint closed lifetimes, every
    buffer fits its slot, and the slot total bounds the true peak."""
    for seed in range(6):
        tn = random_closed_network(10 + seed, 3, seed)
        tree = random_tree(tn, seed=seed)
        rng = np.random.default_rng(seed)
        smask = _random_smask(tree, rng)
        mem = plan_memory(tree, smask, itemsize=ITEMSIZE)
        for seg in (mem.naive, mem.prologue, mem.epilogue):
            if seg is None:
                continue
            birth, death = step_lifetimes(
                list(seg.steps), seg.entry, seg.outputs
            )
            by_slot: dict = {}
            for v, sid in seg.slot_of.items():
                assert seg.nbytes[v] <= seg.slot_bytes[sid]
                by_slot.setdefault(sid, []).append(v)
            for members in by_slot.values():
                ivals = sorted((birth[v], death[v]) for v in members)
                for (b0, d0), (b1, d1) in zip(ivals, ivals[1:]):
                    assert d0 < b1, (seg.name, ivals)
            assert seg.slot_total_bytes() >= seg.peak_bytes
            # pinned buffers are never slot-assigned or freed
            for v in seg.pinned:
                assert v not in seg.slot_of
                for dead in seg.frees.values():
                    assert v not in dead


def test_frees_cover_every_intermediate_once():
    tn = random_closed_network(12, 3, 3)
    tree = random_tree(tn, seed=3)
    mem = plan_memory(tree, 0, itemsize=ITEMSIZE)
    seg = mem.naive
    freed = [u for dead in seg.frees.values() for u in dead]
    assert len(freed) == len(set(freed))
    # everything except the root dies exactly once
    assert set(freed) == set(tree.emask) - {tree.root}


def test_epilogue_peak_scales_with_slice_batch():
    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = circuit_to_network(c, bitstring="0110100101")
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    mem = plan_memory(tree, S, itemsize=ITEMSIZE)
    p1, p4 = mem.epilogue_peak(1), mem.epilogue_peak(4)
    pinned = mem.epilogue.pinned_bytes
    assert p1 == mem.epilogue.peak_bytes
    assert p4 == pinned + 4 * (p1 - pinned)


# ----------------------------------------------------------------------
# fused transpose-GEMM
# ----------------------------------------------------------------------
def _random_form(rng, nb, nm, nn, nk, sizes_from=(1, 6)):
    batch = [f"b{i}" for i in range(nb)]
    ms = [f"m{i}" for i in range(nm)]
    ns = [f"n{i}" for i in range(nn)]
    ks = [f"k{i}" for i in range(nk)]
    sizes = {
        ix: int(rng.integers(*sizes_from)) for ix in batch + ms + ns + ks
    }
    inds_a = batch + ms + ks
    inds_b = batch + ks + ns
    rng.shuffle(inds_a)
    rng.shuffle(inds_b)
    _, inds_out = pair_contract_inds(
        tuple(inds_a), tuple(inds_b), frozenset(batch)
    )
    form = lower_step(inds_a, inds_b, inds_out, sizes.__getitem__)
    sa = tuple(sizes[ix] for ix in inds_a)
    sb = tuple(sizes[ix] for ix in inds_b)
    return form, sa, sb


def _fused_vs_einsum(seed, nb, nm, nn, nk, complex_, sizes_from=(1, 6)):
    rng = np.random.default_rng(seed)
    form, sa, sb = _random_form(rng, nb, nm, nn, nk, sizes_from)
    dtype = np.complex64 if complex_ else np.float32
    a = rng.normal(size=sa)
    b = rng.normal(size=sb)
    if complex_:
        a = a + 1j * rng.normal(size=sa)
        b = b + 1j * rng.normal(size=sb)
    a, b = a.astype(dtype), b.astype(dtype)
    spec = GemmSpec(form, "pallas_fused", 4, 4, 4, 0.0, 0.0)
    got = np.asarray(gemm_form.apply(spec, jnp.asarray(a), jnp.asarray(b)))
    want = np.einsum(form.expr, a, b)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-4 * scale)


@pytest.mark.parametrize("complex_", [False, True])
@pytest.mark.parametrize(
    "nb,nm,nn,nk",
    [(0, 1, 1, 1), (1, 2, 2, 2), (2, 1, 2, 0), (0, 2, 1, 2), (1, 0, 2, 1),
     (0, 0, 0, 2)],
)
def test_fused_matches_einsum_fixed(nb, nm, nn, nk, complex_):
    for seed in (0, 1):
        _fused_vs_einsum(seed, nb, nm, nn, nk, complex_)


@given(
    seed=st.integers(0, 10_000),
    nb=st.integers(0, 2),
    nm=st.integers(0, 2),
    nn=st.integers(0, 2),
    nk=st.integers(0, 2),
    complex_=st.booleans(),
)
@settings(max_examples=30)
def test_fused_property(seed, nb, nm, nn, nk, complex_):
    """Random pairwise contractions (random role counts, sizes 1..5,
    shuffled axis orders, complex Karatsuba + real) — fused
    transpose-GEMM == einsum."""
    _fused_vs_einsum(seed, nb, nm, nn, nk, complex_)


@pytest.mark.parametrize("complex_", [False, True])
@pytest.mark.parametrize(
    "nb,nm,nn,nk,tile",
    [(0, 3, 3, 3, 4), (1, 2, 2, 2, 4), (0, 4, 3, 4, 8), (2, 2, 2, 3, 2)],
)
def test_fused_bitwise_vs_tiled_matmul(nb, nm, nn, nk, tile, complex_):
    """Bit-agreement with the permute + ``tiled_matmul`` reference at
    matched tile blocking: power-of-two role dims so the fused
    axis-suffix tiles divide exactly, reference run with identical
    (bm, bn, bk) — same tile values, same K accumulation order, so the
    results must be *bitwise* identical (complex via the same Karatsuba
    on both sides)."""
    rng = np.random.default_rng(7 * nb + nm + nn + nk + tile)
    form, sa, sb = _random_form(rng, nb, nm, nn, nk, sizes_from=(2, 3))
    dtype = np.complex64 if complex_ else np.float32
    a = rng.normal(size=sa)
    b = rng.normal(size=sb)
    if complex_:
        a = a + 1j * rng.normal(size=sa)
        b = b + 1j * rng.normal(size=sb)
    a, b = a.astype(dtype), b.astype(dtype)
    # effective axis-suffix tiles at this target
    _, _, tm = suffix_tile_split(form.m_shape, tile)
    _, _, tn_ = suffix_tile_split(form.n_shape, tile)
    _, _, tk = suffix_tile_split(form.k_shape, tile)
    fused = np.asarray(
        ops.fused_matmul(
            jnp.asarray(a), jnp.asarray(b),
            perm_a=form.perm_a, perm_b=form.perm_b,
            nb=len(form.batch_inds), nm=len(form.m_inds),
            nn=len(form.n_inds), nk=len(form.k_inds),
            bm=tile, bn=tile, bk=tile, interpret=True,
        )
    ).reshape(form.B, form.M, form.N)
    a2 = jnp.transpose(jnp.asarray(a), form.perm_a).reshape(
        form.B, form.M, form.K
    )
    b2 = jnp.transpose(jnp.asarray(b), form.perm_b).reshape(
        form.B, form.K, form.N
    )
    ref = np.stack([
        np.asarray(
            ops.matmul(
                a2[i], b2[i], bm=tm, bn=tn_, bk=tk,
                min_kernel_dim=1, interpret=True,
            )
        )
        for i in range(form.B)
    ])
    assert fused.dtype == ref.dtype
    assert np.array_equal(fused, ref), (form.expr, tm, tn_, tk)


def test_fused_apply_under_vmap():
    """The fused step must run inside the executor's slice-batch vmap."""
    rng = np.random.default_rng(3)
    form, sa, sb = _random_form(rng, 1, 2, 2, 2, sizes_from=(2, 3))
    a = rng.normal(size=sa).astype(np.float32)
    b = rng.normal(size=sb).astype(np.float32)
    spec = GemmSpec(form, "pallas_fused", 4, 4, 4, 0.0, 0.0)
    va = jnp.stack([jnp.asarray(a), 2.0 * jnp.asarray(a)])
    vb = jnp.stack([jnp.asarray(b), jnp.asarray(b)])
    got = jax.vmap(lambda x, y: gemm_form.apply(spec, x, y))(va, vb)
    want = np.einsum(form.expr, a, b)
    np.testing.assert_allclose(
        np.asarray(got[1]), 2.0 * want, rtol=0,
        atol=1e-4 * max(1.0, np.abs(want).max()),
    )


def test_fused_spec_adapts_to_64bit_arrays():
    """A fused spec handed complex128 arrays at trace time must route to
    the full-precision dot, not truncate through the fp32 kernel."""
    jax.config.update("jax_enable_x64", True)
    try:
        rng = np.random.default_rng(5)
        form, sa, sb = _random_form(rng, 0, 2, 2, 2, sizes_from=(2, 3))
        a = (rng.normal(size=sa) + 1j * rng.normal(size=sa)).astype(
            np.complex128
        )
        b = (rng.normal(size=sb) + 1j * rng.normal(size=sb)).astype(
            np.complex128
        )
        spec = GemmSpec(form, "pallas_fused", 4, 4, 4, 0.0, 0.0)
        got = np.asarray(
            gemm_form.apply(spec, jnp.asarray(a), jnp.asarray(b))
        )
        assert got.dtype == np.complex128
        want = np.einsum(form.expr, a, b)
        np.testing.assert_allclose(
            got, want, rtol=0, atol=1e-10 * max(1.0, np.abs(want).max())
        )
    finally:
        jax.config.update("jax_enable_x64", False)


def _big_pow2_form(rng):
    """An MXU-sized all-power-of-two form the refiner can fuse."""
    ms = [f"m{i}" for i in range(8)]
    ns = [f"n{i}" for i in range(8)]
    ks = [f"k{i}" for i in range(8)]
    sizes = {ix: 2 for ix in ms + ns + ks}
    inds_a = ms + ks
    inds_b = ks + ns
    rng.shuffle(inds_a)
    rng.shuffle(inds_b)
    _, inds_out = pair_contract_inds(
        tuple(inds_a), tuple(inds_b), frozenset()
    )
    return lower_step(inds_a, inds_b, inds_out, sizes.__getitem__)


def test_refiner_picks_fused_and_credits_transpose():
    form = _big_pow2_form(np.random.default_rng(0))
    spec = refine_step(form, np.complex64, fused=True)
    ref = refine_step(form, np.complex64, fused=False)
    assert spec.backend == "pallas_fused"
    assert ref.backend == "pallas"
    # the fused cost model credits the eliminated 2*(|A|+|B|)*bytes of
    # transpose bandwidth (plus zero padding), so it must model faster
    assert spec.modeled_time_s < ref.modeled_time_s
    assert spec.pad_waste == 0.0
    assert spec.transpose_bytes == 0.0
    assert ref.transpose_bytes > 0.0
    # effective tiles divide exactly
    assert form.M % spec.bm == 0
    assert form.N % spec.bn == 0
    assert form.K % spec.bk == 0
    # schedule-level accounting
    sched = refine_schedule(
        [(form.inds_a, form.inds_b, form.inds_out)],
        {**{ix: 2 for ix in form.inds_a}, **{ix: 2 for ix in form.inds_b}}
        .__getitem__,
        dtype=np.complex64,
        fused=True,
    )
    assert sched.backend_counts() == {"pallas_fused": 1}
    assert sched.transpose_bytes_eliminated() == pytest.approx(
        2.0 * 8 * (form.B * form.M * form.K + form.B * form.K * form.N)
    )
    assert "pallas_fused=1" in sched.summary_row()


def test_fused_env_gate(monkeypatch):
    form = _big_pow2_form(np.random.default_rng(1))
    monkeypatch.setenv("REPRO_FUSED_GEMM", "0")
    assert default_fused() is False
    assert refine_step(form, np.complex64).backend == "pallas"
    monkeypatch.setenv("REPRO_FUSED_GEMM", "1")
    assert default_fused() is True
    assert refine_step(form, np.complex64).backend == "pallas_fused"
    monkeypatch.setenv("REPRO_FUSED_GEMM", "maybe")
    with pytest.raises(ValueError):
        default_fused()


# ----------------------------------------------------------------------
# peak-aware slicing
# ----------------------------------------------------------------------
def _certified_peak(tree, S):
    mem = plan_memory(tree, S, itemsize=ITEMSIZE)
    return max(mem.peak_bytes, mem.peak_bytes_hoisted)


def test_peak_mode_never_larger_than_width_mode():
    """|S_peak| <= |S_width| on every instance, and the refined mask
    still honors the width-mode budget max(live-factor bound, achieved
    width certified peak) — certified over both the naive and the
    hoisted (prologue/epilogue, pinned frontier) execution modes."""
    strict = 0
    for seed in range(6):
        c = random_1d_circuit(10 + (seed % 3), 8, seed=seed)
        tn, arrays = circuit_to_network(c, bitstring="0" * c.num_qubits)
        tn, arrays = simplify_network(tn, arrays)
        tree = random_tree(tn, seed=seed)
        target = max(tree.width() - 3, 4)
        Sw = find_slices(tree, target, method="lifetime")
        Sp = find_slices(tree, target, method="lifetime", mode="peak")
        assert popcount(Sp) <= popcount(Sw)
        budget = max(
            peak_budget_for_width(target), _certified_peak(tree, Sw)
        )
        assert _certified_peak(tree, Sp) <= budget
        if popcount(Sp) < popcount(Sw):
            strict += 1
    assert strict > 0  # the pool must exhibit a strict improvement


def test_peak_mode_results_agree():
    """Peak-mode slicing changes |S| only — the contraction value must
    not move."""
    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = circuit_to_network(c, bitstring="0110100101")
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    Sp = find_slices(tree, 4, method="lifetime", mode="peak")
    got = np.asarray(
        ContractionPlan(tree, Sp).contract_all(arrays, slice_batch=4)
    )
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-5)


def test_explicit_budget_tops_up():
    """A hard explicit byte budget tighter than the width result's peak
    forces deeper slicing until the certified peak fits."""
    tn = random_closed_network(14, 3, 2)
    tree = random_tree(tn, seed=2)
    target = max(tree.width() - 2, 3)
    S = find_slices(tree, target, method="lifetime")
    budget = _certified_peak(tree, S) // 2
    S2 = refine_slices_for_peak(tree, S, target, budget_bytes=budget)
    assert _certified_peak(tree, S2) <= budget


def test_peak_monotone_in_smask():
    """Adding a sliced index never increases the planned peak — the
    property the prune/top-up loops rely on."""
    tn = random_closed_network(12, 3, 5)
    tree = random_tree(tn, seed=5)
    rng = np.random.default_rng(5)
    S = _random_smask(tree, rng, max_bits=3)
    for b in range(tree.tn.num_inds):
        if (S >> b) & 1 or (tree.tn.open_mask >> b) & 1:
            continue
        assert peak_bytes(tree, S | (1 << b)) <= peak_bytes(tree, S)


# ----------------------------------------------------------------------
# executor + report integration
# ----------------------------------------------------------------------
def test_report_memory_fields():
    c = random_1d_circuit(9, 7, seed=11)
    res = simulate_amplitude(c, "011010010", target_dim=4, use_cache=False)
    rep = res.report
    assert rep.peak_bytes > 0
    assert rep.peak_bytes_hoisted > 0
    assert rep.buffer_slots > 0
    assert "peak=" in rep.row() and "slots=" in rep.row()
    mem = res.plan.memory_plan()
    assert mem.peak_bytes == rep.peak_bytes
    # the slot plan never needs more buffers than a no-reuse executor
    assert mem.buffer_slots <= len(mem.naive.nbytes)


def test_hoist_cache_device_identity_key():
    """Device-resident leaves are keyed by buffer identity — no value
    hashing/host transfer; host leaves still key by value."""
    host = [np.ones((2, 2), np.complex64), np.zeros(2, np.complex64)]
    k1, keep1 = leaf_key(host)
    k2, _ = leaf_key([a.copy() for a in host])
    assert k1 == k2  # host arrays: equal values -> equal keys
    assert keep1 == ()  # nothing to pin
    dev = [jnp.asarray(a) for a in host]
    dk1, dkeep = leaf_key(dev)
    dk2, _ = leaf_key(dev)
    assert dk1 == dk2  # same buffers -> same key
    assert len(dkeep) == 2 and dkeep[0] is dev[0]  # ids pinned alive
    dk3, _ = leaf_key([jnp.asarray(a) for a in host])
    assert dk3 != dk1  # distinct device buffers miss (safe direction)
    assert dk1 != k1  # identity keys never collide with value keys


def test_prologue_cache_hits_on_device_arrays():
    """Passing the same device arrays twice must hit the hoist cache
    without hashing their values."""
    c = random_1d_circuit(10, 8, seed=5)
    tn, arrays = circuit_to_network(c, bitstring="0" * 10)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    plan = ContractionPlan(tree, S)
    assert plan.can_hoist
    dev = [jnp.asarray(a) for a in arrays]
    h1 = plan.contract_prologue(dev)
    assert plan._hoist_cache.stats()["misses"] == 1
    h2 = plan.contract_prologue(dev)
    assert plan._hoist_cache.stats()["hits"] == 1
    for x, y in zip(h1, h2):
        assert x is y
    # a distinct device copy misses (identity key) but stays correct
    dev2 = [jnp.asarray(a) for a in arrays]
    h3 = plan.contract_prologue(dev2)
    assert plan._hoist_cache.stats()["misses"] == 2
    for x, y in zip(h1, h3):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-7)


def test_hoist_cache_disabled_still_exact(monkeypatch):
    """With the hoist cache disabled (no key, no entry) the two-phase
    path re-materializes the prologue per call and stays exact."""
    monkeypatch.setenv("REPRO_HOIST_CACHE_SIZE", "0")
    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = circuit_to_network(c, bitstring="0110100101")
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    plan = ContractionPlan(tree, S)
    assert plan.can_hoist and plan._hoist_cache.maxsize == 0
    got = np.asarray(plan.contract_all(arrays, slice_batch=4, hoist=True))
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-5)
    assert len(plan._hoist_cache) == 0  # nothing was cached


# ----------------------------------------------------------------------
# pinned regression gate (CI: peak on the syc-12 plan must not grow)
# ----------------------------------------------------------------------
def test_syc12_peak_regression():
    from repro.quantum.circuits import sycamore_like

    here = os.path.dirname(os.path.abspath(__file__))
    with open(
        os.path.join(here, "..", "experiments", "memory", "pinned_syc12.json")
    ) as f:
        pinned = json.load(f)
    circ = sycamore_like(4, 5, 12, seed=0)
    tn, arrays = circuit_to_network(circ, bitstring="0" * circ.num_qubits)
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(
        tn, repeats=pinned["planner_repeats"], seed=pinned["planner_seed"]
    )
    target = max(tree.width() - 4, 8)
    assert target == pinned["target_dim"]
    S = find_slices(tree, target, method="lifetime")
    mem = plan_memory(tree, S, itemsize=pinned["itemsize"])
    assert mem.peak_bytes <= pinned["peak_bytes"]
    assert mem.peak_bytes_hoisted <= pinned["peak_bytes_hoisted"]

    # fusion-boundary pass: the chain planner must keep finding at least
    # the pinned number of multi-step VMEM chains on this plan, every
    # chain's certified live set must respect both the pinned fused peak
    # and the hard VMEM budget, and the modeled epilogue HBM savings
    # (round-trips + transpose traffic, counted disjointly) must not
    # regress below the pinned floor.
    from repro.lowering import CHAIN_VMEM_BUDGET_BYTES, plan_tree_chains

    cp = plan_tree_chains(tree, S)
    assert cp.num_multi >= pinned["fused_chains"]
    assert cp.max_live_bytes() <= pinned["chain_peak_bytes"]
    assert cp.max_live_bytes() <= CHAIN_VMEM_BUDGET_BYTES
    assert (
        cp.hbm_bytes_saved("epilogue")
        >= pinned["chain_hbm_bytes_saved_epilogue"]
    )
