"""Contraction-order search quality + DP oracle."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_closed_network
from repro.core.contraction_tree import ContractionTree
from repro.core.pathfinder import (
    dp_optimal_tree,
    greedy_ssa_path,
    partition_ssa_path,
    random_greedy_tree,
)


@given(n=st.integers(5, 11), seed=st.integers(0, 500))
@settings(max_examples=10)
def test_greedy_within_factor_of_optimal(n, seed):
    tn = random_closed_network(n, 3, seed)
    opt = dp_optimal_tree(tn)
    tree = random_greedy_tree(tn, repeats=8, seed=seed)
    # log2 gap bounded (greedy is near-optimal on tiny graphs)
    assert tree.log2_total_cost() <= opt.log2_total_cost() + 4.0


def test_dp_is_really_optimal_exhaustive_tiny():
    """Cross-check DP against full enumeration on a 5-tensor network."""
    import itertools

    tn = random_closed_network(5, 3, 17)
    opt = dp_optimal_tree(tn).total_cost()
    best = math.inf
    # enumerate all ssa paths
    def rec(avail, path):
        nonlocal best
        if len(avail) == 1:
            tree = ContractionTree.from_ssa_path(tn, path)
            best = min(best, tree.total_cost())
            return
        for i, j in itertools.combinations(sorted(avail), 2):
            nid = tn.num_tensors + len(path)
            rec(avail - {i, j} | {nid}, path + [(i, j)])

    rec(set(range(5)), [])
    assert math.isclose(opt, best, rel_tol=1e-9)


@given(n=st.integers(8, 40), seed=st.integers(0, 500))
@settings(max_examples=10)
def test_partition_path_valid(n, seed):
    tn = random_closed_network(n, 3, seed)
    path = partition_ssa_path(tn, seed=seed)
    tree = ContractionTree.from_ssa_path(tn, path)
    tree.check_valid()


def test_greedy_handles_open_indices():
    from repro.core.tensor_network import TensorNetwork

    tn = TensorNetwork(
        [("a", "b"), ("b", "c"), ("c", "d"), ("d", "e")],
        open_inds=("a", "e"),
    )
    path = greedy_ssa_path(tn)
    tree = ContractionTree.from_ssa_path(tn, path)
    tree.check_valid()
    from repro.core.tensor_network import popcount

    assert popcount(tree.emask[tree.root]) == 2  # both open inds survive
