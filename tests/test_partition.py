"""Two-phase (lifetime-partitioned) execution: partitioner correctness
against brute-force lifetime closures, hoisted == naive equivalence on
both backends (with/without open indices, under vmap slice batching and
the shard_map subprocess harness), ragged slice batches, the prologue
cache, and the REPRO_HOIST off-switch."""

import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import random_closed_network, random_tree, subprocess_kwargs
from repro.core import (
    ContractionPlan,
    default_hoist,
    simplify_network,
    simulate_amplitude,
)
from repro.core.executor import auto_slice_batch
from repro.core.lifetime import lifetime_closure, lifetime_edges
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.tensor_network import bits
from repro.lowering.partition import partition_tree
from repro.quantum import statevector
from repro.quantum.circuits import circuit_to_network, random_1d_circuit


def _random_smask(tree, rng, max_bits=4):
    """A slicing mask over closed (degree-2, non-open) indices."""
    closed = [
        b
        for b in range(tree.tn.num_inds)
        if not (tree.tn.open_mask >> b) & 1
    ]
    k = int(rng.integers(1, max_bits + 1))
    chosen = rng.choice(closed, size=min(k, len(closed)), replace=False)
    m = 0
    for b in chosen:
        m |= 1 << int(b)
    return m


# ---------------------------------------------------------- partitioner
@given(n=st.integers(6, 20), seed=st.integers(0, 10_000))
@settings(max_examples=20)
def test_closure_matches_bruteforce_lifetimes(n, seed):
    """The slice-dependent set is exactly the union, over sliced bits, of
    the lifetime edges (Thm. 1 leaf-to-leaf paths) plus all their
    ancestors — computed here the slow way, node by node."""
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed=seed)
    rng = np.random.default_rng(seed)
    smask = _random_smask(tree, rng)
    expected = set()
    for b in bits(smask):
        for v in lifetime_edges(tree, b):
            expected.add(v)
            while v in tree.parent:  # upward closure
                v = tree.parent[v]
                expected.add(v)
    assert lifetime_closure(tree, smask) == expected


@given(n=st.integers(6, 20), seed=st.integers(0, 10_000))
@settings(max_examples=20)
def test_partition_invariants(n, seed):
    tn = random_closed_network(n, 3, seed)
    tree = random_tree(tn, seed=seed)
    rng = np.random.default_rng(seed)
    smask = _random_smask(tree, rng)
    part = partition_tree(tree, smask)
    internal = set(tree.children)
    # invariant + epilogue is a disjoint cover of the internal nodes
    assert set(part.invariant_nodes) | set(part.epilogue_nodes) == internal
    assert not set(part.invariant_nodes) & set(part.epilogue_nodes)
    # invariant nodes never touch a sliced index
    for v in part.invariant_nodes:
        assert tree.node_mask(v) & smask == 0
    # hoisted frontier: invariant nodes consumed by the slice loop
    for v in part.hoisted_nodes:
        assert v in set(part.invariant_nodes)
        p = tree.parent.get(v)
        assert p is None or p in part.dependent
    # the root depends on every sliced index
    assert tree.root in part.dependent
    # leaf cover
    leaves = set(part.prologue_leaves) | set(part.epilogue_leaves)
    assert leaves == set(range(tn.num_tensors))
    # cost accounting: hoisted <= naive (Eq. 6), both tied to Eq. 3/4
    assert part.total_cost == pytest.approx(tree.total_cost())
    assert part.naive_cost() == pytest.approx(tree.sliced_cost(smask))
    assert part.hoisted_cost() <= part.naive_cost() + 1e-6
    assert part.hoisted_overhead() <= tree.slicing_overhead(smask) + 1e-9
    if part.invariant_nodes:
        assert part.hoisted_overhead() < tree.slicing_overhead(smask)
        assert 0.0 < part.invariant_fraction < 1.0


# ------------------------------------------------- hoisted == naive
def _closed_case(seed, nq=10, cycles=8):
    c = random_1d_circuit(nq, cycles, seed=seed)
    rng = np.random.default_rng(seed)
    bs = "".join(str(b) for b in rng.integers(0, 2, nq))
    tn, arrays = circuit_to_network(c, bitstring=bs)
    return simplify_network(tn, arrays)


@pytest.mark.parametrize("backend", ["einsum", "gemm"])
def test_hoisted_equals_naive_closed(backend):
    tn, arrays = _closed_case(3)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    plan = ContractionPlan(tree, S, backend=backend)
    assert plan.can_hoist  # the case must actually exercise hoisting
    naive = np.asarray(plan.contract_all(arrays, slice_batch=4, hoist=False))
    hoisted = np.asarray(plan.contract_all(arrays, slice_batch=4, hoist=True))
    np.testing.assert_allclose(naive, dense, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hoisted, dense, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["einsum", "gemm"])
def test_hoisted_equals_naive_open_indices(backend):
    """Open output wires (batched sampling network) under slicing: the
    hoisted amplitude batch must match the naive one entry-for-entry."""
    from repro.sampling.batch import open_batch_network

    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = open_batch_network(c, "0" * 10, (7, 8, 9))
    tree = random_greedy_tree(tn, repeats=4)
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    S = find_slices(tree, 5, method="lifetime")
    plan = ContractionPlan(tree, S, backend=backend)
    assert plan.num_sliced > 0 and plan.can_hoist
    naive = np.asarray(plan.contract_all(arrays, slice_batch=2, hoist=False))
    hoisted = np.asarray(plan.contract_all(arrays, slice_batch=2, hoist=True))
    assert dense.shape == (2, 2, 2)
    np.testing.assert_allclose(naive, dense, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hoisted, dense, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 500), nq=st.integers(6, 10))
@settings(max_examples=6)
def test_hoisted_amplitude_property(seed, nq):
    """End-to-end: simulate_amplitude(hoist=True) == hoist=False ==
    statevector oracle, through the full planner under vmapped slice
    batching."""
    c = random_1d_circuit(nq, 5, seed=seed)
    rng = np.random.default_rng(seed)
    bs = "".join(str(b) for b in rng.integers(0, 2, nq))
    ref = statevector.amplitude(c, bs)
    r_h = simulate_amplitude(c, bs, target_dim=5, seed=seed, hoist=True,
                             use_cache=False)
    r_n = simulate_amplitude(c, bs, target_dim=5, seed=seed, hoist=False,
                             use_cache=False)
    assert abs(complex(r_h.value) - ref) < 1e-4
    assert abs(complex(r_h.value) - complex(r_n.value)) < 1e-5
    assert r_h.report.measured_overhead <= r_n.report.measured_overhead + 1e-9
    assert r_h.report.measured_overhead <= r_h.report.slicing_overhead + 1e-9


SHARDED_HOIST = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.distributed import contract_sharded
from repro.launch.mesh import make_host_mesh

c = random_1d_circuit(10, 8, seed=3)
tn, arrays = circuit_to_network(c, bitstring="0110100101")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 4, method="lifetime")
dense = ContractionPlan(tree, 0).contract_all(arrays)
for backend in ("einsum", "gemm"):
    plan = ContractionPlan(tree, S, backend=backend)
    assert plan.can_hoist
    for hoist in (False, True):
        mesh = make_host_mesh((4,), ("data",))
        v = contract_sharded(plan, arrays, mesh, axis_names=("data",),
                             slice_batch=2, hoist=hoist)
        assert np.allclose(np.asarray(v), np.asarray(dense), atol=1e-5), (
            backend, hoist)
    # prologue ran once per process and is served from the hoist cache
    assert plan._hoist_cache.stats()["misses"] == 1
    v2 = contract_sharded(plan, arrays, mesh, axis_names=("data",),
                          slice_batch=2, hoist=True)
    assert np.allclose(np.asarray(v2), np.asarray(dense), atol=1e-5)
    assert plan._hoist_cache.stats()["hits"] >= 1
print("DONE")
"""


def test_contract_sharded_hoisted():
    """Hoisted == naive under the shard_map subprocess harness, both
    backends; the prologue is computed outside the slice loop."""
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_HOIST],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


# ------------------------------------------------- ragged slice batches
def test_ragged_slice_batch_any_size():
    """Any slice_batch works: the final ragged batch is padded with
    wrapped-around slice ids masked out, so results never change."""
    tn, arrays = _closed_case(7)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    plan = ContractionPlan(tree, S)
    n_slices = 1 << plan.num_sliced
    assert n_slices >= 8
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    for sb in (3, 5, 7, n_slices - 1, n_slices + 9):
        for hoist in (False, True):
            v = np.asarray(
                plan.contract_all(arrays, slice_batch=sb, hoist=hoist)
            )
            np.testing.assert_allclose(
                v, dense, rtol=1e-4, atol=1e-5,
                err_msg=f"slice_batch={sb} hoist={hoist}",
            )


def test_auto_slice_batch_no_longer_shrinks():
    """auto_slice_batch honors the request (clamped to n_slices) instead
    of silently shrinking to a divisor."""
    assert auto_slice_batch(3, 8) == 3
    assert auto_slice_batch(5, 8) == 5
    assert auto_slice_batch(6, 4) == 4
    assert auto_slice_batch(8, 8) == 8
    assert auto_slice_batch(0, 8) == 1
    assert auto_slice_batch(7, 1) == 1


# ----------------------------------------------- prologue cache + env
def test_prologue_cache_reuse_and_invalidation():
    tn, arrays = _closed_case(5)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, 4, method="lifetime")
    plan = ContractionPlan(tree, S)
    assert plan.can_hoist
    v1 = np.asarray(plan.contract_all(arrays, slice_batch=4, hoist=True))
    stats = plan._hoist_cache.stats()
    assert {k: stats[k] for k in ("size", "maxsize", "hits", "misses")} == dict(
        size=1, maxsize=plan._hoist_cache.maxsize, hits=0, misses=1
    )
    v2 = np.asarray(plan.contract_all(arrays, slice_batch=4, hoist=True))
    assert plan._hoist_cache.stats()["hits"] == 1
    np.testing.assert_allclose(v1, v2, atol=1e-7)
    # changing a prologue leaf's values must miss (different fingerprint)
    arrays2 = [np.asarray(a) for a in arrays]
    i = plan.prologue_leaves[0]
    arrays2[i] = arrays2[i] * 0.5
    _ = plan.contract_all(arrays2, slice_batch=4, hoist=True)
    assert plan._hoist_cache.stats()["misses"] == 2


def test_default_hoist_env(monkeypatch):
    monkeypatch.delenv("REPRO_HOIST", raising=False)
    assert default_hoist() is True
    monkeypatch.setenv("REPRO_HOIST", "0")
    assert default_hoist() is False
    monkeypatch.setenv("REPRO_HOIST", "1")
    assert default_hoist() is True
    monkeypatch.setenv("REPRO_HOIST", "yes")
    with pytest.raises(ValueError):
        default_hoist()


def test_report_hoist_fields():
    c = random_1d_circuit(9, 7, seed=11)
    res = simulate_amplitude(c, "011010010", target_dim=4, use_cache=False,
                             hoist=True)
    rep = res.report
    assert 0.0 <= rep.invariant_fraction < 1.0
    assert rep.measured_overhead <= rep.slicing_overhead + 1e-9
    assert rep.modeled_time_hoisted_s <= rep.modeled_time_s + 1e-12
    assert "hoist=on" in rep.row()
    assert res.plan.hoist_summary().startswith("hoist:")
