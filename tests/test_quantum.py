"""Quantum substrate: gate unitarity, circuit lowering, XEB."""

import numpy as np
import pytest

from repro.quantum import gates, statevector, xeb
from repro.quantum.circuits import (
    circuit_to_network,
    random_1d_circuit,
    sycamore_like,
    zuchongzhi_like,
)


@pytest.mark.parametrize("name", sorted(gates.GATES_1Q))
def test_1q_gates_unitary(name):
    u = gates.GATES_1Q[name]
    np.testing.assert_allclose(u @ u.conj().T, np.eye(2), atol=1e-6)


@pytest.mark.parametrize("name", sorted(gates.GATES_2Q))
def test_2q_gates_unitary(name):
    u = gates.GATES_2Q[name]
    np.testing.assert_allclose(u @ u.conj().T, np.eye(4), atol=1e-6)


def test_fsim_special_cases():
    np.testing.assert_allclose(
        gates.fsim(0, 0), np.eye(4, dtype=np.complex64), atol=1e-7
    )
    iswap_like = gates.fsim(np.pi / 2, 0)
    np.testing.assert_allclose(
        np.abs(iswap_like[1, 2]), 1.0, atol=1e-6
    )


def test_statevector_normalized():
    c = random_1d_circuit(8, 6, seed=0)
    p = statevector.probabilities(c)
    assert abs(p.sum() - 1.0) < 1e-4


def test_circuit_network_shape():
    c = sycamore_like(3, 3, 4, seed=1)
    tn, arrays = circuit_to_network(c, bitstring="0" * 9)
    assert tn.num_tensors == len(arrays)
    assert not tn.is_hyper()
    # every non-open index has degree exactly 2
    assert all(d == 2 for ix, d in tn.ind_degree.items())


def test_patterns_differ():
    a = sycamore_like(3, 3, 8, seed=0)
    b = zuchongzhi_like(3, 3, 8, seed=0)
    pa = [op.qubits for op in a.ops if len(op.qubits) == 2]
    pb = [op.qubits for op in b.ops if len(op.qubits) == 2]
    assert pa != pb


def test_xeb_ideal_sampling_near_one():
    """Sampling from the circuit's own distribution: E[F_XEB] ≈ 1 for an
    RQC deep enough to be Porter-Thomas distributed."""
    c = random_1d_circuit(10, 12, seed=3)
    probs = statevector.probabilities(c)
    samples = xeb.sample_bitstrings(probs, 4000, seed=0)
    f = xeb.linear_xeb(10, probs[samples])
    assert 0.6 < f < 1.6
    # uniform sampling → F ≈ 0
    rng = np.random.default_rng(0)
    uni = rng.integers(0, len(probs), 4000)
    f0 = xeb.linear_xeb(10, probs[uni])
    assert abs(f0) < 0.25
