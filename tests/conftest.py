import os
import random

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def subprocess_kwargs() -> dict:
    """cwd/env for tests that re-exec python with a multi-device XLA_FLAGS
    (portable across checkouts — CI does not live at /root/repo)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    return {"env": env, "cwd": REPO_ROOT}

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("ci")
except ModuleNotFoundError:
    # hypothesis is optional: property-based tests skip cleanly when it is
    # absent, while plain tests in the same modules keep running.  We install
    # a shim into sys.modules *before* test modules are collected (conftest
    # imports first), providing the exact names the test-suite uses:
    # given / settings / strategies-as-st / HealthCheck.
    import sys
    import types

    def _strategy(*_a, **_k):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy  # PEP 562: any strategy name

    def given(*_a, **_k):
        def deco(fn):
            def wrapper(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    class settings:  # noqa: N801 - mirrors hypothesis.settings
        def __init__(self, *_a, **_k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass

    HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.HealthCheck = HealthCheck
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def random_closed_network(n_tensors: int, degree: int, seed: int):
    from repro.core.tensor_network import random_regular_tn

    return random_regular_tn(n_tensors, degree, seed=seed)


def random_tree(tn, seed: int = 0):
    from repro.core.contraction_tree import ContractionTree
    from repro.core.pathfinder import greedy_ssa_path

    path = greedy_ssa_path(tn, seed=seed, temperature=0.5 if seed % 2 else 0.0)
    return ContractionTree.from_ssa_path(tn, path)


@pytest.fixture
def small_circuit():
    from repro.quantum.circuits import random_1d_circuit

    return random_1d_circuit(8, 6, seed=7)
