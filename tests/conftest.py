import random

import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("ci")


def random_closed_network(n_tensors: int, degree: int, seed: int):
    from repro.core.tensor_network import random_regular_tn

    return random_regular_tn(n_tensors, degree, seed=seed)


def random_tree(tn, seed: int = 0):
    from repro.core.contraction_tree import ContractionTree
    from repro.core.pathfinder import greedy_ssa_path

    path = greedy_ssa_path(tn, seed=seed, temperature=0.5 if seed % 2 else 0.0)
    return ContractionTree.from_ssa_path(tn, path)


@pytest.fixture
def small_circuit():
    from repro.quantum.circuits import random_1d_circuit

    return random_1d_circuit(8, 6, seed=7)
