"""Regression tests for the PR-5 correctness fixes: chunk-agnostic
checkpoint resume, select-based (NaN-safe) ragged-batch masking, hoist
cache eviction releasing device buffers, and the sharded ragged-batch
contract."""

import gc
import subprocess
import sys

import jax
import numpy as np
import pytest

from conftest import subprocess_kwargs
from repro.core import ContractionPlan, simplify_network
from repro.core.contraction_tree import ContractionTree
from repro.core.distributed import SliceRangeCheckpoint, contract_resumable
from repro.core.pathfinder import greedy_ssa_path, random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.tensor_network import random_regular_tn
from repro.lowering.cache import HoistCache
from repro.quantum.circuits import circuit_to_network, random_1d_circuit


def _plan(min_sliced: int = 3):
    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = circuit_to_network(c, bitstring="0110100101")
    tn, arrays = simplify_network(tn, arrays)
    tree = random_greedy_tree(tn, repeats=4)
    S = find_slices(tree, tree.width() - min_sliced, method="lifetime")
    plan = ContractionPlan(tree, S)
    assert plan.num_sliced >= min_sliced
    return plan, arrays, tree


# ----------------------------------------------------------------------
# resume-chunk contract
# ----------------------------------------------------------------------
def test_missing_is_chunk_agnostic():
    ck = SliceRangeCheckpoint(10, set(), 0.0)
    ck.add_range(0, 4)
    assert ck.missing(4) == [(4, 8), (8, 10)]
    # a different chunk never re-enqueues completed ids
    assert ck.missing(3) == [(4, 7), (7, 10)]
    assert ck.missing(100) == [(4, 10)]
    ck.add_range(6, 8)
    # ranges stop at done islands and need not align to chunk boundaries
    assert ck.missing(4) == [(4, 6), (8, 10)]
    assert ck.done_ids() == {0, 1, 2, 3, 6, 7}


def test_legacy_range_entries_normalize():
    # checkpoints written by the old range-keyed format still resume
    ck = SliceRangeCheckpoint(8, {(0, 3), 5}, 0.0)
    assert ck.done_ids() == {0, 1, 2, 5}
    assert ck.missing(8) == [(3, 5), (6, 8)]
    ck.add_range(3, 5)
    assert ck.done_ids() == {0, 1, 2, 3, 4, 5}


def test_resume_across_chunk_sizes():
    """A checkpoint written with chunk=k1 must resume under chunk=k2
    without re-summing (double-counting) completed slices."""
    plan, arrays, tree = _plan()
    dense = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    n_slices = 1 << plan.num_sliced
    out_shape = jax.eval_shape(
        lambda: plan.contract_slice(list(arrays), 0)
    )
    state = SliceRangeCheckpoint(
        n_slices, set(), np.zeros(out_shape.shape, out_shape.dtype)
    )
    # partial run at chunk=3, failing after two completed ranges
    with pytest.raises(RuntimeError):
        contract_resumable(plan, arrays, chunk=3, state=state, fail_on={6})
    assert state.done_ids() == set(range(6))
    # resume with a different chunk: completes, no double counting
    val, state = contract_resumable(plan, arrays, chunk=5, state=state)
    np.testing.assert_allclose(val, dense, atol=1e-4)
    assert state.done_ids() == set(range(n_slices))
    # and a third chunk size is a no-op
    val2, _ = contract_resumable(plan, arrays, chunk=7, state=state)
    np.testing.assert_allclose(val2, val, atol=1e-6)


# ----------------------------------------------------------------------
# ragged-batch masking: select, not weight-multiply
# ----------------------------------------------------------------------
def _overflow_network(seed: int = 0):
    """A closed network whose every slice contribution overflows float32
    to +inf (all-positive entries, no cancellation): the correct ragged
    sum is +inf, while a ``0 * inf`` weight-multiply mask turns it NaN."""
    tn = random_regular_tn(10, 3, seed=seed)
    rng = np.random.default_rng(seed)
    arrays = [
        (rng.uniform(0.5, 1.0, size=(2,) * len(t)) * 1e25).astype(
            np.float32
        )
        for t in tn.inputs
    ]
    tree = ContractionTree.from_ssa_path(tn, greedy_ssa_path(tn, seed=1))
    S = find_slices(tree, max(tree.width() - 2, 2), method="lifetime")
    assert S, "need at least one sliced index for a ragged batch"
    return ContractionPlan(tree, S), arrays


@pytest.mark.parametrize("hoist", [False, True])
def test_ragged_padding_does_not_leak_nan(hoist):
    plan, arrays = _overflow_network()
    n_slices = 1 << plan.num_sliced
    assert n_slices % 3 != 0  # slice_batch=3 forces a ragged final batch
    val = np.asarray(plan.contract_all(arrays, slice_batch=3, hoist=hoist))
    assert np.all(np.isinf(val)), val
    assert not np.any(np.isnan(val)), (
        "padded-lane contribution leaked through the validity mask"
    )


@pytest.mark.parametrize("hoist", [False, True])
def test_ragged_padding_correct_value(hoist):
    """Finite case: every slice_batch (ragged or not) sums identically."""
    plan, arrays, tree = _plan()
    ref = np.asarray(ContractionPlan(tree, 0).contract_all(arrays))
    for sb in (3, 5, (1 << plan.num_sliced) - 1):
        val = np.asarray(
            plan.contract_all(arrays, slice_batch=sb, hoist=hoist)
        )
        np.testing.assert_allclose(val, ref, atol=1e-4)


# ----------------------------------------------------------------------
# hoist cache: eviction releases device buffers; optional byte bound
# ----------------------------------------------------------------------
def _n_live() -> int:
    gc.collect()
    return len(jax.live_arrays())


def test_hoist_cache_eviction_releases_device_buffers():
    plan, arrays, _ = _plan()
    assert plan.can_hoist
    plan._hoist_cache = HoistCache(maxsize=2)
    n_out = len(plan.hoisted_nodes)

    def variant(k):
        return [np.asarray(a) * (1.0 + 0.01 * k) for a in arrays]

    out = plan.contract_prologue(variant(0))  # warm the jit trace
    del out
    base = _n_live()
    for k in range(1, 9):
        out = plan.contract_prologue(variant(k))
        del out
    assert len(plan._hoist_cache._entries) == 2
    grown = _n_live() - base
    # 8 inserts at maxsize=2: evictions must have dropped the buffer
    # refs, so growth is bounded by ~2 entries, not 8
    assert grown <= 2 * n_out + 4, (grown, n_out)
    plan._hoist_cache.clear()
    assert _n_live() <= base + 4
    assert plan._hoist_cache.total_bytes == 0


def test_hoist_cache_byte_bound():
    plan, arrays, _ = _plan()
    assert plan.can_hoist
    outs = plan.contract_prologue(arrays, use_cache=False)
    entry_bytes = sum(int(o.nbytes) for o in outs)
    del outs
    # bound admits ~2 entries; entry count alone would admit 8
    plan._hoist_cache = HoistCache(maxsize=8, max_bytes=2 * entry_bytes)
    for k in range(6):
        out = plan.contract_prologue(
            [np.asarray(a) * (1.0 + 0.01 * k) for a in arrays]
        )
        del out
    cache = plan._hoist_cache
    assert len(cache._entries) <= 2
    assert cache.total_bytes <= 2 * entry_bytes
    assert cache.total_bytes == sum(cache._entry_bytes.values())
    # an oversized single entry is still admitted (best-effort bound)
    cache.max_bytes = 1
    out = plan.contract_prologue(
        [np.asarray(a) * 1.5 for a in arrays]
    )
    del out
    assert len(cache._entries) == 1


# ----------------------------------------------------------------------
# sharded ragged batches (shard_map, 8 virtual devices)
# ----------------------------------------------------------------------
SHARDED_RAGGED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.quantum.circuits import random_1d_circuit, circuit_to_network
from repro.core import simplify_network, ContractionPlan
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.distributed import contract_sharded
from repro.launch.mesh import make_host_mesh

c = random_1d_circuit(10, 8, seed=3)
tn, arrays = circuit_to_network(c, bitstring="0110100101")
tn, arrays = simplify_network(tn, arrays)
tree = random_greedy_tree(tn, repeats=4)
S = find_slices(tree, 4, method="lifetime")
plan = ContractionPlan(tree, S)
assert (1 << plan.num_sliced) % (8 * 3) != 0  # genuinely ragged
dense = ContractionPlan(tree, 0).contract_all(arrays)
mesh = make_host_mesh((8,), ("data",))
# slice_batch=3 over 8 devices: per-device ids stay tileable only via
# the executor's padding contract (no divisibility assumption)
v = contract_sharded(plan, arrays, mesh, slice_batch=3)
assert np.allclose(np.asarray(v), np.asarray(dense), atol=1e-4)
# a slice_batch larger than the per-device share still works
v2 = contract_sharded(plan, arrays, mesh, slice_batch=7)
assert np.allclose(np.asarray(v2), np.asarray(dense), atol=1e-4)
print("DONE")
"""


def test_contract_sharded_ragged_batches():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_RAGGED],
        capture_output=True, text=True, timeout=900,
        **subprocess_kwargs(),
    )
    assert "DONE" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
