"""Sycamore-style RQC simulation with the full paper pipeline, comparing
the planner variants the paper compares (Sec. VI):

  greedy (Cotengra-style)  →  sliceFinder  →  + tree tuning  →  + merging

and executing the best plan (sliced, batched, single all-reduce) two ways:

  * per-amplitude XEB over a few independently simulated bitstrings, and
  * the paper's flagship batch-sampling workload: ``--open-qubits k``
    output wires stay open so ONE sliced contraction yields all 2^k
    correlated amplitudes, from which ``--num-samples`` bitstrings are
    drawn and XEB-scored.

    PYTHONPATH=src python examples/simulate_sycamore.py \
        [--rows 4 --cols 4 --cycles 10 --num-samples 1000 --open-qubits 4 \
         --backend gemm]

``--backend gemm`` compiles each plan into the lowered kernel schedule
(``src/repro/lowering/``: GEMM normalization + adaptive tile refiner)
and prints the per-variant schedule summary (node counts per kernel
backend, MXU pad waste) next to the plan row.
"""

import argparse

import numpy as np

from repro.core import (
    plan_contraction,
    sample_bitstrings,
    simulate_amplitude,
)
from repro.core.executor import ContractionPlan, simplify_network
from repro.quantum import xeb
from repro.quantum.circuits import circuit_to_network, sycamore_like


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--cols", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=10)
    ap.add_argument("--target-dim", type=int, default=12)
    ap.add_argument("--samples", type=int, default=4,
                    help="independent per-amplitude simulations for XEB")
    ap.add_argument("--num-samples", type=int, default=1000,
                    help="correlated bitstring samples from one batch")
    ap.add_argument("--open-qubits", type=int, default=4,
                    help="output qubits held open (batch = 2^k amplitudes)")
    ap.add_argument("--backend", choices=("einsum", "gemm"), default=None,
                    help="execution backend (default: $REPRO_BACKEND or "
                    "einsum)")
    ap.add_argument("--fidelity-tol", type=float, default=0.05,
                    help="XEB budget for the precision='auto' demo pass")
    args = ap.parse_args()

    from repro.core import default_backend

    backend = args.backend if args.backend is not None else default_backend()
    circ = sycamore_like(args.rows, args.cols, args.cycles, seed=0)
    nq = circ.num_qubits
    tn, arrays = circuit_to_network(circ, bitstring="0" * nq)
    tn, arrays = simplify_network(tn, arrays)
    print(f"network: {tn.num_tensors} tensors, {tn.num_inds} indices")

    print(f"{'variant':<22}{'log2C':>8}{'slices':>8}{'overhead':>10}"
          f"{'model_t':>12}{'plan_s':>8}")
    for label, kw in (
        ("greedy (cotengra)", dict(method="greedy", tune=False, merge=False)),
        ("sliceFinder", dict(method="lifetime", tune=False, merge=False)),
        ("+ tree tuning", dict(method="lifetime", tune=True, merge=False)),
        ("+ branch merging", dict(method="lifetime", tune=True, merge=True)),
    ):
        tree, smask, rep = plan_contraction(tn, args.target_dim, seed=0, **kw)
        print(
            f"{label:<22}{rep.log2_cost:>8.2f}{rep.num_sliced:>8}"
            f"{rep.slicing_overhead:>10.3f}{rep.modeled_time_s:>12.3e}"
            f"{rep.plan_wall_s:>8.2f}"
        )
        print(
            f"{'':<22}  two-phase: inv_frac={rep.invariant_fraction:.2e} "
            f"hoisted overhead {rep.slicing_overhead:.3f}->"
            f"{rep.measured_overhead:.3f}"
        )
        if backend == "gemm":
            plan = ContractionPlan(tree, smask, backend="gemm")
            print(f"{'':<22}  {plan.schedule.summary_row()}")

    # XEB over a few sampled bitstrings through the full engine (repeat
    # requests share one compiled plan via the plan cache)
    rng = np.random.default_rng(0)
    probs = []
    for i in range(args.samples):
        bs = "".join(str(b) for b in rng.integers(0, 2, nq))
        res = simulate_amplitude(circ, bs, target_dim=args.target_dim,
                                 backend=args.backend)
        probs.append(abs(complex(res.value)) ** 2)
    if args.samples > 0:
        print(f"\nper-amplitude engine: {res.report.row()}")
        if res.plan is not None:
            # measured two-phase speedup on warm repeat requests (plan
            # cache hit, jitted executables reused; planning excluded)
            import time as _time

            bs = "".join(str(b) for b in rng.integers(0, 2, nq))
            times = {}
            for hoist in (False, True):
                best = float("inf")
                for it in range(4):  # first iteration compiles, rest warm
                    t0 = _time.perf_counter()
                    simulate_amplitude(circ, bs, target_dim=args.target_dim,
                                       backend=args.backend, hoist=hoist)
                    if it:
                        best = min(best, _time.perf_counter() - t0)
                times[hoist] = best
            print(
                f"two-phase execution : {res.plan.hoist_summary()} "
                f"measured speedup={times[False] / times[True]:.2f}x"
            )
        f = xeb.linear_xeb(nq, np.asarray(probs))
        print(f"\nLinear XEB over {args.samples} random bitstrings: {f:+.4f} "
              "(random strings → ≈0; circuit-sampled strings → ≈1)")

    # mixed precision under an XEB budget: re-run one amplitude with
    # precision="auto" — MXU-sized GEMM steps demote to bf16-input/
    # fp32-accumulate while the forward error model stays inside
    # --fidelity-tol (needs the gemm backend and a plan large enough to
    # carry Pallas steps, e.g. --rows 4 --cols 5 --cycles 12
    # --target-dim 18; smaller plans certify at zero demotions).
    bs0 = "0" * nq
    r32 = simulate_amplitude(circ, bs0, target_dim=args.target_dim,
                             backend=args.backend, use_cache=False)
    rmp = simulate_amplitude(circ, bs0, target_dim=args.target_dim,
                             backend=args.backend, precision="auto",
                             fidelity_tol=args.fidelity_tol,
                             use_cache=False)
    counts = rmp.report.precision_counts or {}
    scale = max(abs(complex(r32.value)), 1e-300)
    rel = abs(complex(rmp.value) - complex(r32.value)) / scale
    print(
        f"\nmixed precision : mode={rmp.report.precision} "
        f"tol={rmp.report.fidelity_tol:g} steps={counts or '{}'} "
        f"pred_amp_err={rmp.report.predicted_amp_error:.2e} "
        f"|S| {r32.report.num_sliced}->{rmp.report.num_sliced} "
        f"rel_err={rel:.2e}"
    )

    # the paper's batch-sampling workload: one contraction, 2^k correlated
    # amplitudes, num_samples frequency-sampled bitstrings
    k = min(args.open_qubits, nq)
    res = sample_bitstrings(
        circ,
        num_samples=args.num_samples,
        open_qubits=tuple(range(nq - k, nq)),
        target_dim=args.target_dim,
        backend=args.backend,
    )
    uniq = len(set(res.bitstrings))
    print(
        f"\nbatch sampling: {res.batch.size} correlated amplitudes from one "
        f"sliced contraction ({1 << res.report.num_sliced} slices), "
        f"{res.num_samples} samples ({uniq} distinct)"
    )
    print(f"Linear XEB of the sampled batch: {res.xeb:+.4f} "
          "(sampled from the circuit distribution → ≈1 for Porter-Thomas)")


if __name__ == "__main__":
    main()
