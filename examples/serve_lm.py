"""Batched serving example: prefill + decode with KV cache for a dense
GQA model and an attention-free SSM, reporting tokens/s.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.decode_demo import serve


def main() -> None:
    for arch in ("qwen3-4b", "mamba2-130m"):
        r = serve(arch, smoke=True, batch=4, prompt_len=64, gen_tokens=24)
        print(
            f"{arch:<16} prefill {r['prefill_s']*1e3:8.1f} ms   "
            f"decode {r['decode_tok_per_s']:8.1f} tok/s   "
            f"sample: {r['generated'][0][:8].tolist()}"
        )


if __name__ == "__main__":
    main()
