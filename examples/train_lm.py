"""End-to-end driver: train a ~100M-parameter llama3-family model for a
few hundred steps with the production loop (sharded jit step, resumable
synthetic data, async checkpoints, straggler watchdog, auto-resume).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param llama3-family config (CPU-trainable)
    base = get_config("llama3.2-3b")
    cfg100m = dataclasses.replace(
        base,
        name="llama3-100m",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=1536,
        vocab_size=32000,
        tie_embeddings=True,
    )
    # register it so the launcher can find it
    import repro.configs as C

    C.ARCHS[cfg100m.name] = cfg100m

    losses = train(
        "llama3-100m",
        steps=args.steps,
        smoke=False,
        global_batch=4,
        seq_len=128,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        lr=3e-3,
    )
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
