"""Quickstart: simulate a random quantum circuit amplitude with the
lifetime-based contraction engine, check it against the statevector
oracle, then draw correlated bitstring samples from one batched
contraction (the paper's sampling workload).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import sample_bitstrings, simulate_amplitude
from repro.quantum import statevector
from repro.quantum.circuits import random_1d_circuit


def main() -> None:
    circuit = random_1d_circuit(n=10, cycles=8, seed=42)
    bitstring = "0110100101"

    result = simulate_amplitude(
        circuit,
        bitstring,
        target_dim=5,          # memory bound: no tensor above 2^5 entries
        method="lifetime",     # the paper's Algorithm 1 (+ tuning/merging)
    )
    ref = statevector.amplitude(circuit, bitstring)

    print("planner report :", result.report.row())
    print("amplitude      :", complex(result.value))
    print("statevector ref:", ref)
    print("|error|        :", abs(complex(result.value) - ref))
    assert abs(complex(result.value) - ref) < 1e-4
    print("OK")

    # batch sampling: hold 3 output qubits open → one contraction yields
    # all 8 correlated amplitudes; draw bitstrings by frequency sampling
    samples = sample_bitstrings(
        circuit,
        num_samples=100,
        open_qubits=(7, 8, 9),
        target_dim=5,
    )
    print("sampled        :", samples.bitstrings[:5], "...")
    print("sampled XEB    :", f"{samples.xeb:+.4f}")


if __name__ == "__main__":
    main()
