"""Quickstart: simulate a random quantum circuit amplitude with the
lifetime-based contraction engine, check it against the statevector
oracle, then draw correlated bitstring samples from one batched
contraction (the paper's sampling workload).

    PYTHONPATH=src python examples/quickstart.py [--backend {einsum,gemm}]

``--backend gemm`` executes the lowered kernel schedule (every tree node
normalized to GEMM form and refined onto Pallas/dot/einsum — see
``src/repro/lowering/``) instead of the einsum oracle path.
"""

import argparse
import time

from repro.core import sample_bitstrings, simulate_amplitude
from repro.quantum import statevector
from repro.quantum.circuits import random_1d_circuit


def _timed(fn) -> float:
    t0 = time.perf_counter()
    import numpy as np

    np.asarray(fn())  # block until the device result is materialized
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("einsum", "gemm"), default=None,
                    help="execution backend (default: $REPRO_BACKEND or "
                    "einsum)")
    args = ap.parse_args()

    circuit = random_1d_circuit(n=10, cycles=8, seed=42)
    bitstring = "0110100101"

    result = simulate_amplitude(
        circuit,
        bitstring,
        target_dim=5,          # memory bound: no tensor above 2^5 entries
        method="lifetime",     # the paper's Algorithm 1 (+ tuning/merging)
        backend=args.backend,
    )
    ref = statevector.amplitude(circuit, bitstring)

    print("planner report :", result.report.row())
    if result.plan is not None and result.plan.schedule is not None:
        print("lowered sched  :", result.plan.schedule.summary_row())
    if result.plan is not None:
        print("two-phase      :", result.plan.hoist_summary())
    print("amplitude      :", complex(result.value))
    print("statevector ref:", ref)
    print("|error|        :", abs(complex(result.value) - ref))
    assert abs(complex(result.value) - ref) < 1e-4
    print("OK")

    # a second request for the same circuit family hits the plan cache
    result2 = simulate_amplitude(
        circuit, "1001011010", target_dim=5, backend=args.backend
    )
    print("repeat request :", result2.report.row(),
          f"(plan {result2.report.plan_wall_s*1e3:.2f}ms)")

    # hoisting summary: invariant fraction, slices, measured speedup of
    # two-phase execution over the naive full-tree-per-slice path, timed
    # directly on the compiled plan (planning/conversion out of the loop)
    rep = result2.report
    from repro.core.executor import simplify_network
    from repro.quantum.circuits import circuit_to_network

    tn, arrays = simplify_network(
        *circuit_to_network(circuit, bitstring="1001011010")
    )
    plan = result2.plan
    times = {}
    for hoist in (False, True):
        plan.contract_all(arrays, hoist=hoist)  # compile
        times[hoist] = min(
            _timed(lambda: plan.contract_all(arrays, hoist=hoist))
            for _ in range(5)
        )
    print(
        f"hoisting       : inv_frac={rep.invariant_fraction:.2f} "
        f"slices={1 << rep.num_sliced} "
        f"overhead {rep.slicing_overhead:.3f}->{rep.measured_overhead:.3f} "
        f"measured speedup={times[False] / times[True]:.2f}x "
        f"(REPRO_HOIST=0 disables)"
    )

    # lifetime-based memory plan: exact live-set peaks + buffer slots,
    # and the peak-aware slicer (slicing_mode="peak") which stops slicing
    # once the planned peak — not the width proxy — fits the budget
    mem = plan.memory_plan()
    res_peak = simulate_amplitude(
        circuit, "1001011010", target_dim=5, backend=args.backend,
        slicing_mode="peak", use_cache=False,
    )
    assert abs(complex(res_peak.value) - complex(result2.value)) < 1e-5
    print(
        f"memory plan    : peak={mem.peak_bytes}B "
        f"hoisted={mem.peak_bytes_hoisted}B slots={mem.buffer_slots} "
        f"peak-aware |S| {rep.num_sliced}->{res_peak.report.num_sliced}"
    )

    # mixed precision under an XEB budget: precision="auto" lets the
    # planner demote MXU-sized GEMM steps to bf16-input/fp32-accumulate
    # as long as the forward error model stays inside fidelity_tol.
    # This 1-D circuit is too small to carry Pallas steps, so every step
    # stays fp32 — the certified budget is reported either way.
    res_mp = simulate_amplitude(
        circuit, "1001011010", target_dim=5, backend=args.backend,
        precision="auto", fidelity_tol=0.05, use_cache=False,
    )
    counts = res_mp.report.precision_counts or {}
    print(
        f"precision      : mode={res_mp.report.precision} "
        f"tol={res_mp.report.fidelity_tol:g} "
        f"steps={counts or '{}'} "
        f"pred_amp_err={res_mp.report.predicted_amp_error:.2e}"
    )
    assert abs(complex(res_mp.value) - complex(result2.value)) < 1e-4

    # batch sampling: hold 3 output qubits open → one contraction yields
    # all 8 correlated amplitudes; draw bitstrings by frequency sampling
    samples = sample_bitstrings(
        circuit,
        num_samples=100,
        open_qubits=(7, 8, 9),
        target_dim=5,
        backend=args.backend,
    )
    print("sampled        :", samples.bitstrings[:5], "...")
    print("sampled XEB    :", f"{samples.xeb:+.4f}")


if __name__ == "__main__":
    main()
