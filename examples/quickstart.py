"""Quickstart: simulate a random quantum circuit amplitude with the
lifetime-based contraction engine and check it against the statevector
oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import simulate_amplitude
from repro.quantum import statevector
from repro.quantum.circuits import random_1d_circuit


def main() -> None:
    circuit = random_1d_circuit(n=10, cycles=8, seed=42)
    bitstring = "0110100101"

    result = simulate_amplitude(
        circuit,
        bitstring,
        target_dim=5,          # memory bound: no tensor above 2^5 entries
        method="lifetime",     # the paper's Algorithm 1 (+ tuning/merging)
    )
    ref = statevector.amplitude(circuit, bitstring)

    print("planner report :", result.report.row())
    print("amplitude      :", complex(result.value))
    print("statevector ref:", ref)
    print("|error|        :", abs(complex(result.value) - ref))
    assert abs(complex(result.value) - ref) < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
