import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh (16×16 single-pod / 2×16×16 multi-pod) and
record memory/cost/collective analysis for the roofline.

Must be run as a fresh process (the XLA_FLAGS line above precedes any jax
import — jax locks the device count on first init).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k --mesh single --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPES, cell_applicable, get_config
from ..obs import log as obs_log
from ..models import build_model
from ..parallel.sharding import (
    abstract_params,
    count_params,
    logical_shardings,
    param_shardings,
    resolve_spec,
)
from ..roofline.analysis import (
    active_param_count,
    analyze_compiled,
    model_flops,
)
from ..train import optimizer as opt
from ..train.train_step import (
    abstract_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    state_logical,
)
from .mesh import make_production_mesh
from .specs import input_specs

from jax.sharding import NamedSharding, PartitionSpec as P


def dryrun_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    moment_dtype: str = "float32",
    recipe: str | None = None,
) -> dict:
    cfg = get_config(arch)
    if recipe is None or recipe == "arch-default":
        recipe = cfg.sharding_recipe
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = build_model(cfg)
    defs = model.param_defs()
    n_params = count_params(defs)
    t0 = time.time()

    abs_in, log_in = input_specs(arch, shape_name)

    if shape.kind == "train":
        ocfg = opt.OptimizerConfig(moment_dtype=moment_dtype)
        step_fn = make_train_step(model, ocfg)
        st_abs = abstract_state(model, ocfg)
        st_sh = logical_shardings(
            st_abs, state_logical(model, ocfg), mesh, recipe
        )
        b_sh = logical_shardings(
            abs_in["batch"], log_in["batch"], mesh, recipe
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(st_abs, abs_in["batch"])
    elif shape.kind == "prefill":
        fn = make_prefill_step(model)
        p_sh = param_shardings(defs, mesh, recipe)
        b_sh = logical_shardings(
            abs_in["batch"], log_in["batch"], mesh, recipe
        )
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(abstract_params(defs), abs_in["batch"])
    else:  # decode
        fn = make_decode_step(model)
        p_sh = param_shardings(defs, mesh, recipe)
        c_sh = logical_shardings(
            abs_in["cache"], log_in["cache"], mesh, recipe
        )
        t_sh = logical_shardings(
            abs_in["tokens"], log_in["tokens"], mesh, recipe
        )
        pos_sh = NamedSharding(mesh, P())
        args = [
            abstract_params(defs),
            abs_in["cache"],
            abs_in["tokens"],
            abs_in["pos"],
        ]
        in_sh = [p_sh, c_sh, t_sh, pos_sh]
        if cfg.mrope:
            args.append(abs_in["mrope_positions"])
            in_sh.append(
                logical_shardings(
                    abs_in["mrope_positions"], log_in["mrope_positions"],
                    mesh, recipe,
                )
            )
        jitted = jax.jit(
            fn,
            in_shardings=tuple(in_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(*args)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = analyze_compiled(compiled, n_dev)
    n_active = active_param_count(cfg, n_params)
    mflops = model_flops(cfg, shape, n_active)
    summary = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "params": n_params,
        "active_params": n_active,
        "moment_dtype": moment_dtype,
        "recipe": recipe,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": roof.summary(),
        "model_flops_global": mflops,
        "useful_ratio": (
            mflops / (roof.flops * n_dev) if roof.flops else None
        ),
    }
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--moments", default="float32", choices=["float32", "int8"])
    ap.add_argument("--recipe", default="arch-default")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.mesh}"
    if args.moments != "float32":
        tag += f"__m{args.moments}"
    if args.recipe not in ("default", "arch-default"):
        tag += f"__r{args.recipe}"
    path = os.path.join(args.out, tag + ".json")
    try:
        res = dryrun_cell(
            args.arch,
            args.shape,
            multi_pod=(args.mesh == "multi"),
            moment_dtype=args.moments,
            recipe=args.recipe,
        )
    except Exception as e:
        res = {
            "arch": args.arch,
            "shape": args.shape,
            "mesh": args.mesh,
            "error": repr(e),
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    if "error" in res:
        obs_log.error(f"FAIL {tag}: {res['error']}", tag=tag)
        raise SystemExit(1)
    if "skipped" in res:
        obs_log.info(f"SKIP {tag}: {res['skipped']}", tag=tag)
        return
    r = res["roofline"]
    obs_log.info(
        f"OK {tag}: compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
        f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
        f"useful={res['useful_ratio'] and round(res['useful_ratio'],3)} "
        f"compile={res['compile_s']}s",
        tag=tag,
    )


if __name__ == "__main__":
    main()
