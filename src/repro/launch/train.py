"""Production-shaped training driver.

Demonstrates the full loop on any mesh (including 1 CPU device): sharded
jit train step, deterministic resumable data, async atomic checkpoints,
auto-resume from the latest checkpoint, and a straggler watchdog (EMA
step-time monitor that flags and logs slow steps — at cluster scale this
is the hook that triggers slice re-execution / hot-spare swap).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..obs import log as obs_log
from ..configs import get_config, smoke_shrink
from ..data.pipeline import SyntheticTextDataset
from ..models import build_model
from ..parallel.sharding import logical_shardings, param_shardings
from ..train import optimizer as opt
from ..train.train_step import (
    TrainState,
    abstract_state,
    init_state,
    make_train_step,
    state_logical,
)
from .mesh import make_host_mesh


class StragglerWatchdog:
    """EMA step-time monitor; at scale the callback re-enqueues the step's
    batch (safe: the pipeline is deterministic per step index)."""

    def __init__(self, threshold: float = 3.0, decay: float = 0.9):
        self.ema: float | None = None
        self.threshold = threshold
        self.decay = decay
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else (
            self.decay * self.ema + (1 - self.decay) * dt
        )
        if slow:
            self.flagged.append(step)
        return slow


def train(
    arch: str,
    steps: int = 100,
    smoke: bool = True,
    global_batch: int = 8,
    seq_len: int = 128,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    mesh_shape: tuple[int, ...] = (),
    log_every: int = 10,
    seed: int = 0,
    lr: float = 1e-3,
    schedule_steps: int | None = None,
):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_shrink(cfg)
    model = build_model(cfg)
    sched = schedule_steps or steps
    ocfg = opt.OptimizerConfig(
        learning_rate=lr, warmup_steps=min(20, sched // 5 + 1),
        total_steps=sched,
    )
    n_dev = len(jax.devices())
    if not mesh_shape:
        mesh_shape = (n_dev, 1)
    mesh = make_host_mesh(mesh_shape, ("data", "model")[: len(mesh_shape)])

    ds = SyntheticTextDataset(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        embed_dim=cfg.d_model if (cfg.embed_inputs or cfg.is_encdec) else 0,
        mrope=cfg.mrope,
    )
    if cfg.embed_inputs and not cfg.is_encdec:
        sample = {k: v for k, v in ds.batch(0).items() if k != "tokens"}
    else:
        sample = ds.batch(0)

    st_abs = abstract_state(model, ocfg)
    st_sh = logical_shardings(st_abs, state_logical(model, ocfg), mesh)
    b_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), sample
    )
    b_log = {
        k: (("dp",) + (None,) * (v.ndim - 1))
        if k != "positions"
        else (None, "dp", None)
        for k, v in sample.items()
    }
    b_sh = logical_shardings(b_abs, b_log, mesh)

    step_fn = jax.jit(
        make_train_step(model, ocfg),
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and mgr.latest_step() is not None:
        template = jax.tree.map(np.zeros_like, jax.eval_shape(
            lambda: init_state(model, ocfg, jax.random.PRNGKey(seed))
        ))
        state = mgr.restore(template, shardings=st_sh)
        start_step = int(np.asarray(state.step))
        obs_log.info(f"resumed from step {start_step}", step=start_step)
    else:
        state = init_state(model, ocfg, jax.random.PRNGKey(seed))
        state = jax.device_put(state, st_sh)

    dog = StragglerWatchdog()
    losses = []
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in
                 (sample if step == 0 else (
                     {kk: vv for kk, vv in ds.batch(step).items()
                      if kk in sample})).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if dog.observe(step, dt):
            obs_log.warning(
                f"[watchdog] step {step} slow: {dt:.2f}s (ema {dog.ema:.2f}s)",
                step=step, dt_s=dt, ema_s=dog.ema,
            )
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            obs_log.info(
                f"step {step:5d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):7.3f} "
                f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms",
                step=step, loss=loss, dt_s=dt,
            )
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state)
    if mgr:
        mgr.save(steps, state, blocking=True)
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    losses = train(
        args.arch,
        steps=args.steps,
        smoke=args.smoke,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        lr=args.lr,
    )
    obs_log.info(f"first loss {losses[0]:.4f} → last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
