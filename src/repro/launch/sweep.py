"""Run the full dry-run matrix as subprocesses (fresh process per cell —
XLA device-count flags are locked at first jax init), skipping cells whose
JSON already exists.  Order: single-pod first (roofline table), smallest
architectures first (early signal)."""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from ..obs import log as obs_log

ORDER = [
    "mamba2-130m",
    "seamless-m4t-medium",
    "llama3.2-3b",
    "qwen3-4b",
    "zamba2-7b",
    "deepseek-7b",
    "deepseek-moe-16b",
    "qwen2-vl-72b",
    "llama4-scout-17b-a16e",
    "llama3-405b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)
    todo = []
    for mesh in meshes:
        for arch in ORDER:
            for shape in SHAPES:
                todo.append((arch, shape, mesh))
    env = dict(os.environ, PYTHONPATH="src")
    n_ok = n_fail = n_skip = 0
    for arch, shape, mesh in todo:
        tag = f"{arch}__{shape}__{mesh}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                prev = json.load(f)
            if "error" not in prev:
                obs_log.info(f"CACHED {tag}", tag=tag)
                continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh,
            "--out", args.out,
        ]
        extra = []
        if arch == "llama3-405b" and shape == "train_4k":
            extra = ["--moments", "int8"]  # fp32 variant run separately
        try:
            r = subprocess.run(
                cmd + extra, env=env, timeout=args.timeout,
                capture_output=True, text=True, cwd=os.getcwd(),
            )
            out = (r.stdout + r.stderr).strip().splitlines()
            obs_log.info(out[-1] if out else f"?? {tag}", tag=tag)
            if r.returncode == 0:
                n_ok += 1
            else:
                n_fail += 1
        except subprocess.TimeoutExpired:
            obs_log.warning(f"TIMEOUT {tag}", tag=tag)
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "error": "compile timeout"}, f)
            n_fail += 1
    obs_log.info(f"done: ok={n_ok} fail={n_fail}", ok=n_ok, fail=n_fail)


if __name__ == "__main__":
    main()
