"""Batched LLM-decode demo: prefill a batch of prompts, then decode.

Demonstrates the model-stack inference path on any mesh (including 1 CPU
device): jitted prefill + decode with a persistent KV/SSM cache, greedy
sampling, and tokens/s accounting.  (This used to live at
``repro.launch.serve``; that entry point now serves *contractions* —
the paper workload — via :mod:`repro.engine.server`.)

    PYTHONPATH=src python -m repro.launch.decode_demo --arch qwen3-4b \
        --smoke --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_shrink
from ..obs import log as obs_log
from ..models import build_model
from ..parallel.sharding import init_params
from ..train.train_step import make_decode_step, make_prefill_step


def serve(
    arch: str,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    seed: int = 0,
):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_shrink(cfg)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = init_params(model.param_defs(), key)

    max_len = prompt_len + gen_tokens
    # window archs need the ring alignment: round max_len to the window
    if cfg.window:
        max_len = -(-max_len // cfg.window) * cfg.window

    b = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                      cfg.vocab_size)}
    if cfg.is_encdec or cfg.embed_inputs:
        b["embeds"] = jax.random.normal(
            key, (batch, prompt_len, cfg.d_model), jnp.float32
        )
        if not cfg.is_encdec:
            pass  # decoder-only embed-input archs still decode over tokens
    if cfg.mrope:
        b["positions"] = jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32), (3, batch, prompt_len)
        )

    prefill = jax.jit(
        lambda p, bb: model.prefill(p, bb, max_len=max_len)
    )
    decode = jax.jit(make_decode_step(model))

    t0 = time.perf_counter()
    cache, logits = prefill(params, b)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [np.asarray(tokens)]
    t0 = time.perf_counter()
    for i in range(gen_tokens - 1):
        pos = jnp.int32(prompt_len + i)
        mrope = (
            jnp.full((3, batch, 1), prompt_len + i, jnp.int32)
            if cfg.mrope
            else None
        )
        logits, cache = decode(params, cache, tokens, pos, mrope)
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate(outs, axis=1)
    toks_per_s = batch * (gen_tokens - 1) / max(t_decode, 1e-9)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": toks_per_s,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    r = serve(
        args.arch,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen_tokens=args.gen,
    )
    obs_log.info(
        f"prefill {r['prefill_s']*1e3:.1f} ms, decode {r['decode_s']*1e3:.1f} ms"
        f" → {r['decode_tok_per_s']:.1f} tok/s",
        prefill_s=r["prefill_s"], decode_s=r["decode_s"],
    )
    obs_log.info(f"sample: {r['generated'][0][:16]}")


if __name__ == "__main__":
    main()
