"""Contraction-serving CLI: fire a mixed tenant burst at the engine.

Launch driver for :class:`repro.engine.server.EngineServer` — the
multi-tenant contraction-as-a-service layer.  Submits a burst of
amplitude requests (bitstrings varying on the last ``--vary`` qubits, so
the server can coalesce them into open-qubit batch contractions) plus a
few correlated-sampling tenants against one circuit family, then prints
per-request queue/compute latencies and the server's coalescing
counters.  The second burst of a run is the warm path: the family's
plan is cached, so it shows the serving speedup the plan cache buys.

    PYTHONPATH=src python -m repro.launch.serve --rows 3 --cols 3 \
        --cycles 8 --amps 12 --samples 2 --target-dim 12
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..engine import AmplitudeRequest, EngineServer, SampleRequest
from ..obs import log as obs_log
from ..quantum.circuits import sycamore_like


def _burst(
    srv: EngineServer,
    circuit,
    n_amps: int,
    n_samples: int,
    target_dim: int,
    vary: int,
    seed: int = 0,
):
    """Submit one mixed burst and wait for every ticket."""
    n = circuit.num_qubits
    rng = np.random.default_rng(seed)
    tickets = []
    for i in range(n_amps):
        tail = rng.integers(0, 2, size=min(vary, n))
        bits = ["0"] * n
        for j, b in enumerate(tail):
            bits[n - len(tail) + j] = str(int(b))
        tickets.append(
            srv.submit(
                AmplitudeRequest(
                    circuit, "".join(bits), target_dim=target_dim
                )
            )
        )
    for i in range(n_samples):
        tickets.append(
            srv.submit(
                SampleRequest(
                    circuit,
                    num_samples=256,
                    target_dim=target_dim,
                    seed=seed + i,
                )
            )
        )
    t0 = time.perf_counter()
    for t in tickets:
        t.result(timeout=600)
    wall = time.perf_counter() - t0
    return tickets, wall


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serve amplitude/sampling traffic on the engine"
    )
    ap.add_argument("--rows", type=int, default=3)
    ap.add_argument("--cols", type=int, default=3)
    ap.add_argument("--cycles", type=int, default=8)
    ap.add_argument("--target-dim", type=int, default=12)
    ap.add_argument("--amps", type=int, default=12,
                    help="amplitude requests per burst")
    ap.add_argument("--samples", type=int, default=2,
                    help="sampling requests per burst")
    ap.add_argument("--vary", type=int, default=4,
                    help="qubits the amplitude bitstrings vary on")
    ap.add_argument("--bursts", type=int, default=2,
                    help="bursts to fire (first is cold, rest warm)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    circuit = sycamore_like(args.rows, args.cols, args.cycles,
                            seed=args.seed)
    with EngineServer(
        max_queue=args.max_queue,
        max_batch=args.max_batch,
        max_open=max(1, args.vary),
    ) as srv:
        for burst in range(args.bursts):
            tickets, wall = _burst(
                srv, circuit, args.amps, args.samples,
                args.target_dim, args.vary, seed=args.seed + burst,
            )
            lat = sorted(t.total_s for t in tickets)
            obs_log.info(
                f"burst {burst} ({'cold' if burst == 0 else 'warm'}): "
                f"{len(tickets)} requests in {wall:.2f}s "
                f"({len(tickets)/max(wall, 1e-9):.1f} req/s), "
                f"p50 {lat[len(lat)//2]*1e3:.0f} ms, "
                f"max {lat[-1]*1e3:.0f} ms",
                burst=burst, wall_s=wall,
            )
        st = srv.stats()
    obs_log.info(
        f"served {st['completed']} ok / {st['failed']} failed / "
        f"{st['rejected']} rejected; {st['coalesced']} coalesced over "
        f"{st['groups']} groups ({st['warm_families']} warm families)",
        **{k: st[k] for k in ("completed", "coalesced", "groups")},
    )


if __name__ == "__main__":
    main()
