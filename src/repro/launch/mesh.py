"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host
devices *before* any jax initialization.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit/auto axis types; older jax has neither
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:

    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small meshes for tests/examples (must divide available devices)."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def multi_host_mesh(axis_name: str = "data"):
    """One flat mesh over every *global* device of a ``jax.distributed``
    run — the data-parallel axis the multi-host transport reduces over.

    Call :func:`repro.distributed.init_multi_host` first in an N-process
    launch; at world size 1 this degenerates to a mesh over the local
    devices, so the same code path serves both (the world-size-1
    invariance contract)."""
    import numpy as np
    from jax.sharding import Mesh

    devices = np.asarray(jax.devices())
    return Mesh(devices, (axis_name,), **_axis_kwargs(1))


def main(argv=None) -> int:
    """CI smoke entry point: ``python -m repro.launch.mesh`` prints this
    process's world view and proves a cross-process psum round-trips.
    Run as N plain subprocesses with ``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` set (no mpirun)."""
    import argparse

    from ..distributed.transport import init_multi_host

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("--coordinator", default=None)
    p.add_argument("--num-processes", type=int, default=None)
    p.add_argument("--process-id", type=int, default=None)
    args = p.parse_args(argv)
    rank, size = init_multi_host(
        args.coordinator, args.num_processes, args.process_id
    )
    mesh = multi_host_mesh()
    from ..distributed.transport import CollectiveTransport

    tp = CollectiveTransport(mesh=mesh, chunks=1)
    tp.rounds = 1
    import numpy as np

    tp.push(np.asarray([float(rank + 1)], dtype=np.float32))
    total = tp.finalize()
    expect = size * (size + 1) / 2
    ok = total is not None and float(total[0]) == expect
    print(
        f"mesh-smoke rank={rank}/{size} devices={len(jax.devices())} "
        f"psum={float(total[0]) if total is not None else None} "
        f"{'OK' if ok else 'FAIL'}"
    )
    return 0 if ok else 1


if __name__ == "__main__":  # pragma: no cover - exercised by CI leg
    raise SystemExit(main())
