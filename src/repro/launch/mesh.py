"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host
devices *before* any jax initialization.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit/auto axis types; older jax has neither
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:

    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small meshes for tests/examples (must divide available devices)."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))
