"""ShapeDtypeStruct stand-ins + logical shardings for every model input.

``input_specs(arch, shape)`` returns, per the cell's kind:

  train:   {"batch": {...}}                         → train_step(state, batch)
  prefill: {"batch": {...}}                         → prefill(params, batch)
  decode:  {"cache": {...}, "tokens": …, "pos": …}  → decode_step(...)

plus a parallel tree of *logical* axis tuples (resolved against the active
mesh by parallel.sharding.resolve_spec).  No array is ever allocated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config
from ..models import build_model


def _tok(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(arch: str, shape_name: str):
    """Returns (abstract_inputs, logical_shardings) dicts."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    model = build_model(cfg)

    if sh.kind in ("train", "prefill"):
        batch: dict = {}
        logical: dict = {}
        if cfg.family == "encdec":
            batch["embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16
            )
            logical["embeds"] = ("dp", None, None)
            batch["tokens"] = _tok((B, S))
            logical["tokens"] = ("dp", None)
        elif cfg.embed_inputs:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16
            )
            logical["embeds"] = ("dp", None, None)
        else:
            batch["tokens"] = _tok((B, S))
            logical["tokens"] = ("dp", None)
        if cfg.mrope:
            batch["positions"] = _tok((3, B, S))
            logical["positions"] = (None, "dp", None)
        if sh.kind == "train":
            batch["labels"] = _tok((B, S))
            logical["labels"] = ("dp", None)
        return {"batch": batch}, {"batch": logical}

    # decode: cache + one token
    cache_spec = model.cache_spec(B, S)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(
        x[0], jax.ShapeDtypeStruct
    )
    cache_abs = jax.tree.map(lambda t: t[0], cache_spec, is_leaf=is_pair)
    cache_log = jax.tree.map(lambda t: t[1], cache_spec, is_leaf=is_pair)
    # "layer" axis is never sharded
    cache_log = jax.tree.map(
        lambda log: tuple(None if a == "layer" else a for a in log),
        cache_log,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    out = {
        "cache": cache_abs,
        "tokens": _tok((B, 1)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    log = {
        "cache": cache_log,
        "tokens": ("dp", None),
        "pos": (),
    }
    if cfg.mrope:
        out["mrope_positions"] = _tok((3, B, 1))
        log["mrope_positions"] = (None, "dp", None)
    return out, log
