"""Architecture-aware path refinement: branch merging (Sec. V-B) and GEMM
orientation (Sec. V-C), adapted from Sunway SW26010pro to TPU v5e.

A pairwise contraction is a GEMM: kept indices of the stem tensor form M,
kept indices of the branch form N, contracted indices form K.  Narrow GEMMs
(tiny N or K — ubiquitous on RQC stems, the paper observes k,n ≤ 4) fall
off the roofline on *any* wide-vector machine.  On Sunway the culprits are
the 8×8 SWTT kernel + DMA bandwidth (critical intensity 42.96 F/B); on TPU
v5e they are MXU 128×128 tile quantization + HBM bandwidth (critical
intensity 197e12/819e9 ≈ 240 F/B — narrow GEMMs hurt *more*).

``F(M, N, K)`` below is the TPU efficiency surface: achievable/peak FLOPs
for a bf16 GEMM, modelled as MXU tile quantization capped by the HBM
roofline.  ``surface="sunway"`` reproduces the paper's machine model
(8-lane kernel quantization, 42.96 F/B) for the faithful-baseline
benchmarks.

Branch merging pre-contracts two neighbouring branches when the modelled
time (complexity / F, summed over slice multipliers, Eq. 10 generalized)
drops.  All improving merges are applied until a fixed point, as in the
paper.
"""

from __future__ import annotations

import dataclasses

from .contraction_tree import ContractionTree
from .lifetime import Stem, detect_stem
from .tensor_network import popcount

# hardware constants (TPU v5e)
TPU_PEAK_FLOPS = 197e12  # bf16
TPU_HBM_BW = 819e9  # bytes/s
TPU_MXU = 128  # systolic tile

SUNWAY_PEAK_FLOPS = 2.2e12  # per CG, paper Sec. V-A
SUNWAY_DMA_BW = 51.2e9
SUNWAY_LANE = 8  # SWTT 8x8 kernel


def gemm_efficiency(
    m: float, n: float, k: float, surface: str = "tpu"
) -> float:
    """F(M,N,K): fraction of peak for a (2^m × 2^k) @ (2^k × 2^n) GEMM.

    Tile-quantization × bandwidth-roofline model; arguments are log2 dims.
    """
    M, N, K = 2.0 ** m, 2.0 ** n, 2.0 ** k
    if surface == "tpu":
        tile, peak, bw, dtype_bytes = TPU_MXU, TPU_PEAK_FLOPS, TPU_HBM_BW, 2.0
    elif surface == "sunway":
        tile, peak, bw, dtype_bytes = (
            SUNWAY_LANE,
            SUNWAY_PEAK_FLOPS,
            SUNWAY_DMA_BW,
            4.0,
        )
    else:
        raise ValueError(surface)

    def ceil_to(x: float, t: float) -> float:
        import math

        return max(t, math.ceil(x / t) * t)

    flops = 2.0 * M * N * K
    flops_padded = 2.0 * ceil_to(M, tile) * ceil_to(N, tile) * ceil_to(K, tile)
    t_compute = flops_padded / peak
    t_mem = dtype_bytes * (M * K + K * N + M * N) / bw
    t = max(t_compute, t_mem)
    return flops / (t * peak)


def contraction_gemm_shape(
    tree: ContractionTree, v: int
) -> tuple[int, int, int]:
    """(m, n, k) log2 GEMM dims of contraction node ``v``: M = kept of the
    bigger child, N = kept of the smaller, K = contracted."""
    l, r = tree.children[v]
    ml, mr = tree.emask[l], tree.emask[r]
    if popcount(ml) < popcount(mr):
        ml, mr = mr, ml
    open_m = tree.tn.open_mask
    shared = ml & mr & ~open_m
    k = popcount(shared)
    m = popcount(ml) - k
    n = popcount(mr) - k
    return m, n, k


def modeled_node_time(
    tree: ContractionTree, v: int, S: int, surface: str = "tpu",
    slice_fused: bool = False, slice_batched: bool = False,
) -> float:
    """Modelled wall time of node ``v``: 2^(|S| - |S∩nm|) repetitions of a
    sliced GEMM at F(M,N,K) efficiency.

    ``slice_fused`` (beyond-paper, §Perf): when a sliced index is
    *contracted* at this node (present in both children), the per-slice
    sum  C = Σ_s A_s·B_s  is algebraically one GEMM with the slice group
    concatenated along K — so the node runs at the efficiency of the
    UNSLICED K while doing identical FLOPs.  Narrow-K stems (the paper's
    Sec. V-A pathology, worse on the 128-wide MXU) get their K back.
    """
    nm = tree.node_mask(v)
    l, r = tree.children[v]
    ml, mr = tree.emask[l], tree.emask[r]
    if popcount(ml) < popcount(mr):
        ml, mr = mr, ml
    open_m = tree.tn.open_mask
    shared = ml & mr & ~open_m
    k_s = popcount(shared & ~S)
    m_s = popcount(ml & ~S) - k_s
    n_s = popcount(mr & ~S) - k_s
    fused_bits = popcount(shared & S) if slice_fused else 0
    mult = 2.0 ** (popcount(S) - popcount(S & nm))
    flops = 2.0 ** (m_s + n_s + k_s + fused_bits + 1)
    if slice_fused:
        mult /= 2.0 ** fused_bits  # the fused group runs as one GEMM
    # slice batching (beyond-paper, implemented by the executor's vmap):
    # when the absorbed operand carries no sliced index (branches "carry
    # few or zero sliced indices", Sec. III-D) every subtask shares the
    # stationary operand — the subtask group is one GEMM with the slice
    # batch concatenated along M.
    m_batch = 0.0
    if slice_batched and mult > 1 and (mr & S) == 0:
        import math

        m_batch = math.log2(mult)
    peak = TPU_PEAK_FLOPS if surface == "tpu" else SUNWAY_PEAK_FLOPS
    eff = gemm_efficiency(m_s + m_batch, n_s, k_s + fused_bits, surface)
    return mult * flops / (eff * peak)


def modeled_tree_time(
    tree: ContractionTree, S: int, surface: str = "tpu",
    slice_fused: bool = False, slice_batched: bool = False,
) -> float:
    """Σ over nodes of modeled_node_time (absolute seconds for one pass
    over all slices on one chip)."""
    return sum(
        modeled_node_time(tree, v, S, surface, slice_fused, slice_batched)
        for v in tree.children
    )


@dataclasses.dataclass
class MergeResult:
    tree: ContractionTree
    merges: int
    time_before: float
    time_after: float


def merge_branches(
    tree: ContractionTree,
    S: int,
    surface: str = "tpu",
    max_passes: int = 10,
) -> MergeResult:
    """Apply all time-improving branch merges on the stem (Eq. 10
    generalized to the modelled F surface), repeating until fixed point."""
    work = tree.copy()
    t_before = modeled_tree_time(work, S, surface)
    merges = 0
    for _ in range(max_passes):
        stem = detect_stem(work)
        did = 0
        for i in range(len(stem.nodes) - 1):
            args = stem.exchange_args(i)  # same adjacency requirements
            if args is None:
                continue
            p, q, branch_q, branch_p = args
            # adjacency may be stale after an earlier merge in this sweep
            if work.parent.get(q) != p:
                continue
            if branch_q not in work.children.get(q, ()) or (
                branch_p not in work.children.get(p, ())
            ):
                continue
            before = modeled_node_time(work, p, S, surface) + modeled_node_time(
                work, q, S, surface
            )
            snapshot = work.copy()
            mid = work.merge_branches_at(p, q, branch_q, branch_p)
            after = modeled_node_time(work, p, S, surface) + modeled_node_time(
                work, mid, S, surface
            )
            if after < before:
                did += 1
            else:
                work = snapshot
        merges += did
        if did == 0:
            break
    return MergeResult(work, merges, t_before, modeled_tree_time(work, S, surface))


def orient_gemms(tree: ContractionTree) -> ContractionTree:
    """Sec. V-C analogue: order every node's children so the larger tensor
    takes the M role (stationary operand) — keeps stem GEMMs 'uphill'
    (N ≥ K) when executed end-to-end in post-order."""
    work = tree.copy()
    for v in list(work.children):
        l, r = work.children[v]
        if popcount(work.emask[l]) < popcount(work.emask[r]):
            work.children[v] = (r, l)
    return work
