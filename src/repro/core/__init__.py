"""Lifetime-based tensor-network contraction planning + sliced execution.

The paper's contribution lives here:
  tensor_network  — graph representation (bitmask index algebra)
  contraction_tree— W(B), C(B), C(B,S) (Eqs. 2/3/6) + tree surgery
  lifetime        — lifetime/correlated contractions/stem (Defs. 1-2, Thm. 1)
  slicing         — sliceFinder (Alg. 1), greedy baseline, interval-optimal
  tuning          — branch exchange + tuningSliceFinder (Alg. 2)
  merging         — branch merging under the TPU F(M,N,K) surface (Sec. V)
  pathfinder      — contraction-order search (greedy/partition/DP oracle)
  executor        — jitted sliced contraction (vmap slice batching,
                    open-index amplitude batches, einsum + lowered-GEMM
                    backends via repro.lowering)
  distributed     — shard_map slice parallelism + psum (the one all-reduce)
  api             — end-to-end pipeline + PlanReport; sample_bitstrings
                    (batched correlated-amplitude sampling, Sec. VI)
"""

from .api import (  # noqa: F401
    PlanReport,
    SimulationResult,
    draw_from_batch,
    open_amplitude_batch,
    open_session,
    plan_compiled,
    plan_contraction,
    sample_bitstrings,
    simulate_amplitude,
)
from .contraction_tree import ContractionTree  # noqa: F401
from .executor import (  # noqa: F401
    ContractionPlan,
    default_backend,
    default_hoist,
    simplify_network,
)
from .lifetime import Stem, detect_stem  # noqa: F401
from .slicing import find_slices, greedy_slicer, interval_optimal_slicer, slice_finder  # noqa: F401
from .tensor_network import TensorNetwork  # noqa: F401
from .tuning import tuning_slice_finder  # noqa: F401
