"""Slicing-set selection (Sec. IV).

Three strategies, all returning an index bitmask ``S``:

* :func:`slice_finder` — the paper's Algorithm 1.  In-place, lifetime-guided:
  repeatedly take the *smallest dimension-exceeded* stem tensor, slice its
  longest-lifetime indices until it fits, peel fitted tensors off the stem
  ends, repeat.  One pass over stem indices — this is what gives the
  100-200x planner speedup over repeated greedy.

* :func:`greedy_slicer` — the Cotengra-style baseline: repeatedly add the
  single index that minimizes the post-slice total cost (Eq. 6), optionally
  restarted ``repeats`` times with randomized tie-breaking, keeping the
  best.  Implemented with the same incremental cost trick cotengra uses so
  the comparison is fair.

* :func:`interval_optimal_slicer` — beyond-paper: on the stem-interval
  relaxation (every lifetime ∩ stem is a contiguous interval, demands
  ``dim_i - t`` per position), the farthest-right-endpoint sweep is provably
  minimal.  Used to verify the paper's "smallest slicing set" claim.

All strategies are followed by :func:`ensure_width` which tops up ``S``
greedily until the *whole tree* satisfies the memory bound (the paper notes
stems occasionally miss a huge off-stem tensor).

Beyond the width proxy, :func:`refine_slices_for_peak` (the
``mode="peak"`` leg of :func:`find_slices`) re-judges the finished mask
against the *planned live-set peak* from :mod:`repro.lowering.memory`:
the width bound must conservatively assume several width-sized tensors
are simultaneously live, so once the schedule's true peak is known,
slicing can stop earlier — indices whose removal keeps the planned peak
within the byte budget are pruned, shrinking ``2^|S|`` (a direct
multiplicative saving on ``contract_all``, Eq. 4).
"""

from __future__ import annotations

import random

from .contraction_tree import ContractionTree
from .lifetime import Stem, detect_stem
from .tensor_network import bits, popcount


# ----------------------------------------------------------------------
# Algorithm 1 — sliceFinder
# ----------------------------------------------------------------------
def slice_finder(
    tree: ContractionTree,
    target_dim: int,
    stem: Stem | None = None,
) -> int:
    """Paper Algorithm 1 (in-place slicing on the stem)."""
    if stem is None:
        stem = detect_stem(tree)
    open_m = tree.tn.open_mask
    # M: dimension-exceeded stem tensors, in stem order (contiguity holds:
    # dropping tensors only shortens stem-scoped lifetimes).
    masks = [m for m in stem.masks() if popcount(m) > target_dim]
    S = 0
    guard = 0
    while masks:
        guard += 1
        if guard > 10_000:  # pragma: no cover - safety valve
            break
        # stem-scoped lifetimes of currently sliceable indices
        lo: dict[int, int] = {}
        hi: dict[int, int] = {}
        for pos, m in enumerate(masks):
            for b in bits(m & ~open_m):
                if b not in lo:
                    lo[b] = pos
                hi[b] = pos
        lf = {b: hi[b] - lo[b] + 1 for b in lo}
        dims = [popcount(m) for m in masks]
        exceeded = [i for i, d in enumerate(dims) if d > target_dim]
        if not exceeded:
            break
        k = min(exceeded, key=lambda i: dims[i])
        while dims[k] > target_dim:
            cand = list(bits(masks[k] & ~open_m))
            if not cand:
                break  # only open indices left; ensure_width must finish
            b = max(cand, key=lambda b_: (lf.get(b_, 1), b_))
            S |= 1 << b
            bm = ~(1 << b)
            for i in range(lo.get(b, 0), hi.get(b, len(masks) - 1) + 1):
                if masks[i] & (1 << b):
                    masks[i] &= bm
                    dims[i] -= 1
        # peel fitted tensors from both ends (keeps M contiguous)
        while masks and popcount(masks[0]) <= target_dim:
            masks.pop(0)
        while masks and popcount(masks[-1]) <= target_dim:
            masks.pop()
        if not any(popcount(m) > target_dim for m in masks):
            break
    return S


# ----------------------------------------------------------------------
# Cotengra-style greedy baseline
# ----------------------------------------------------------------------
def greedy_slicer(
    tree: ContractionTree,
    target_dim: int,
    repeats: int = 1,
    seed: int = 0,
    temperature: float = 0.0,
) -> int:
    """Repeated greedy SliceFinder baseline (Cotengra's strategy).

    Each step evaluates *every* candidate index against the full Eq. 6 cost
    and takes the cheapest; restarts keep the best overall.  Intentionally
    the same cost structure as cotengra's SliceFinder so the Fig. 8 speed
    comparison is apples-to-apples.
    """
    rng = random.Random(seed)
    open_m = tree.tn.open_mask
    node_masks = [tree.node_mask(v) for v in tree.children]
    edge_masks = list(tree.emask.values())

    best_S = None
    best_cost = float("inf")
    for _ in range(max(1, repeats)):
        S = 0
        while True:
            width = max(popcount(m & ~S) for m in edge_masks)
            if width <= target_dim:
                break
            # candidates: indices of any still-exceeded tensor
            cand_mask = 0
            for m in edge_masks:
                if popcount(m & ~S) > target_dim:
                    cand_mask |= m
            cand_mask &= ~open_m & ~S
            cands = list(bits(cand_mask))
            if not cands:
                break
            # incremental Eq.6: base_v = 2^(|nm|-|S∩nm|); adding index i
            # doubles every node not containing i.
            total = 0.0
            per_index: dict[int, float] = {c: 0.0 for c in cands}
            for nm in node_masks:
                base = 2.0 ** (popcount(nm) - popcount(S & nm))
                total += base
                hit = nm & cand_mask
                for b in bits(hit):
                    per_index[b] += base
            scores = {c: 2.0 * total - per_index[c] for c in cands}
            lo = min(scores.values())
            if temperature > 0.0:
                pool = [c for c in cands if scores[c] <= lo * (1 + temperature)]
                choice = rng.choice(pool)
            else:
                choice = min(cands, key=lambda c: (scores[c], c))
            S |= 1 << choice
        c = tree.sliced_cost(S)
        if c < best_cost:
            best_cost, best_S = c, S
    return best_S if best_S is not None else 0


# ----------------------------------------------------------------------
# beyond-paper: interval-optimal slicing on the stem relaxation
# ----------------------------------------------------------------------
def interval_optimal_slicer(
    tree: ContractionTree,
    target_dim: int,
    stem: Stem | None = None,
) -> int:
    """Minimal slicing set under the stem-interval model.

    Every stem position ``i`` demands ``c_i = dim_i - t`` sliced indices
    among its own; lifetimes are intervals, so the classic sweep (when a
    position is deficient, add the available indices with the farthest
    right endpoint) is optimal by an exchange argument.
    """
    if stem is None:
        stem = detect_stem(tree)
    open_m = tree.tn.open_mask
    masks = stem.masks()
    n = len(masks)
    lo: dict[int, int] = {}
    hi: dict[int, int] = {}
    for pos, m in enumerate(masks):
        for b in bits(m & ~open_m):
            if b not in lo:
                lo[b] = pos
            hi[b] = pos
    S = 0
    for i in range(n):
        deficit = popcount(masks[i] & ~S) - target_dim
        if deficit <= 0:
            continue
        avail = [
            b
            for b in bits(masks[i] & ~open_m & ~S)
        ]
        avail.sort(key=lambda b: (hi[b], b), reverse=True)
        for b in avail[:deficit]:
            S |= 1 << b
    return S


# ----------------------------------------------------------------------
# global memory-bound guarantee
# ----------------------------------------------------------------------
def ensure_width(tree: ContractionTree, S: int, target_dim: int) -> int:
    """Greedy top-up until every tree tensor fits the bound (handles huge
    off-stem tensors the stem pass cannot see)."""
    open_m = tree.tn.open_mask
    edge_masks = list(tree.emask.values())
    node_masks = [tree.node_mask(v) for v in tree.children]
    guard = 0
    while True:
        guard += 1
        if guard > 5_000:  # pragma: no cover
            break
        worst = max(edge_masks, key=lambda m: popcount(m & ~S))
        if popcount(worst & ~S) <= target_dim:
            return S
        cands = list(bits(worst & ~open_m & ~S))
        if not cands:
            raise ValueError(
                "cannot satisfy memory bound: open indices exceed target"
            )
        # pick the candidate minimizing Eq. 6 (incremental form)
        best_b, best_pen = None, float("inf")
        pen = {c: 0.0 for c in cands}
        cand_mask = 0
        for c in cands:
            cand_mask |= 1 << c
        total = 0.0
        for nm in node_masks:
            base = 2.0 ** (popcount(nm) - popcount(S & nm))
            total += base
            for b in bits(nm & cand_mask):
                pen[b] += base
        for c in cands:
            p = 2.0 * total - pen[c]
            if p < best_pen:
                best_pen, best_b = p, c
        S |= 1 << best_b
    return S


# ----------------------------------------------------------------------
# peak-aware refinement (lifetime-based memory plan, not the width proxy)
# ----------------------------------------------------------------------
# live tensors the width proxy must budget for (operands + output of the
# running GEMM plus headroom for leaves/branches): width target t with
# itemsize w therefore implies a byte budget of LIVE_FACTOR * w * 2^t
DEFAULT_LIVE_FACTOR = 4


def peak_budget_for_width(
    target_dim: int, itemsize: int = 8, live_factor: int = DEFAULT_LIVE_FACTOR
) -> int:
    """The byte budget a width-``target_dim`` schedule implicitly
    guarantees under the proxy's live-set assumption."""
    return live_factor * itemsize * (1 << target_dim)


def refine_slices_for_peak(
    tree: ContractionTree,
    S: int,
    target_dim: int,
    itemsize: int = 8,
    budget_bytes: int | None = None,
    itemsize_of: dict[int, int] | None = None,
) -> int:
    """Shrink (or, for a hard explicit budget, grow) a slicing mask so
    the *planned live-set peak* — not the width proxy — meets the byte
    budget.

    ``itemsize_of`` (per-node storage itemsizes from the precision
    planner) makes the certified peak dtype-true under a mixed-precision
    plan: bf16-stored nodes count half bytes, so re-certifying an
    fp32-derived mask against the *same* budget can only prune further —
    peak-mode slicing under bf16 finds a never-larger ``|S|``.

    The *certified* peak is the worst case over both execution modes:
    the naive full-tree subtask and the two-phase hoisted pair
    (``max(prologue, epilogue)`` — the epilogue counting the pinned
    hoisted frontier), each at ``slice_batch=1``; the executor's vmap
    scales the non-pinned epilogue share by the slice batch
    (:meth:`~repro.lowering.memory.MemoryPlan.epilogue_peak`), an
    execution-time choice the planner cannot see.

    The naive peak is monotone in ``S`` (removing a sliced index only
    grows tensors on its lifetime), which drives the top-up loop (same
    Eq. 6 greedy as :func:`ensure_width`; only reachable with a tight
    explicit budget).  The prune loop needs no monotonicity — every
    candidate removal is re-certified against the full budget — so it
    also covers the non-monotone hoisted segments: repeatedly drop the
    sliced index whose removal keeps the certified peak within budget at
    the lowest resulting Eq. 6 cost.  Each drop halves the subtask count
    outright.

    With ``budget_bytes=None`` the budget is
    ``max(peak_budget_for_width(target_dim, itemsize),
    certified_peak(S))`` — never demanding more than the width-proxy
    schedule already uses, which makes peak mode a strict refinement:
    ``|S_peak| <= |S_width|`` always, with strict improvement whenever
    the width pipeline sliced an index the true peak never needed.
    """
    from ..lowering.memory import certified_peak as _peak  # lazy: cycle

    def certified_peak(mask: int) -> int:
        return _peak(tree, mask, itemsize, itemsize_of=itemsize_of)

    if budget_bytes is None:
        budget_bytes = max(
            peak_budget_for_width(target_dim, itemsize),
            certified_peak(S),
        )
    open_m = tree.tn.open_mask
    node_masks = [tree.node_mask(v) for v in tree.children]
    guard = 0
    # top-up: only an explicit budget tighter than the width result's own
    # peak can trigger this
    while certified_peak(S) > budget_bytes:
        guard += 1
        if guard > 5_000:  # pragma: no cover - safety valve
            break
        worst = max(tree.emask.values(), key=lambda m: popcount(m & ~S))
        cands = list(bits(worst & ~open_m & ~S))
        if not cands:
            break  # only open indices left: budget unreachable
        best_b, best_pen = None, float("inf")
        for c in cands:
            pen = sum(
                2.0 ** (popcount(nm) - popcount((S | (1 << c)) & nm))
                for nm in node_masks
            )
            if pen < best_pen:
                best_pen, best_b = pen, c
        S |= 1 << best_b
    # prune: drop indices the true peak never needed
    while True:
        guard += 1
        if guard > 5_000:  # pragma: no cover
            break
        removable = [
            b
            for b in bits(S)
            if certified_peak(S & ~(1 << b)) <= budget_bytes
        ]
        if not removable:
            return S
        b = min(removable, key=lambda b_: (tree.sliced_cost(S & ~(1 << b_)), b_))
        S &= ~(1 << b)
    return S


def reslice(
    tree: ContractionTree,
    target_dim: int,
    warm: int = 0,
    mode: str = "width",
    itemsize: int = 8,
    budget_bytes: int | None = None,
    compare_fresh: bool = True,
) -> int:
    """Incremental re-slice after a tree move, warm-starting from the
    previous mask — the in-place slicer invocation the anytime
    co-optimizer (:mod:`repro.optimize`) runs after every accepted tree
    mutation.

    The warm mask is adapted to the new tree: bits are first topped up
    to restore the width bound (the move may have widened an edge), then
    greedily pruned while the bound holds (the move may have shortened a
    lifetime, making a previously needed bit redundant — pruning halves
    the subtask count per dropped bit).  With ``compare_fresh`` a fresh
    :func:`slice_finder` pass also runs and the cheaper mask (Eq. 6)
    wins, so warm starting never costs quality; pass
    ``compare_fresh=False`` inside tight search loops where the warm
    mask is expected to stay near-optimal.  ``mode="peak"`` finishes
    with :func:`refine_slices_for_peak` against ``budget_bytes``."""
    open_m = tree.tn.open_mask
    S = warm & ~open_m
    if tree.sliced_width(S) > target_dim:
        S = ensure_width(tree, S, target_dim)
    while True:
        removable = [
            b
            for b in bits(S)
            if tree.sliced_width(S & ~(1 << b)) <= target_dim
        ]
        if not removable:
            break
        b = min(
            removable, key=lambda b_: (tree.sliced_cost(S & ~(1 << b_)), b_)
        )
        S &= ~(1 << b)
    if compare_fresh:
        fresh = ensure_width(tree, slice_finder(tree, target_dim), target_dim)
        if tree.sliced_cost(fresh) < tree.sliced_cost(S):
            S = fresh
    if mode == "peak":
        S = refine_slices_for_peak(
            tree, S, target_dim, itemsize=itemsize, budget_bytes=budget_bytes
        )
    elif mode != "width":
        raise ValueError(f"unknown slicing mode {mode!r}")
    return S


def find_slices(
    tree: ContractionTree,
    target_dim: int,
    method: str = "lifetime",
    mode: str = "width",
    itemsize: int = 8,
    budget_bytes: int | None = None,
    **kw,
) -> int:
    """Unified entry point.  ``method``: lifetime (paper Alg. 1), greedy
    (Cotengra baseline), interval (beyond-paper optimal sweep).
    ``mode="peak"`` re-judges the finished mask against the planned
    live-set peak (:func:`refine_slices_for_peak`) instead of stopping at
    the width proxy."""
    if method == "lifetime":
        S = slice_finder(tree, target_dim, stem=kw.get("stem"))
    elif method == "greedy":
        S = greedy_slicer(
            tree,
            target_dim,
            repeats=kw.get("repeats", 1),
            seed=kw.get("seed", 0),
            temperature=kw.get("temperature", 0.0),
        )
    elif method == "interval":
        S = interval_optimal_slicer(tree, target_dim, stem=kw.get("stem"))
    else:
        raise ValueError(f"unknown slicing method {method!r}")
    S = ensure_width(tree, S, target_dim)
    if mode == "peak":
        S = refine_slices_for_peak(
            tree, S, target_dim, itemsize=itemsize, budget_bytes=budget_bytes
        )
    elif mode != "width":
        raise ValueError(f"unknown slicing mode {mode!r}")
    return S


def partition_slice_ids(
    n_slices: int, n_parts: int
) -> list[tuple[int, int]]:
    """The paper's static process split: contiguous ``[start, end)``
    runs of slice ids, near-equal in *count* (first ``n_slices mod
    n_parts`` parts get one extra id).  This is the Sec. V-D baseline the
    work-stealing scheduler (:mod:`repro.distributed`) is measured
    against; empty parts (``n_parts > n_slices``) come back as empty
    ranges so host indices stay aligned."""
    if n_parts < 1:
        raise ValueError("n_parts must be >= 1")
    base, extra = divmod(int(n_slices), int(n_parts))
    out = []
    pos = 0
    for p in range(n_parts):
        take = base + (1 if p < extra else 0)
        out.append((pos, pos + take))
        pos += take
    return out
