"""Tensor-network graph representation.

The planner side of the paper works on an undirected (multi-)graph
G = (V, E): vertices are tensors, edges are shared indices, and every edge
in an RQC network has weight 2 (qubit dimension). We keep the general
integer-weight form but the fast paths assume weight 2 (log2 size == index
count), matching the paper's complexity algebra (Eq. 2/3/6).

Index sets are represented as Python int bitmasks over a dense index space:
union/intersection/popcount are single machine ops, which is what makes the
lifetime/tuning inner loops cheap (the paper's "traverse all indices once").
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Hashable, Iterable, Mapping, Sequence


def popcount(mask: int) -> int:
    return mask.bit_count()


def bits(mask: int):
    """Iterate set bit positions of ``mask`` in ascending order."""
    while mask:
        low = mask & -mask
        yield low.bit_length() - 1
        mask ^= low


@dataclasses.dataclass(frozen=True)
class IndexSpace:
    """Dense bijection between user index labels and bit positions."""

    labels: tuple[Hashable, ...]

    def __post_init__(self):
        object.__setattr__(
            self, "_pos", {lab: i for i, lab in enumerate(self.labels)}
        )

    def __len__(self) -> int:
        return len(self.labels)

    def bit(self, label: Hashable) -> int:
        return self._pos[label]

    def mask(self, labels: Iterable[Hashable]) -> int:
        m = 0
        for lab in labels:
            m |= 1 << self._pos[lab]
        return m

    def labels_of(self, mask: int) -> tuple[Hashable, ...]:
        return tuple(self.labels[b] for b in bits(mask))


class TensorNetwork:
    """A tensor network over binary (size-2) indices.

    Parameters
    ----------
    tensors: sequence of index-label tuples, one per tensor (ordered — the
        executor uses the ordering to map onto array axes).
    open_inds: output indices (appear in exactly one tensor; never
        contracted, never sliced).
    ind_sizes: optional per-index dimension (default 2 everywhere). The
        planner's log2 algebra requires uniform size 2; non-2 sizes are
        allowed only for executor-level generality.
    """

    def __init__(
        self,
        tensors: Sequence[Sequence[Hashable]],
        open_inds: Sequence[Hashable] = (),
        ind_sizes: Mapping[Hashable, int] | None = None,
    ):
        seen: dict[Hashable, None] = {}
        for t in tensors:
            for ix in t:
                seen.setdefault(ix, None)
        for ix in open_inds:
            if ix not in seen:
                raise ValueError(f"open index {ix!r} not present in any tensor")
        self.space = IndexSpace(tuple(seen.keys()))
        self.inputs: tuple[tuple[Hashable, ...], ...] = tuple(
            tuple(t) for t in tensors
        )
        self.open_inds: tuple[Hashable, ...] = tuple(open_inds)
        self.masks: tuple[int, ...] = tuple(
            self.space.mask(t) for t in self.inputs
        )
        self.open_mask: int = self.space.mask(self.open_inds)
        self.ind_sizes = dict(ind_sizes or {})
        # Degree check: every non-open index must appear exactly twice for
        # the graph (non-hyper) contraction model the paper uses.
        counts: dict[Hashable, int] = {}
        for t in self.inputs:
            for ix in t:
                counts[ix] = counts.get(ix, 0) + 1
            if len(set(t)) != len(t):
                raise ValueError(f"repeated index within one tensor: {t}")
        self.ind_degree = counts

    # ------------------------------------------------------------------
    @property
    def num_tensors(self) -> int:
        return len(self.inputs)

    @property
    def num_inds(self) -> int:
        return len(self.space)

    def size_of(self, ix: Hashable) -> int:
        return self.ind_sizes.get(ix, 2)

    def log2_size(self, mask: int) -> int:
        """log2 of the tensor size for an index mask (uniform size-2)."""
        return popcount(mask)

    def is_hyper(self) -> bool:
        return any(
            d > 2 or (d > 1 and ix in self.open_inds)
            for ix, d in self.ind_degree.items()
        )

    # ------------------------------------------------------------------
    def neighbors(self) -> list[list[int]]:
        """Adjacency between tensors that share at least one index."""
        adj: list[list[int]] = [[] for _ in range(self.num_tensors)]
        by_ind: dict[Hashable, list[int]] = {}
        for i, t in enumerate(self.inputs):
            for ix in t:
                by_ind.setdefault(ix, []).append(i)
        pair_seen = set()
        for ix, owners in by_ind.items():
            for a, b in itertools.combinations(owners, 2):
                if (a, b) not in pair_seen:
                    pair_seen.add((a, b))
                    adj[a].append(b)
                    adj[b].append(a)
        return adj

    # ------------------------------------------------------------------
    def simplify_low_rank(self) -> tuple["TensorNetwork", list[tuple[int, int]]]:
        """Absorb rank-1/rank-2 tensors into a neighbour (Cotengra-style
        pre-processing).  Returns (new_network, merge_log) where merge_log
        records (absorbed, into) positions in the *original* numbering.

        Only the graph structure is simplified here; the executor applies
        the same merge log to concrete arrays.
        """
        inputs = [list(t) for t in self.inputs]
        alive = [True] * len(inputs)
        merge_log: list[tuple[int, int]] = []
        changed = True
        while changed:
            changed = False
            by_ind: dict[Hashable, list[int]] = {}
            for i, t in enumerate(inputs):
                if alive[i]:
                    for ix in t:
                        by_ind.setdefault(ix, []).append(i)
            for i, t in enumerate(inputs):
                if not alive[i] or len(t) > 2:
                    continue
                closed = [ix for ix in t if ix not in self.open_inds]
                if not closed:
                    continue
                partners = [j for j in by_ind.get(closed[0], []) if j != i]
                if not partners:
                    continue
                j = partners[0]
                if not alive[j]:
                    continue
                shared = set(t) & set(inputs[j])
                shared -= set(self.open_inds)
                new_t = [ix for ix in inputs[j] if ix not in shared] + [
                    ix for ix in t if ix not in shared and ix not in inputs[j]
                ]
                inputs[j] = new_t
                alive[i] = False
                merge_log.append((i, j))
                changed = True
                break
        new_inputs = [t for i, t in enumerate(inputs) if alive[i]]
        tn = TensorNetwork(new_inputs, self.open_inds, self.ind_sizes)
        return tn, merge_log


def random_regular_tn(
    num_tensors: int, degree: int, seed: int = 0
) -> TensorNetwork:
    """A random degree-regular closed tensor network (for tests/benchmarks).

    Builds a random multigraph where every vertex has ``degree`` incident
    binary indices, i.e. every tensor is a ``degree``-dimensional tensor.
    """
    import random

    rng = random.Random(seed)
    stubs = [v for v in range(num_tensors) for _ in range(degree)]
    for _ in range(100):
        rng.shuffle(stubs)
        ok = all(
            stubs[2 * i] != stubs[2 * i + 1] for i in range(len(stubs) // 2)
        )
        if ok:
            break
    tensors: list[list[str]] = [[] for _ in range(num_tensors)]
    for e in range(len(stubs) // 2):
        a, b = stubs[2 * e], stubs[2 * e + 1]
        if a == b:  # drop self loops from the final failed shuffle
            continue
        name = f"e{e}"
        tensors[a].append(name)
        tensors[b].append(name)
    return TensorNetwork([t for t in tensors if t])
