"""Distributed sliced contraction.

The paper's process-level parallelism: the ``2^|S|`` slice subtasks are
independent ("embarrassing parallelism ... only one all-reduce operation is
required after the computation").  We express this jax-natively:

  * slice ids are sharded over the mesh's data-parallel axes via
    ``shard_map`` (each device scans its own chunk),
  * the slice-invariant prologue of two-phase execution (see
    :mod:`repro.lowering.partition`) is materialized once per process,
    before the shard_map loop, and rides into every device's scan as a
    replicated capture — devices only re-execute the slice-dependent
    epilogue,
  * partial amplitudes are combined with a single ``psum`` — the paper's
    all-reduce,
  * within a slice, the contraction itself is an SPMD program, so a
    "model"-axis sharding of the big stem tensors (TP) composes
    transparently when the plan is executed under ``pjit`` instead.

Because subtasks are independent and enumerable, the slice axis is
*elastic*: the same plan runs on any device count dividing ``2^|S|``
(padding handles the remainder), which is also the fault-tolerance story —
a lost device's slice range is re-executed elsewhere (work stealing at the
granularity of slice ids), and a checkpoint is just the set of completed
slice ids plus the partial sum (id-keyed, so a resume may re-chunk freely).

This module is the *single-process* (device-level) layer.  Process-level
parallelism — LPT work-stealing scheduling across hosts, the overlapped
collective transport, and elastic per-host claims built on
:class:`SliceRangeCheckpoint` — lives in :mod:`repro.distributed`
(``contract_multihost``); both layers share the slice-id contract
defined here, and every path is behavior-identical at world size 1.

Both drivers here are thin strategy adapters over the unified engine
(:class:`repro.engine.session.ContractionSession`): the shard_map
program, per-slice jit program, ragged-batch masking, hoisted-prologue
materialization and work accounting have exactly one implementation in
:mod:`repro.engine.session`.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..obs import metrics as _metrics, trace as _trace
from .executor import ContractionPlan


def contract_sharded(
    plan: ContractionPlan,
    arrays,
    mesh: Mesh,
    axis_names: tuple[str, ...] = ("data",),
    slice_batch: int = 1,
    hoist: bool | None = None,
) -> jnp.ndarray:
    """Contract all slices with slice-parallelism over ``axis_names``.

    Every device scans its chunk of slice ids and contributes to one psum.
    Each scan step runs ``slice_batch`` subtasks under ``vmap`` (the
    executor's GEMM-recovery batching, now per device).

    When the plan's network holds output indices open (batched
    correlated-amplitude sampling), the per-device accumulator is the full
    open-batch tensor — the open axes are *replicated*, only the slice axis
    is sharded — so the one psum returns the complete 2^k amplitude batch
    on every device.

    Two-phase execution (``hoist``, default ``REPRO_HOIST``): the
    slice-invariant prologue is materialized ONCE per process — before
    the shard_map slice loop, not inside it — and the hoisted buffers
    enter the worker as replicated captures, so each device's scan runs
    only the slice-dependent epilogue.  Under the naive path every device
    re-executes the full tree per slice.

    Plans built with ``backend="gemm"`` carry a lowered kernel schedule
    (:mod:`repro.lowering`); ``contract_slice`` threads that same static
    schedule through ``shard_map`` unchanged, so every device executes
    the identical refined Pallas/dot/einsum program per node.  The jitted
    shard_map program is memoized on the plan per (mesh, axis set, slice
    batch, hoist mode) — repeated serving calls on a cached plan skip
    retracing.

    Strategy adapter: the shard_map program, ragged padding, masking,
    prologue replication and work accounting all live in the unified
    engine (:meth:`~repro.engine.session.ContractionSession.run_sharded`).
    """
    from ..engine.session import ContractionSession  # lazy: cycle

    return ContractionSession(plan, arrays, hoist=hoist).run_sharded(
        mesh, axis_names=axis_names, slice_batch=slice_batch
    )


@dataclasses.dataclass
class SliceRangeCheckpoint:
    """Fault-tolerance unit for long contractions: completed slice ids
    (stored as canonical merged ``[start, end)`` intervals) plus the
    running partial sum.  Restart = re-enqueue the missing ids.

    **Resume-chunk contract**: completion is tracked by slice *id* — the
    intervals are merged independently of how work was chunked — so
    :meth:`missing` is chunk-agnostic: a checkpoint written with
    ``chunk=k1`` resumes correctly under any ``chunk=k2`` (the old
    range-*keyed* ``done`` re-ran already-summed slices on a chunk
    change and double-counted them into ``partial``).  Storage stays
    O(#intervals), never O(2^|S|): completed work coalesces into a few
    tuples even for paper-scale slice counts.  ``done`` also accepts
    bare ids and unmerged/overlapping tuples (e.g. a legacy checkpoint);
    everything is canonicalized on use."""

    n_slices: int
    done: set
    partial: np.ndarray | complex

    def _intervals(self) -> list[tuple[int, int]]:
        """Sorted disjoint ``[start, end)`` intervals covering ``done``."""
        iv: list[tuple[int, int]] = []
        for d in self.done:
            if isinstance(d, tuple):
                if d[1] > d[0]:
                    iv.append((int(d[0]), int(d[1])))
            else:
                iv.append((int(d), int(d) + 1))
        iv.sort()
        merged: list[tuple[int, int]] = []
        for s, e in iv:
            if merged and s <= merged[-1][1]:
                if e > merged[-1][1]:
                    merged[-1] = (merged[-1][0], e)
            else:
                merged.append((s, e))
        return merged

    def done_ids(self) -> set[int]:
        """Completed slice ids, materialized (tests/introspection on
        small checkpoints — prefer :meth:`_intervals` at scale)."""
        return {i for s, e in self._intervals() for i in range(s, e)}

    def add_range(self, start: int, end: int) -> None:
        """Record ids ``[start, end)`` as summed into ``partial``."""
        self.done.add((int(start), int(end)))
        self.done = set(self._intervals())

    def missing(self, chunk: int) -> list[tuple[int, int]]:
        """Maximal runs of not-yet-done slice ids, capped at ``chunk``
        length.  Ranges need not align to any previous chunking."""
        out: list[tuple[int, int]] = []
        pos = 0
        bounds = [
            (min(s, self.n_slices), min(e, self.n_slices))
            for s, e in self._intervals()
        ] + [(self.n_slices, self.n_slices)]
        for s, e in bounds:
            while pos < s:
                nxt = min(pos + chunk, s)
                out.append((pos, nxt))
                pos = nxt
            pos = max(pos, e)
        return out


def contract_resumable(
    plan: ContractionPlan,
    arrays,
    chunk: int = 4,
    state: SliceRangeCheckpoint | None = None,
    fail_on: set[int] | None = None,
    hoist: bool | None = None,
):
    """Single-host resumable driver used by tests to demonstrate the
    checkpoint/restart contract of slice-level fault tolerance.

    Unlike the vmapped scan (where XLA's loop-invariant code motion can
    reclaim invariant recomputation on its own), each slice here is an
    independent jit call, so two-phase execution (``hoist``, default
    ``REPRO_HOIST``) is what keeps the prologue out of the per-slice
    loop — it is materialized once and fed to every call.  A restart
    re-derives it from the same leaf arrays (pure function), so the
    checkpoint stays just the completed slice ids + partial sum — and
    because completion is id-keyed, a resume may use a *different*
    ``chunk`` than the run that wrote the checkpoint (see
    :class:`SliceRangeCheckpoint`).

    ``fail_on``: slice-range starts that raise (simulated node failure) the
    first time they run.

    Strategy adapter: each slice executes as one
    :meth:`~repro.engine.session.ContractionSession.run_slice` call (the
    session owns the hoisted prologue and the jitted per-slice program);
    only the checkpoint bookkeeping lives here.
    """
    from ..engine.session import ContractionSession  # lazy: cycle

    sess = ContractionSession(plan, arrays, hoist=hoist)
    hoist = sess.hoist
    sess.hoisted()  # materialize the prologue outside the slice loop
    n_slices = sess.n_slices
    if state is None:
        state = SliceRangeCheckpoint(n_slices, set(), sess.zeros())
    failed = set(fail_on or ())

    with _trace.span(
        "exec.resumable", cat="exec", slices=n_slices, chunk=chunk,
        hoist=hoist,
    ):
        for s, e in state.missing(chunk):
            if s in failed:
                failed.discard(s)
                raise RuntimeError(
                    f"simulated failure in slice range [{s},{e})"
                )
            with _trace.span(
                "exec.slice_range", cat="exec", start=s, end=e
            ):
                acc = None
                for sid in range(s, e):
                    r = sess.run_slice(sid)
                    acc = r if acc is None else acc + r
                _trace.sync(acc)
            state.partial = state.partial + np.asarray(acc)
            state.add_range(s, e)
            _metrics.inc("exec.slices_executed", e - s)
            if hoist:
                _metrics.inc(
                    "exec.flops_executed",
                    plan.partition.per_slice_cost * (e - s),
                )
            else:
                _metrics.inc(
                    "exec.flops_executed",
                    plan.executed_flops(e - s, hoist=False),
                )
    return state.partial, state
