"""Lifetime of indices and quantitative stem detection (Secs. III-A/III-C).

Definitions (paper):
  * lifetime(k)   — the set of tree edges (tensors) whose index set contains
                    k.  By conservation (Lemma 1) this is exactly the
                    leaf-to-leaf path between the two input tensors that own
                    k (Theorem 1).
  * correlated contractions(k) — the tree nodes on that path.
  * stem          — the leaf-to-leaf path of maximum total contraction cost
                    (the paper's quantitative generalization of Alibaba's
                    observed stem).  Branches are the off-path subtrees.

The :class:`Stem` view linearizes the stem: ``tensors[i]`` are the tree-edge
ids along the path (dims rise toward the apex and fall after it), and
``nodes[i]`` joins ``tensors[i]`` and ``tensors[i+1]``.  The intersection of
any index's lifetime with the stem is a contiguous interval of positions
(intersection of two tree paths is a path) — this is what makes the
in-place sliceFinder linear-time.
"""

from __future__ import annotations

import dataclasses

from .contraction_tree import ContractionTree
from .tensor_network import bits, popcount


def lifetime_edges(tree: ContractionTree, bit: int) -> list[int]:
    """All tree edges (node ids, incl. leaves) whose tensor contains index
    ``bit``."""
    m = 1 << bit
    return [v for v, em in tree.emask.items() if em & m]


def lifetime_closure(tree: ContractionTree, smask: int) -> set[int]:
    """Slice-dependent node set for a slicing mask ``S``: every tree node
    (leaf or internal) whose subtree result depends on the bit assignment
    of some index in ``smask``.

    This is the upward closure (toward the root) of the union of the
    sliced indices' lifetimes: by Thm. 1 each lifetime is the leaf-to-leaf
    path between the index's two owners, and every ancestor of that path
    inherits the dependence even after the index has been contracted away
    inside the subtree.  The complement — nodes with no sliced index in
    their lifetime-closure — is the slice-invariant prologue of two-phase
    execution: those contractions are identical across all 2^|S| subtasks
    and can be hoisted out of the slice loop (Sec. III, Eq. 4 — the
    interpretable part of the slicing overhead)."""
    dependent: set[int] = set()
    for v, em in tree.emask.items():
        if tree.is_leaf(v) and em & smask:
            dependent.add(v)
    for v in tree.contract_order():
        l, r = tree.children[v]
        if l in dependent or r in dependent:
            dependent.add(v)
    return dependent


def correlated_contractions(tree: ContractionTree, bit: int) -> list[int]:
    m = 1 << bit
    return [v for v in tree.children if tree.node_mask(v) & m]


def step_lifetimes(
    steps: list[tuple[int, int, int]],
    entry: tuple[int, ...],
    outputs: tuple[int, ...] = (),
) -> tuple[dict[int, int], dict[int, int]]:
    """(birth, death) step indices for every buffer of an execution
    segment — the *buffer* counterpart of the paper's index lifetimes
    (Thm. 1 is about when an index exists; this is about when a tensor
    occupies memory).

    ``steps`` are ``(lhs, rhs, out)`` node ids in execution order;
    ``entry`` buffers (leaf arrays, hoisted frontier tensors) are born at
    step ``-1``.  A buffer dies at the step that consumes it — in a
    contraction *tree* every node has exactly one consumer — except the
    segment ``outputs`` (and any never-consumed entry), which live to the
    segment end.  A buffer is live at step ``t`` iff
    ``birth[v] <= t <= death[v]``: during step ``t`` both inputs and the
    output are resident simultaneously (an out-of-place GEMM cannot
    alias its operands), which is what makes these closed intervals the
    exact live-set algebra for the planner in
    :mod:`repro.lowering.memory`.
    """
    end = len(steps)
    birth = {v: -1 for v in entry}
    death = {v: end for v in entry}
    for t, (lhs, rhs, out) in enumerate(steps):
        birth[out] = t
        death[out] = end
        death[lhs] = t
        death[rhs] = t
    for v in outputs:
        death[v] = end
    return birth, death


def leaf_path(tree: ContractionTree, a: int, b: int) -> tuple[list[int], list[int]]:
    """The unique tree path between leaves ``a`` and ``b``.

    Returns (tensors, nodes): tensors are the tree-edge ids along the path
    (starting at ``a``, ending at ``b``), nodes are the internal nodes
    joining consecutive tensors (len(nodes) == len(tensors) - 1).
    """
    anc_a = [a]
    v = a
    while v in tree.parent:
        v = tree.parent[v]
        anc_a.append(v)
    pos = {v: i for i, v in enumerate(anc_a)}
    chain_b = [b]
    v = b
    while v not in pos:
        v = tree.parent[v]
        chain_b.append(v)
    apex = v
    chain_b.pop()  # drop apex itself: it is a *node*, not a path tensor
    a_side = anc_a[: pos[apex]]  # tensors a .. child-of-apex (a side)
    tensors = a_side + list(reversed(chain_b))
    # nodes: on the a-side the parent of each tensor; then the apex; then on
    # the b-side each tensor *is* the node producing the next one.
    nodes: list[int] = []
    for i in range(len(a_side) - 1):
        nodes.append(tree.parent[a_side[i]])
    nodes.append(apex)
    for t in reversed(chain_b[1:]):
        nodes.append(t)
    assert len(nodes) == len(tensors) - 1
    return tensors, nodes


@dataclasses.dataclass
class Stem:
    """Linearized stem view over a contraction tree."""

    tree: ContractionTree
    tensors: list[int]  # tree-edge ids along the path
    nodes: list[int]  # joining nodes, len == len(tensors) - 1
    apex_pos: int  # index into ``nodes`` of the apex

    # ------------------------------------------------------------------
    def masks(self) -> list[int]:
        return [self.tree.emask[t] for t in self.tensors]

    def dims(self) -> list[int]:
        return [popcount(m) for m in self.masks()]

    def node_cost_log2(self, i: int) -> int:
        return popcount(self.tree.node_mask(self.nodes[i]))

    def branch_of(self, i: int) -> int | None:
        """The off-path child subtree absorbed at ``nodes[i]`` (None at the
        apex, whose both children are on the path)."""
        if i == self.apex_pos:
            return None
        n = self.nodes[i]
        on_path = {self.tensors[i], self.tensors[i + 1]}
        l, r = self.tree.children[n]
        if l not in on_path:
            return l
        if r not in on_path:
            return r
        return None

    def total_cost(self) -> float:
        return sum(
            2.0 ** popcount(self.tree.node_mask(n)) for n in self.nodes
        )

    def index_intervals(self) -> dict[int, tuple[int, int]]:
        """For every index bit present on the stem, its contiguous position
        interval [lo, hi] (inclusive) over ``tensors``.  This is the
        stem-scoped lifetime."""
        lo: dict[int, int] = {}
        hi: dict[int, int] = {}
        for pos, m in enumerate(self.masks()):
            for b in bits(m):
                if b not in lo:
                    lo[b] = pos
                hi[b] = pos
        return {b: (lo[b], hi[b]) for b in lo}

    def check_contiguous(self) -> None:
        """Property check: every index occupies a contiguous stem segment."""
        for b, (l, h) in self.index_intervals().items():
            m = 1 << b
            for p in range(l, h + 1):
                assert self.tree.emask[self.tensors[p]] & m, (
                    f"lifetime of bit {b} not contiguous on stem at {p}"
                )

    # adjacency info needed for exchange/merge surgery ------------------
    def exchange_args(self, i: int) -> tuple[int, int, int, int] | None:
        """Arguments (p, q, branch_q, branch_p) to swap the branches of
        ``nodes[i]`` and ``nodes[i+1]`` via tree.exchange_at, or None when
        the pair straddles the apex (chain broken there) or lacks a
        branch."""
        if i + 1 >= len(self.nodes):
            return None
        if self.apex_pos in (i, i + 1):
            return None
        b0, b1 = self.branch_of(i), self.branch_of(i + 1)
        if b0 is None or b1 is None:
            return None
        n0, n1 = self.nodes[i], self.nodes[i + 1]
        if i + 1 <= self.apex_pos:  # a-side: parent(n0) == n1
            if self.tree.parent.get(n0) != n1:
                return None
            return (n1, n0, b0, b1)
        else:  # b-side: parent(n1) == n0
            if self.tree.parent.get(n1) != n0:
                return None
            return (n0, n1, b1, b0)


def detect_stem(tree: ContractionTree) -> Stem:
    """Quantitative stem: leaf-to-leaf path maximizing summed node cost.

    Classic two-pass tree DP (max node-weighted path), O(n).
    """
    order = tree.contract_order()  # post-order: children before parents
    down: dict[int, float] = {}
    down_leaf: dict[int, int] = {}
    for v in tree.emask:
        if tree.is_leaf(v):
            down[v] = 0.0
            down_leaf[v] = v
    best_val = -1.0
    best_apex = None
    for v in order:
        l, r = tree.children[v]
        c = 2.0 ** popcount(tree.node_mask(v))
        if down[l] >= down[r]:
            down[v] = c + down[l]
            down_leaf[v] = down_leaf[l]
        else:
            down[v] = c + down[r]
            down_leaf[v] = down_leaf[r]
        through = c + down[l] + down[r]
        if through > best_val:
            best_val = through
            best_apex = v
    l, r = tree.children[best_apex]
    leaf_a, leaf_b = down_leaf[l], down_leaf[r]
    tensors, nodes = leaf_path(tree, leaf_a, leaf_b)
    apex_pos = nodes.index(best_apex)
    return Stem(tree, tensors, nodes, apex_pos)
