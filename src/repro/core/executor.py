"""JAX execution of sliced contraction trees.

The planner (pathfinder/slicing/tuning/merging) emits a contraction tree
plus a slicing bitmask ``S``; this module compiles that into a jitted JAX
program:

  * each of the ``2^|S|`` subtasks fixes the sliced indices to one bit
    assignment (``lax.index_in_dim`` on the leaf arrays — shape-stable, so
    a single jitted function serves every subtask),
  * subtasks are batched with ``vmap`` (beyond-paper: batching slices
    recovers GEMM efficiency lost to narrow stems — the M dimension grows
    by the slice-batch factor); a ragged final batch is padded with
    wrapped-around slice ids masked out by a validity weight, so any
    ``slice_batch`` works,
  * results are summed — the paper's single all-reduce.

**Two-phase (hoisted) execution.**  The paper's Eq. 4 localizes slicing
overhead to the contractions whose lifetime-closure touches a sliced
index; every other node computes the identical tensor in all ``2^|S|``
subtasks.  :mod:`repro.lowering.partition` splits the tree accordingly
and the plan executes it as a *prologue/epilogue pair*: the
slice-invariant prologue runs **once per plan** on the full leaf arrays
(its outputs — the maximal invariant subtree roots — are materialized
and LRU-cached by leaf fingerprint), and only the slice-dependent
epilogue runs (and is vmapped) inside the slice loop, consuming the
hoisted buffers as captured constants.  ``REPRO_HOIST=0`` (or
``hoist=False``) is the off-switch back to the naive full-tree-per-slice
path; both modes are exact and agree to numerical precision.

Open output indices are first-class: when the network declares
``open_inds`` (e.g. a subset of final qubit wires held open for batched
correlated-amplitude sampling), every slice contributes a *tensor* of
amplitudes — one axis per open index, axes in ``tn.open_inds`` order —
and the cross-slice sum accumulates that whole batch.  One sliced
contraction therefore produces ``2^k`` correlated amplitudes instead of
one, which is the paper's flagship sampling workload (Sec. VI: 1M
correlated samples of Sycamore).  See :mod:`repro.sampling` for the
sampling layer built on top.

Two execution backends share the slice machinery: the default
``einsum`` oracle path lowers every tree node to ``jnp.einsum``, while
``backend="gemm"`` compiles the tree through :mod:`repro.lowering` into
an explicit kernel schedule — each node normalized to
transpose→reshape→GEMM form and refined onto Pallas ``tiled_matmul`` /
``jnp.dot`` / ``jnp.einsum`` per the adaptive tile refiner.  The
schedule is static per plan, so it runs identically under the per-slice
path, the vmapped slice batch, and ``shard_map``.

Distribution across devices lives in :mod:`repro.core.distributed`.
"""

from __future__ import annotations

import dataclasses
import os
import string
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics, trace as _trace
from .contraction_tree import ContractionTree
from .tensor_network import TensorNetwork, bits

_LETTERS = string.ascii_letters

BACKENDS = ("einsum", "gemm")


def default_backend() -> str:
    """Execution backend when none is requested: the ``REPRO_BACKEND``
    environment variable (CI runs the tier-1 gate under both values) or
    the einsum oracle path."""
    backend = os.environ.get("REPRO_BACKEND", "einsum")
    if backend not in BACKENDS:
        raise ValueError(
            f"REPRO_BACKEND={backend!r} not in {BACKENDS}"
        )
    return backend


def default_hoist() -> bool:
    """Whether two-phase (slice-invariant hoisted) execution is enabled
    when no explicit ``hoist=`` is requested: the ``REPRO_HOIST``
    environment variable (CI runs the tier-1 gate under both values),
    defaulting to on.  ``REPRO_HOIST=0`` is the documented off-switch
    back to the naive full-tree-per-slice executor."""
    v = os.environ.get("REPRO_HOIST", "1")
    if v not in ("0", "1"):
        raise ValueError(f"REPRO_HOIST={v!r} not in ('0', '1')")
    return v == "1"


def pair_contract_inds(
    inds_a: Sequence, inds_b: Sequence, open_inds: frozenset
) -> tuple[tuple, tuple]:
    """(contracted, out) index tuples for a pairwise contraction, with the
    deterministic ordering convention shared by planner and executor."""
    sa, sb = set(inds_a), set(inds_b)
    contracted = tuple(
        ix for ix in inds_a if ix in sb and ix not in open_inds
    )
    out = tuple(ix for ix in inds_a if ix not in contracted) + tuple(
        ix for ix in inds_b if ix not in contracted and ix not in sa
    )
    return contracted, out


def einsum_expr(inds_a, inds_b, inds_out) -> str:
    local: dict = {}

    def lab(ix):
        if ix not in local:
            local[ix] = _LETTERS[len(local)]
        return local[ix]

    return (
        "".join(lab(i) for i in inds_a)
        + ","
        + "".join(lab(i) for i in inds_b)
        + "->"
        + "".join(lab(i) for i in inds_out)
    )


def simplify_network(
    tn: TensorNetwork, arrays: list[np.ndarray]
) -> tuple[TensorNetwork, list[np.ndarray]]:
    """Absorb rank-1/2 tensors into neighbours (gate fusion), keeping the
    arrays in sync — the Cotengra-style pre-processing the paper applies
    before planning."""
    open_set = frozenset(tn.open_inds)
    inputs = [list(t) for t in tn.inputs]
    arrs = [np.asarray(a) for a in arrays]
    alive = [True] * len(inputs)
    changed = True
    while changed:
        changed = False
        by_ind: dict = {}
        for i, t in enumerate(inputs):
            if alive[i]:
                for ix in t:
                    by_ind.setdefault(ix, []).append(i)
        for i, t in enumerate(inputs):
            if not alive[i] or len(t) > 2:
                continue
            closed = [ix for ix in t if ix not in open_set]
            if not closed:
                continue
            partners = [j for j in by_ind.get(closed[0], []) if j != i and alive[j]]
            if not partners:
                continue
            j = partners[0]
            _, out = pair_contract_inds(inputs[j], t, open_set)
            expr = einsum_expr(inputs[j], t, out)
            arrs[j] = np.einsum(expr, arrs[j], arrs[i])
            inputs[j] = list(out)
            alive[i] = False
            changed = True
            break
    new_inputs = [t for i, t in enumerate(inputs) if alive[i]]
    new_arrays = [a for i, a in enumerate(arrs) if alive[i]]
    return TensorNetwork(new_inputs, tn.open_inds, tn.ind_sizes), new_arrays


def auto_slice_batch(requested: int, n_slices: int) -> int:
    """Clamp the requested slice batch to the slice count.

    Historically this silently shrank to the largest power of two
    dividing ``n_slices`` because ``contract_all`` required exact tiling;
    the executor now pads the final ragged batch (masked by a validity
    weight), so any batch size works and the request is honored as-is."""
    return max(1, min(requested, n_slices))


@dataclasses.dataclass
class _Step:
    lhs: int  # env key
    rhs: int
    out: int
    expr: str
    inds_lhs: tuple = ()
    inds_rhs: tuple = ()
    inds_out: tuple = ()


class ContractionPlan:
    """Compiled sliced-contraction program for one (tree, S) pair.

    ``backend="gemm"`` additionally lowers every step through
    :mod:`repro.lowering` into a refined kernel schedule (``self.
    schedule``); ``backend=None`` resolves via :func:`default_backend`.
    ``dtype`` only informs the refiner's cost model / backend choice —
    execution adapts to the concrete arrays it is handed.

    ``precision`` (``None`` → :func:`~repro.lowering.precision.
    default_precision`, i.e. ``REPRO_PRECISION``) selects the
    mixed-precision mode for the lowered schedule: ``"auto"`` demotes
    MXU steps to bf16-input/fp32-accumulate while the forward error
    model's predicted Linear-XEB fidelity loss stays within
    ``fidelity_tol``; ``"bf16"`` forces every eligible step; ``"fp32"``
    (the default) leaves the plan untouched.  Only meaningful for
    ``backend="gemm"``.
    """

    def __init__(
        self,
        tree: ContractionTree,
        smask: int = 0,
        backend: str | None = None,
        dtype=jnp.complex64,
        precision: str | None = None,
        fidelity_tol: float | None = None,
    ):
        self.tree = tree
        tn = tree.tn
        self.tn = tn
        space = tn.space
        self.smask = smask
        self.sliced_bits = list(bits(smask))
        self.num_sliced = len(self.sliced_bits)
        slicepos = {b: i for i, b in enumerate(self.sliced_bits)}
        sliced_labels = {space.labels[b] for b in self.sliced_bits}
        open_set = frozenset(tn.open_inds)

        # leaf slicing specs: (axis, slice position) — applied high-axis
        # first so earlier axes stay valid.
        self.leaf_specs: list[list[tuple[int, int]]] = []
        node_inds: dict[int, tuple] = {}
        for i, inds in enumerate(tn.inputs):
            spec = [
                (ax, slicepos[space.bit(ix)])
                for ax, ix in enumerate(inds)
                if ix in sliced_labels
            ]
            spec.sort(reverse=True)
            self.leaf_specs.append(spec)
            node_inds[i] = tuple(ix for ix in inds if ix not in sliced_labels)

        self.steps: list[_Step] = []
        for v in tree.contract_order():
            l, r = tree.children[v]
            _, out = pair_contract_inds(node_inds[l], node_inds[r], open_set)
            expr = einsum_expr(node_inds[l], node_inds[r], out)
            node_inds[v] = out
            self.steps.append(
                _Step(l, r, v, expr, node_inds[l], node_inds[r], out)
            )
        self.root = tree.root
        raw_out = node_inds[self.root]
        # canonicalize: output axes follow tn.open_inds declaration order
        want = tuple(ix for ix in tn.open_inds if ix in raw_out)
        self.out_perm = tuple(raw_out.index(ix) for ix in want)
        self.out_inds = want if want else raw_out

        self.backend = backend if backend is not None else default_backend()
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        self.dtype = jnp.dtype(dtype)
        from ..lowering.precision import (  # lazy: avoid cycle
            DEFAULT_FIDELITY_TOL,
            PRECISION_MODES,
            default_precision,
        )

        self.precision_mode = (
            precision if precision is not None else default_precision()
        )
        if self.precision_mode not in PRECISION_MODES:
            raise ValueError(
                f"precision {self.precision_mode!r} not in {PRECISION_MODES}"
            )
        self.fidelity_tol = (
            DEFAULT_FIDELITY_TOL if fidelity_tol is None
            else float(fidelity_tol)
        )
        self.schedule = None
        if self.backend == "gemm":
            from ..lowering import refine_schedule  # lazy: avoid cycle

            self.schedule = refine_schedule(
                [(s.inds_lhs, s.inds_rhs, s.inds_out) for s in self.steps],
                tn.size_of,
                dtype=self.dtype,
            )

        # two-phase partition: slice-invariant prologue steps (run once
        # per plan) vs slice-dependent epilogue steps (run per slice).
        self.partition = None
        self.prologue_idx: tuple[int, ...] = ()
        self.epilogue_idx: tuple[int, ...] = tuple(range(len(self.steps)))
        self.hoisted_nodes: tuple[int, ...] = ()
        self.prologue_leaves: tuple[int, ...] = ()
        self.epilogue_leaves: tuple[int, ...] = tuple(range(tn.num_tensors))
        if self.num_sliced and self.steps:
            from ..lowering.partition import partition_tree  # lazy: cycle

            part = partition_tree(tree, smask)
            pos = {st.out: k for k, st in enumerate(self.steps)}
            self.partition = part
            self.prologue_idx = tuple(pos[v] for v in part.invariant_nodes)
            self.epilogue_idx = tuple(pos[v] for v in part.epilogue_nodes)
            self.hoisted_nodes = part.hoisted_nodes
            self.prologue_leaves = part.prologue_leaves
            self.epilogue_leaves = part.epilogue_leaves
        # mixed-precision assignment: runs after the partition (epilogue
        # steps weigh 2^|S| in the greedy order) and before the memory/
        # chain planning (their byte accounting must see the storage
        # precision the schedule will actually run at)
        self._itemsize_of: dict[int, int] | None = None
        if self.schedule is not None and self.precision_mode != "fp32":
            from ..lowering.precision import (  # lazy: avoid cycle
                assign_precision,
                storage_itemsizes,
            )

            self.schedule = assign_precision(
                self.schedule,
                mode=self.precision_mode,
                fidelity_tol=self.fidelity_tol,
                epilogue_positions=(
                    self.epilogue_idx if self.num_sliced else None
                ),
                n_slices=1 << self.num_sliced,
            )
            if self.schedule.precision_counts().get("bf16"):
                self._itemsize_of = storage_itemsizes(
                    [(s.lhs, s.rhs, s.out) for s in self.steps],
                    self.schedule.specs,
                    self.dtype,
                    tree.emask,
                )
        # lifetime-based buffer plan (lazy; built eagerly below when the
        # fusion-boundary pass needs the per-node buffer sizes)
        self._memory_plan = None
        # fusion-boundary pass (epilogue megakernel): runs of adjacent
        # schedule steps whose certified live set fits VMEM execute as
        # single fused-chain calls.  Planned per execution segment so a
        # chain can never cross the prologue/epilogue boundary; the
        # REPRO_MEGAKERNEL switch is read here (plan construction) and
        # joins the plan-cache fingerprint in the API layer.
        self.chain_plan = None
        self._chain_dispatch: dict[str, dict] = {}
        if self.schedule is not None and self.steps:
            from ..lowering.refiner import (  # lazy: avoid cycle
                default_megakernel,
                plan_chains,
            )

            if default_megakernel():
                mem = self.memory_plan()
                segments = {"naive": tuple(range(len(self.steps)))}
                if self.partition is not None:
                    if self.prologue_idx:
                        segments["prologue"] = self.prologue_idx
                    segments["epilogue"] = self.epilogue_idx
                step_nodes = tuple(
                    (s.lhs, s.rhs, s.out) for s in self.steps
                )
                self.chain_plan = plan_chains(
                    self.schedule, step_nodes, segments, mem.naive.nbytes,
                    itemsize_of=self._itemsize_of,
                )
                self._chain_dispatch = {
                    name: self.chain_plan.by_segment(name)
                    for name in segments
                }
        # memoized jitted executables (plan-lifetime — a cached plan
        # served twice skips retracing, not just re-planning)
        self._compiled: dict = {}
        # materialized prologue tensors, LRU-keyed by the fingerprint of
        # the leaf arrays the prologue consumes (cross-call reuse, e.g.
        # repeated sampler calls on one open-qubit batch network)
        from ..lowering.cache import HoistCache  # lazy: avoid cycle

        hoist_bytes = os.environ.get("REPRO_HOIST_CACHE_BYTES", "")
        self._hoist_cache = HoistCache(
            maxsize=int(os.environ.get("REPRO_HOIST_CACHE_SIZE", "8")),
            max_bytes=int(hoist_bytes) if hoist_bytes else None,
        )
        if self.chain_plan is not None:
            _metrics.inc("plan.chains_fused", self.chain_plan.num_multi)
            _metrics.inc(
                "plan.chain_hbm_bytes_saved",
                self.chain_plan.hbm_bytes_saved("naive"),
            )

    # ------------------------------------------------------------------
    @property
    def num_open(self) -> int:
        """Number of open output indices carried through the stem."""
        return len(self.out_inds)

    @property
    def batch_size(self) -> int:
        """Correlated amplitudes produced per full contraction (2^k)."""
        n = 1
        for ix in self.out_inds:
            n *= self.tn.size_of(ix)
        return n

    def out_shape(self) -> tuple[int, ...]:
        """Shape of the contraction output (one axis per open index)."""
        return tuple(self.tn.size_of(ix) for ix in self.out_inds)

    # ------------------------------------------------------------------
    # two-phase (hoisted) execution metrics
    # ------------------------------------------------------------------
    @property
    def can_hoist(self) -> bool:
        """True when the partition found slice-invariant contractions to
        hoist out of the slice loop."""
        return bool(self.prologue_idx)

    @property
    def invariant_fraction(self) -> float:
        """Fraction of the dense tree cost C(B) that is slice-invariant."""
        return self.partition.invariant_fraction if self.partition else 0.0

    def executed_overhead(self, hoist: bool = True) -> float:
        """Executed-FLOPs overhead over the dense C(B) for the chosen
        execution mode: Eq. 4 for the naive full-tree-per-slice path, the
        prologue + 2^|S|·epilogue cost under hoisting."""
        if self.num_sliced == 0:
            return 1.0
        if hoist and self.partition is not None and self.can_hoist:
            return self.partition.hoisted_overhead()
        return self.tree.slicing_overhead(self.smask)

    def executed_flops(
        self, n_slices: int | None = None, hoist: bool = True
    ) -> float:
        """FLOPs actually executed when contracting ``n_slices`` subtasks
        (default: all ``2^|S|``) under the chosen mode — the quantity the
        obs layer accumulates into ``exec.flops_executed``.  Hoisted:
        one prologue plus ``n`` epilogues; naive: ``n`` full subtasks."""
        total = 1 << self.num_sliced
        n = total if n_slices is None else n_slices
        if hoist and self.partition is not None and self.can_hoist:
            p = self.partition
            return p.invariant_cost + p.per_slice_cost * n
        return self.tree.sliced_cost(self.smask) / total * n

    def hoist_summary(self) -> str:
        """One-line two-phase summary for examples/benchmarks."""
        return (
            f"hoist: inv_frac={self.invariant_fraction:.2f} "
            f"slices={1 << self.num_sliced} "
            f"hoisted_buffers={len(self.hoisted_nodes)} "
            f"overhead naive={self.executed_overhead(False):.3f} -> "
            f"hoisted={self.executed_overhead(True):.3f}"
        )

    # ------------------------------------------------------------------
    # lifetime-based buffer plan
    # ------------------------------------------------------------------
    def memory_plan(self):
        """The lifetime-based :class:`~repro.lowering.memory.MemoryPlan`
        for this plan's ``(tree, S)`` pair — exact live-set peaks per
        execution segment, linear-scan buffer slots, and the per-step
        free schedule :meth:`_run_steps` executes.  Built lazily once per
        plan (pure planner algebra, no arrays touched)."""
        if self._memory_plan is None:
            from ..lowering.memory import plan_memory  # lazy: avoid cycle

            self._memory_plan = plan_memory(
                self.tree, self.smask, itemsize=self.dtype.itemsize,
                part=self.partition, itemsize_of=self._itemsize_of,
            )
        return self._memory_plan

    # ------------------------------------------------------------------
    def slice_values(self, slice_id):
        """bit-decompose a (traced) slice id into per-index 0/1 values."""
        ar = jnp.arange(self.num_sliced, dtype=jnp.int32)
        return (
            jnp.right_shift(jnp.asarray(slice_id, jnp.int32), ar) & 1
        ).astype(jnp.int32)

    def _run_steps(self, env: dict, step_ids, segment: str = "naive") -> None:
        """Execute the given step positions over ``env`` (shared by the
        prologue, the epilogue, and the naive full-tree path).

        Frees are driven by the lifetime-based memory plan's per-step
        free schedule for ``segment`` — deterministic last-use drops (in
        the epilogue this keeps the pinned hoisted buffers out of the
        free lists; they are cross-slice captures whose storage is never
        reclaimable inside one subtask).

        Positions planned into a fused chain (``self.chain_plan``,
        keyed by the chain's first position) dispatch as one
        ``gemm_form.apply_chain`` call — this single site covers the
        vmapped scan, ``contract_sharded``, and ``contract_resumable``,
        which all funnel through here."""
        seg = self.memory_plan().segment_for(segment)
        frees = seg.frees if seg is not None else None
        chains = self._chain_dispatch.get(segment, {})
        ids = list(step_ids)
        i = 0
        while i < len(ids):
            k = ids[i]
            ch = chains.get(k)
            if ch is not None:
                # fused chain: one megakernel call covers the whole run;
                # interior intermediates never enter env (they live in
                # the kernel's VMEM scratch slots), so the planned frees
                # for them are no-ops and everything else drops exactly
                # where the lifetime plan says it dies.
                from ..lowering import gemm_form  # lazy: avoid cycle

                assert tuple(ids[i:i + ch.n_steps]) == ch.positions, (
                    segment, ch.positions, ids[i:i + ch.n_steps]
                )
                env[ch.out_node] = gemm_form.apply_chain(
                    ch,
                    [self.schedule.specs[p] for p in ch.positions],
                    [env[n] for n in ch.external_nodes],
                )
                interior = {n[2] for n in ch.nodes[:-1]}
                for p in ch.positions:
                    out = self.steps[p].out
                    dead = (
                        frees[out]
                        if frees is not None
                        else (self.steps[p].lhs, self.steps[p].rhs)
                    )
                    for u in dead:
                        if u in env and u not in interior:
                            del env[u]
                i += ch.n_steps
                continue
            st = self.steps[k]
            if self.schedule is None:
                env[st.out] = jnp.einsum(st.expr, env[st.lhs], env[st.rhs])
            else:
                from ..lowering import gemm_form  # lazy: avoid cycle

                env[st.out] = gemm_form.apply(
                    self.schedule.specs[k], env[st.lhs], env[st.rhs]
                )
            dead = (
                frees[st.out]
                if frees is not None
                else (st.lhs, st.rhs)
            )
            for u in dead:
                del env[u]
            i += 1

    def contract_slice(
        self, arrays: Sequence[jnp.ndarray], slice_id, hoisted=None
    ):
        """Contract one subtask (slice assignment = bits of slice_id).

        ``hoisted`` (from :meth:`contract_prologue`) seeds the environment
        with the materialized slice-invariant buffers, so only the
        epilogue steps run; ``None`` executes the full tree (naive)."""
        svals = self.slice_values(slice_id)
        env: dict[int, jnp.ndarray] = {}
        if hoisted is None:
            leaf_ids: Sequence[int] = range(len(arrays))
            step_ids: Sequence[int] = range(len(self.steps))
            segment = "naive"
        else:
            env.update(zip(self.hoisted_nodes, hoisted))
            leaf_ids = self.epilogue_leaves
            step_ids = self.epilogue_idx
            segment = "epilogue"
        for i in leaf_ids:
            a = jnp.asarray(arrays[i])
            for axis, spos in self.leaf_specs[i]:
                a = jax.lax.dynamic_index_in_dim(
                    a, svals[spos], axis=axis, keepdims=False
                )
            env[i] = a
        self._run_steps(env, step_ids, segment)
        out = env[self.root]
        if self.out_perm and self.out_perm != tuple(range(out.ndim)):
            out = jnp.transpose(out, self.out_perm)
        return out

    # ------------------------------------------------------------------
    def _prologue_outputs(self, arrays) -> list[jnp.ndarray]:
        """Run the slice-invariant prologue on the full (unsliced) leaf
        arrays and return the hoisted frontier buffers in
        ``hoisted_nodes`` order.  Invariant leaves carry no sliced index
        by construction, so no slice specs apply here."""
        env: dict[int, jnp.ndarray] = {
            i: jnp.asarray(arrays[i]) for i in self.prologue_leaves
        }
        self._run_steps(env, self.prologue_idx, "prologue")
        return [env[v] for v in self.hoisted_nodes]

    def contract_prologue(self, arrays, use_cache: bool = True):
        """Materialize the slice-invariant prologue once.

        The result is memoized two ways: the jitted program on the plan
        (no retracing), and the concrete output buffers in an LRU keyed
        by :func:`repro.lowering.cache.leaf_key` over the prologue's
        leaf arrays.  Device-resident leaves are keyed by shape/dtype +
        buffer identity — no device→host transfer on the hot path; the
        key's keep-alive references ride with the cache entry so an id
        can never be recycled while its entry is live.  Host (numpy)
        leaves fall back to value hashing.  Set
        ``REPRO_HOIST_CACHE_SIZE=0`` or ``use_cache=False`` to skip both
        the key and the cache.
        """
        if not self.can_hoist:
            return []

        def compute():
            ck = ("prologue",)
            fn = self._compiled.get(ck) or self._compiled.setdefault(
                ck, jax.jit(lambda a: self._prologue_outputs(a))
            )
            with _trace.span(
                "exec.prologue", cat="exec", buffers=len(self.hoisted_nodes)
            ):
                out = fn(list(arrays))
                _trace.sync(out)
            _metrics.inc(
                "exec.flops_executed", self.partition.invariant_cost
            )
            return out

        if use_cache and self._hoist_cache.maxsize > 0:
            from ..lowering.cache import leaf_key  # lazy: cycle

            key, keepalive = leaf_key(arrays, self.prologue_leaves)
            # single-flight: concurrent sessions over the same leaves
            # (serving tenants on one family) materialize the prologue
            # once — the waiters get the leader's buffers, and the
            # invariant-cost FLOPs are counted exactly once.
            # third slot: per-Mesh replicated device-put copies, filled
            # lazily by contract_prologue_replicated on the sharded path
            return self._hoist_cache.single_flight(
                key, lambda: (compute(), keepalive, {})
            )[0]
        return compute()

    def contract_prologue_replicated(
        self, arrays, mesh, use_cache: bool = True
    ):
        """Prologue buffers device-put replicated over ``mesh`` — the
        form ``contract_sharded`` captures into its shard_map worker.

        The placed copies are cached *in the same HoistCache entry* as
        the host-side prologue outputs, keyed by ``mesh``: repeated
        sharded calls on a plan-cache hit reuse the already-broadcast
        buffers instead of re-issuing the device_put every invocation
        (``exec.hoist_replicated_reuse`` counts the skips,
        ``exec.hoist_replicated_put`` the actual broadcasts)."""
        if not self.can_hoist:
            return []
        out = self.contract_prologue(arrays, use_cache=use_cache)
        entry = key = None
        if use_cache and self._hoist_cache.maxsize > 0:
            from ..lowering.cache import leaf_key  # lazy: cycle

            key, _ = leaf_key(arrays, self.prologue_leaves)
            entry = self._hoist_cache.get(key)
            if entry is not None and len(entry) > 2:
                placed = entry[2].get(mesh)
                if placed is not None:
                    _metrics.inc("exec.hoist_replicated_reuse")
                    return placed
        from jax.sharding import NamedSharding, PartitionSpec

        sharding = NamedSharding(mesh, PartitionSpec())
        placed = [jax.device_put(o, sharding) for o in out]
        _metrics.inc("exec.hoist_replicated_put")
        if entry is not None and len(entry) > 2:
            entry[2][mesh] = placed
            # re-put so the cache's byte accounting sees the new copies
            self._hoist_cache.put(key, entry)
        return placed

    # ------------------------------------------------------------------
    def contract_all(
        self,
        arrays: Sequence[jnp.ndarray],
        slice_batch: int = 8,
        hoist: bool | None = None,
    ) -> jnp.ndarray:
        """Sum over all 2^|S| subtasks (single host) — strategy adapter
        over the unified engine: a one-shot
        :class:`~repro.engine.session.ContractionSession` running the
        scan-of-vmapped-batches strategy (:meth:`~repro.engine.session.
        ContractionSession.run_all`).  ``hoist`` selects two-phase
        execution (default ``REPRO_HOIST``)."""
        from ..engine.session import ContractionSession  # lazy: cycle

        return ContractionSession(self, arrays, hoist=hoist).run_all(
            slice_batch=slice_batch
        )


def contract_dense(
    tn: TensorNetwork, arrays: Sequence[np.ndarray], tree: ContractionTree
) -> jnp.ndarray:
    """Unsliced contraction (reference path)."""
    return ContractionPlan(tree, 0).contract_all(arrays)
