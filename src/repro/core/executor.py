"""JAX execution of sliced contraction trees.

The planner (pathfinder/slicing/tuning/merging) emits a contraction tree
plus a slicing bitmask ``S``; this module compiles that into a jitted JAX
program:

  * each of the ``2^|S|`` subtasks fixes the sliced indices to one bit
    assignment (``lax.index_in_dim`` on the leaf arrays — shape-stable, so
    a single jitted function serves every subtask),
  * subtasks are batched with ``vmap`` (beyond-paper: batching slices
    recovers GEMM efficiency lost to narrow stems — the M dimension grows
    by the slice-batch factor),
  * results are summed — the paper's single all-reduce.

Open output indices are first-class: when the network declares
``open_inds`` (e.g. a subset of final qubit wires held open for batched
correlated-amplitude sampling), every slice contributes a *tensor* of
amplitudes — one axis per open index, axes in ``tn.open_inds`` order —
and the cross-slice sum accumulates that whole batch.  One sliced
contraction therefore produces ``2^k`` correlated amplitudes instead of
one, which is the paper's flagship sampling workload (Sec. VI: 1M
correlated samples of Sycamore).  See :mod:`repro.sampling` for the
sampling layer built on top.

Two execution backends share the slice machinery: the default
``einsum`` oracle path lowers every tree node to ``jnp.einsum``, while
``backend="gemm"`` compiles the tree through :mod:`repro.lowering` into
an explicit kernel schedule — each node normalized to
transpose→reshape→GEMM form and refined onto Pallas ``tiled_matmul`` /
``jnp.dot`` / ``jnp.einsum`` per the adaptive tile refiner.  The
schedule is static per plan, so it runs identically under the per-slice
path, the vmapped slice batch, and ``shard_map``.

Distribution across devices lives in :mod:`repro.core.distributed`.
"""

from __future__ import annotations

import dataclasses
import os
import string
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .contraction_tree import ContractionTree
from .tensor_network import TensorNetwork, bits

_LETTERS = string.ascii_letters

BACKENDS = ("einsum", "gemm")


def default_backend() -> str:
    """Execution backend when none is requested: the ``REPRO_BACKEND``
    environment variable (CI runs the tier-1 gate under both values) or
    the einsum oracle path."""
    backend = os.environ.get("REPRO_BACKEND", "einsum")
    if backend not in BACKENDS:
        raise ValueError(
            f"REPRO_BACKEND={backend!r} not in {BACKENDS}"
        )
    return backend


def pair_contract_inds(
    inds_a: Sequence, inds_b: Sequence, open_inds: frozenset
) -> tuple[tuple, tuple]:
    """(contracted, out) index tuples for a pairwise contraction, with the
    deterministic ordering convention shared by planner and executor."""
    sa, sb = set(inds_a), set(inds_b)
    contracted = tuple(
        ix for ix in inds_a if ix in sb and ix not in open_inds
    )
    out = tuple(ix for ix in inds_a if ix not in contracted) + tuple(
        ix for ix in inds_b if ix not in contracted and ix not in sa
    )
    return contracted, out


def einsum_expr(inds_a, inds_b, inds_out) -> str:
    local: dict = {}

    def lab(ix):
        if ix not in local:
            local[ix] = _LETTERS[len(local)]
        return local[ix]

    return (
        "".join(lab(i) for i in inds_a)
        + ","
        + "".join(lab(i) for i in inds_b)
        + "->"
        + "".join(lab(i) for i in inds_out)
    )


def simplify_network(
    tn: TensorNetwork, arrays: list[np.ndarray]
) -> tuple[TensorNetwork, list[np.ndarray]]:
    """Absorb rank-1/2 tensors into neighbours (gate fusion), keeping the
    arrays in sync — the Cotengra-style pre-processing the paper applies
    before planning."""
    open_set = frozenset(tn.open_inds)
    inputs = [list(t) for t in tn.inputs]
    arrs = [np.asarray(a) for a in arrays]
    alive = [True] * len(inputs)
    changed = True
    while changed:
        changed = False
        by_ind: dict = {}
        for i, t in enumerate(inputs):
            if alive[i]:
                for ix in t:
                    by_ind.setdefault(ix, []).append(i)
        for i, t in enumerate(inputs):
            if not alive[i] or len(t) > 2:
                continue
            closed = [ix for ix in t if ix not in open_set]
            if not closed:
                continue
            partners = [j for j in by_ind.get(closed[0], []) if j != i and alive[j]]
            if not partners:
                continue
            j = partners[0]
            _, out = pair_contract_inds(inputs[j], t, open_set)
            expr = einsum_expr(inputs[j], t, out)
            arrs[j] = np.einsum(expr, arrs[j], arrs[i])
            inputs[j] = list(out)
            alive[i] = False
            changed = True
            break
    new_inputs = [t for i, t in enumerate(inputs) if alive[i]]
    new_arrays = [a for i, a in enumerate(arrs) if alive[i]]
    return TensorNetwork(new_inputs, tn.open_inds, tn.ind_sizes), new_arrays


def auto_slice_batch(requested: int, n_slices: int) -> int:
    """Largest power-of-two batch ≤ ``requested`` that divides ``n_slices``
    (contract_all requires the batch to tile the slice range exactly)."""
    sb = 1
    while sb * 2 <= min(requested, n_slices) and n_slices % (sb * 2) == 0:
        sb *= 2
    return sb


@dataclasses.dataclass
class _Step:
    lhs: int  # env key
    rhs: int
    out: int
    expr: str
    inds_lhs: tuple = ()
    inds_rhs: tuple = ()
    inds_out: tuple = ()


class ContractionPlan:
    """Compiled sliced-contraction program for one (tree, S) pair.

    ``backend="gemm"`` additionally lowers every step through
    :mod:`repro.lowering` into a refined kernel schedule (``self.
    schedule``); ``backend=None`` resolves via :func:`default_backend`.
    ``dtype`` only informs the refiner's cost model / backend choice —
    execution adapts to the concrete arrays it is handed.
    """

    def __init__(
        self,
        tree: ContractionTree,
        smask: int = 0,
        backend: str | None = None,
        dtype=jnp.complex64,
    ):
        self.tree = tree
        tn = tree.tn
        self.tn = tn
        space = tn.space
        self.smask = smask
        self.sliced_bits = list(bits(smask))
        self.num_sliced = len(self.sliced_bits)
        slicepos = {b: i for i, b in enumerate(self.sliced_bits)}
        sliced_labels = {space.labels[b] for b in self.sliced_bits}
        open_set = frozenset(tn.open_inds)

        # leaf slicing specs: (axis, slice position) — applied high-axis
        # first so earlier axes stay valid.
        self.leaf_specs: list[list[tuple[int, int]]] = []
        node_inds: dict[int, tuple] = {}
        for i, inds in enumerate(tn.inputs):
            spec = [
                (ax, slicepos[space.bit(ix)])
                for ax, ix in enumerate(inds)
                if ix in sliced_labels
            ]
            spec.sort(reverse=True)
            self.leaf_specs.append(spec)
            node_inds[i] = tuple(ix for ix in inds if ix not in sliced_labels)

        self.steps: list[_Step] = []
        for v in tree.contract_order():
            l, r = tree.children[v]
            _, out = pair_contract_inds(node_inds[l], node_inds[r], open_set)
            expr = einsum_expr(node_inds[l], node_inds[r], out)
            node_inds[v] = out
            self.steps.append(
                _Step(l, r, v, expr, node_inds[l], node_inds[r], out)
            )
        self.root = tree.root
        raw_out = node_inds[self.root]
        # canonicalize: output axes follow tn.open_inds declaration order
        want = tuple(ix for ix in tn.open_inds if ix in raw_out)
        self.out_perm = tuple(raw_out.index(ix) for ix in want)
        self.out_inds = want if want else raw_out

        self.backend = backend if backend is not None else default_backend()
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        self.dtype = jnp.dtype(dtype)
        self.schedule = None
        if self.backend == "gemm":
            from ..lowering import refine_schedule  # lazy: avoid cycle

            self.schedule = refine_schedule(
                [(s.inds_lhs, s.inds_rhs, s.inds_out) for s in self.steps],
                tn.size_of,
                dtype=self.dtype,
            )
        # memoized jitted executables (plan-lifetime — a cached plan
        # served twice skips retracing, not just re-planning)
        self._compiled: dict = {}

    # ------------------------------------------------------------------
    @property
    def num_open(self) -> int:
        """Number of open output indices carried through the stem."""
        return len(self.out_inds)

    @property
    def batch_size(self) -> int:
        """Correlated amplitudes produced per full contraction (2^k)."""
        n = 1
        for ix in self.out_inds:
            n *= self.tn.size_of(ix)
        return n

    def out_shape(self) -> tuple[int, ...]:
        """Shape of the contraction output (one axis per open index)."""
        return tuple(self.tn.size_of(ix) for ix in self.out_inds)

    # ------------------------------------------------------------------
    def slice_values(self, slice_id):
        """bit-decompose a (traced) slice id into per-index 0/1 values."""
        ar = jnp.arange(self.num_sliced, dtype=jnp.int32)
        return (
            jnp.right_shift(jnp.asarray(slice_id, jnp.int32), ar) & 1
        ).astype(jnp.int32)

    def contract_slice(self, arrays: Sequence[jnp.ndarray], slice_id):
        """Contract one subtask (slice assignment = bits of slice_id)."""
        svals = self.slice_values(slice_id)
        env: dict[int, jnp.ndarray] = {}
        for i, arr in enumerate(arrays):
            a = jnp.asarray(arr)
            for axis, spos in self.leaf_specs[i]:
                a = jax.lax.dynamic_index_in_dim(
                    a, svals[spos], axis=axis, keepdims=False
                )
            env[i] = a
        if self.schedule is None:
            for st in self.steps:
                env[st.out] = jnp.einsum(st.expr, env[st.lhs], env[st.rhs])
                del env[st.lhs], env[st.rhs]
        else:
            from ..lowering import gemm_form  # lazy: avoid cycle

            for st, spec in zip(self.steps, self.schedule.specs):
                env[st.out] = gemm_form.apply(spec, env[st.lhs], env[st.rhs])
                del env[st.lhs], env[st.rhs]
        out = env[self.root]
        if self.out_perm and self.out_perm != tuple(range(out.ndim)):
            out = jnp.transpose(out, self.out_perm)
        return out

    # ------------------------------------------------------------------
    def contract_all(
        self,
        arrays: Sequence[jnp.ndarray],
        slice_batch: int = 8,
    ) -> jnp.ndarray:
        """Sum over all 2^|S| subtasks (single host).  Subtasks run in
        vmapped batches of ``slice_batch`` and are accumulated with a
        ``lax.scan`` so peak memory is bounded."""
        n_slices = 1 << self.num_sliced
        if self.num_sliced == 0:
            key = ("dense",)
            # setdefault: concurrent serving threads race to publish, but
            # all end up calling the one surviving jitted fn (single trace)
            fn = self._compiled.get(key) or self._compiled.setdefault(
                key, jax.jit(lambda a: self.contract_slice(a, 0))
            )
            return fn(list(arrays))
        slice_batch = min(slice_batch, n_slices)
        assert n_slices % slice_batch == 0
        key = ("all", slice_batch)
        fn = self._compiled.get(key)
        if fn is None:
            ids = jnp.arange(n_slices, dtype=jnp.int32).reshape(
                -1, slice_batch
            )

            @jax.jit
            def run(arrs):
                batched = jax.vmap(
                    lambda sid: self.contract_slice(arrs, sid)
                )

                def body(acc, chunk):
                    return acc + jnp.sum(batched(chunk), axis=0), None

                out_shape = jax.eval_shape(
                    lambda: jnp.sum(batched(ids[0]), axis=0)
                )
                acc0 = jnp.zeros(out_shape.shape, out_shape.dtype)
                acc, _ = jax.lax.scan(body, acc0, ids)
                return acc

            fn = self._compiled.setdefault(key, run)
        return fn(list(arrays))


def contract_dense(
    tn: TensorNetwork, arrays: Sequence[np.ndarray], tree: ContractionTree
) -> jnp.ndarray:
    """Unsliced contraction (reference path)."""
    return ContractionPlan(tree, 0).contract_all(arrays)
