"""Contraction-order search.

The paper (and its baselines Cotengra/Alibaba) rely on anytime heuristics:
randomized greedy search over pairwise contractions, graph-partition guided
orders, and local tuning.  We implement:

  * ``greedy_ssa_path``     — opt_einsum/cotengra-style greedy with Boltzmann
                              (temperature) randomization.
  * ``random_greedy_tree``  — multi-restart greedy, keep the best tree by
                              C(B) (Eq. 3).
  * ``partition_ssa_path``  — recursive bisection (KL-style refinement of a
                              BFS grown cut), the kahypar/GN analogue.
  * ``dp_optimal_tree``     — exact subset DP (Pfeifer et al.) for small
                              networks; used as test oracle.
"""

from __future__ import annotations

import heapq
import math
import random
from typing import Sequence

from .contraction_tree import ContractionTree
from .tensor_network import TensorNetwork, bits, popcount


def _gumbel(rng: random.Random) -> float:
    """Standard Gumbel noise — the Boltzmann-randomization primitive
    shared by the greedy pathfinder and the reconfiguration moves."""
    return -math.log(-math.log(rng.random() + 1e-12) + 1e-12)


# ----------------------------------------------------------------------
# greedy
# ----------------------------------------------------------------------
def greedy_ssa_path(
    tn: TensorNetwork,
    seed: int = 0,
    temperature: float = 0.0,
) -> list[tuple[int, int]]:
    """Greedy pairwise contraction minimizing ``size(out) - size(a) -
    size(b)`` with optional Boltzmann noise (temperature in log2-size
    units)."""
    rng = random.Random(seed)
    masks: dict[int, int] = {i: m for i, m in enumerate(tn.masks)}
    open_m = tn.open_mask
    owners: dict[int, set[int]] = {}
    for i, m in masks.items():
        for b in bits(m & ~open_m):
            owners.setdefault(b, set()).add(i)

    def result(ma: int, mb: int) -> int:
        return (ma ^ mb) | (ma & mb & open_m)

    def score(ma: int, mb: int) -> float:
        r = result(ma, mb)
        s = 2.0 ** popcount(r) - 2.0 ** popcount(ma) - 2.0 ** popcount(mb)
        if temperature > 0.0:
            s -= temperature * _gumbel(rng) * max(abs(s), 1.0)
        return s

    heap: list[tuple[float, int, int]] = []
    seen_pairs: set[tuple[int, int]] = set()

    def push_pairs_of(i: int) -> None:
        cands: set[int] = set()
        for b in bits(masks[i] & ~open_m):
            cands |= owners.get(b, set())
        cands.discard(i)
        for j in cands:
            key = (min(i, j), max(i, j))
            if key not in seen_pairs:
                seen_pairs.add(key)
                heapq.heappush(heap, (score(masks[i], masks[j]), *key))

    for i in list(masks):
        push_pairs_of(i)

    ssa = len(masks)
    path: list[tuple[int, int]] = []
    n_alive = len(masks)
    while n_alive > 1:
        contracted = False
        while heap:
            _, a, b = heapq.heappop(heap)
            if a in masks and b in masks:
                contracted = True
                break
        if not contracted:
            # disconnected components: contract two arbitrary survivors
            alive = sorted(masks)
            a, b = alive[0], alive[1]
        ma, mb = masks.pop(a), masks.pop(b)
        for b_ in bits(ma & ~open_m):
            owners[b_].discard(a)
        for b_ in bits(mb & ~open_m):
            owners[b_].discard(b)
        nid = ssa
        ssa += 1
        masks[nid] = result(ma, mb)
        for b_ in bits(masks[nid] & ~open_m):
            owners.setdefault(b_, set()).add(nid)
        path.append((a, b))
        push_pairs_of(nid)
        n_alive -= 1
    return path


def random_greedy_tree(
    tn: TensorNetwork,
    repeats: int = 16,
    seed: int = 0,
    temperatures: Sequence[float] = (0.0, 0.3, 1.0),
) -> ContractionTree:
    best: ContractionTree | None = None
    best_cost = float("inf")
    for r in range(repeats):
        temp = temperatures[r % len(temperatures)] if r else 0.0
        path = greedy_ssa_path(tn, seed=seed + r, temperature=temp)
        tree = ContractionTree.from_ssa_path(tn, path)
        c = tree.total_cost()
        if c < best_cost:
            best, best_cost = tree, c
    assert best is not None
    return best


# ----------------------------------------------------------------------
# local reconfiguration moves (anytime co-optimizer, repro.optimize)
# ----------------------------------------------------------------------
def local_ssa_order(
    masks: Sequence[int],
    open_m: int,
    rng: random.Random | None = None,
    temperature: float = 0.0,
) -> list[tuple[int, int]]:
    """Greedy pairwise order over a small set of tensors, as an SSA path
    over *positions* (result of pair ``j`` takes position
    ``len(masks) + j``) — the format :meth:`ContractionTree.
    splice_subtree` consumes.  Minimizes result size, prefers connected
    pairs, with optional Boltzmann noise for randomized reconfiguration
    moves."""
    masks = list(masks)
    alive = list(range(len(masks)))
    pairs: list[tuple[int, int]] = []

    def result(ma: int, mb: int) -> int:
        return (ma ^ mb) | (ma & mb & open_m)

    while len(alive) > 1:
        best = None
        best_s = float("inf")
        for i in range(len(alive)):
            for j in range(i + 1, len(alive)):
                ma, mb = masks[alive[i]], masks[alive[j]]
                shared = popcount(ma & mb & ~open_m)
                s = 2.0 ** popcount(result(ma, mb))
                if not shared:
                    s *= 1e6  # prefer connected pairs
                if temperature > 0.0 and rng is not None:
                    s *= math.exp(-temperature * _gumbel(rng))
                if s < best_s:
                    best_s, best = s, (i, j)
        i, j = best
        pa, pb = alive[i], alive[j]
        masks.append(result(masks[pa], masks[pb]))
        pairs.append((pa, pb))
        alive = [x for k, x in enumerate(alive) if k not in (i, j)]
        alive.append(len(masks) - 1)
    return pairs


def reconfigure_subtree(
    tree: ContractionTree,
    rng: random.Random,
    max_roots: int = 8,
    temperature: float = 0.3,
):
    """One subtree-reconfiguration move: pick an internal node (sampled
    with probability proportional to its contraction cost, so expensive
    regions are reworked most often), cut its subtree at a ≤``max_roots``
    frontier, and splice a freshly searched local order back in place.

    Returns the :class:`~repro.core.contraction_tree.SpliceResult` (undo
    record + incremental cost delta), or ``None`` when no productive
    region exists.  The caller owns accept/reject:
    ``tree.unsplice(result)`` reverts the move exactly."""
    internal = tree.internal_nodes()
    if not internal:
        return None
    # cost-weighted sample over log2 costs (avoids overflow on wide trees)
    log2s = [(popcount(tree.node_mask(v)), v) for v in internal]
    top = max(c for c, _ in log2s)
    weights = [2.0 ** (c - top) for c, _ in log2s]
    r = rng.random() * sum(weights)
    v = log2s[-1][1]
    for w, (_, cand) in zip(weights, log2s):
        r -= w
        if r <= 0:
            v = cand
            break
    frontier = tree.subtree_frontier(v, max_roots=max_roots)
    if len(frontier) < 3:
        return None
    pairs = local_ssa_order(
        [tree.emask[f] for f in frontier],
        tree.tn.open_mask,
        rng=rng,
        temperature=temperature,
    )
    return tree.splice_subtree(v, frontier, pairs)


def boltzmann_restart_tree(
    tn: TensorNetwork,
    rng: random.Random,
    temperatures: Sequence[float] = (0.0, 0.2, 0.5, 1.0),
) -> ContractionTree:
    """A fresh greedy tree at a randomly drawn Boltzmann temperature —
    the co-optimizer's escape hatch out of a stalled basin."""
    return ContractionTree.from_ssa_path(
        tn,
        greedy_ssa_path(
            tn,
            seed=rng.randrange(1 << 31),
            temperature=rng.choice(list(temperatures)),
        ),
    )


# ----------------------------------------------------------------------
# recursive bisection (GN/kahypar analogue)
# ----------------------------------------------------------------------
def partition_ssa_path(
    tn: TensorNetwork, seed: int = 0, leaf_size: int = 8
) -> list[tuple[int, int]]:
    """Recursive bisection: grow a balanced cut by BFS, refine KL-style,
    recurse, contract each side greedily, then join."""
    rng = random.Random(seed)
    # Partitioning acts as an ordering constraint on greedy: build the
    # hierarchy of vertex groups, then emit contractions bottom-up.
    adj = tn.neighbors()

    def bisect(vs: list[int]) -> tuple[list[int], list[int]]:
        vset = set(vs)
        start = rng.choice(vs)
        side = {start}
        frontier = [start]
        target = len(vs) // 2
        while len(side) < target and frontier:
            nxt: list[int] = []
            for v in frontier:
                for u in adj[v]:
                    if u in vset and u not in side and len(side) < target:
                        side.add(u)
                        nxt.append(u)
            frontier = nxt
            if not frontier and len(side) < target:
                rest = [v for v in vs if v not in side]
                side.add(rng.choice(rest))
                frontier = [next(iter(side))]
        part = [0 if v in side else 1 for v in vs]
        part = _refine_cut_sub(vs, part)
        a = [v for v, p in zip(vs, part) if p == 0]
        b = [v for v, p in zip(vs, part) if p == 1]
        if not a or not b:
            half = len(vs) // 2
            a, b = vs[:half], vs[half:]
        return a, b

    def _refine_cut_sub(vs: list[int], part: list[int]) -> list[int]:
        pos = {v: i for i, v in enumerate(vs)}
        n = len(vs)

        def gain(i: int) -> int:
            g = 0
            for u in adj[vs[i]]:
                j = pos.get(u)
                if j is not None:
                    g += 1 if part[j] != part[i] else -1
            return g

        for _ in range(4):
            moved = False
            sizes = [part.count(0), part.count(1)]
            for i in sorted(range(n), key=gain, reverse=True):
                g = gain(i)
                src = part[i]
                if g > 0 and sizes[src] - 1 >= max(1, int(0.4 * n)):
                    part[i] = 1 - src
                    sizes[src] -= 1
                    sizes[1 - src] += 1
                    moved = True
            if not moved:
                break
        return part

    def groups(vs: list[int]) -> list:
        if len(vs) <= leaf_size:
            return vs  # leaf group
        a, b = bisect(vs)
        return [groups(a), groups(b)]

    hierarchy = groups(list(range(tn.num_tensors)))

    # emit contractions: within each leaf group greedily (by shared-index
    # result size), then join group representatives pairwise up the tree.
    masks: dict[int, int] = {i: m for i, m in enumerate(tn.masks)}
    open_m = tn.open_mask
    ssa_counter = [tn.num_tensors]
    path: list[tuple[int, int]] = []

    def result(ma: int, mb: int) -> int:
        return (ma ^ mb) | (ma & mb & open_m)

    def contract_ids(ids: list[int]) -> int:
        ids = list(ids)
        while len(ids) > 1:
            best = None
            best_s = float("inf")
            for i in range(len(ids)):
                for j in range(i + 1, len(ids)):
                    ma, mb = masks[ids[i]], masks[ids[j]]
                    shared = popcount(ma & mb & ~open_m)
                    s = 2.0 ** popcount(result(ma, mb))
                    s = s if shared else s * 1e6  # prefer connected pairs
                    if s < best_s:
                        best_s, best = s, (i, j)
            i, j = best
            a, b = ids[i], ids[j]
            nid = ssa_counter[0]
            ssa_counter[0] += 1
            masks[nid] = result(masks[a], masks[b])
            path.append((a, b))
            ids = [x for k, x in enumerate(ids) if k not in (i, j)] + [nid]
        return ids[0]

    def emit(h) -> int:
        if isinstance(h, list) and len(h) == 2 and isinstance(h[0], list):
            a = emit(h[0])
            b = emit(h[1])
            nid = ssa_counter[0]
            ssa_counter[0] += 1
            masks[nid] = result(masks[a], masks[b])
            path.append((a, b))
            return nid
        # leaf group (flat list of ints)
        return contract_ids(h if isinstance(h, list) else [h])

    emit(hierarchy)
    return path


# ----------------------------------------------------------------------
# exact DP (test oracle for small networks)
# ----------------------------------------------------------------------
def dp_optimal_tree(tn: TensorNetwork) -> ContractionTree:
    """Exact minimum-C(B) tree over all binary contraction orders.

    Subset DP over tensors; feasible up to ~13 tensors.
    """
    n = tn.num_tensors
    if n > 14:
        raise ValueError("dp_optimal_tree limited to <= 14 tensors")
    open_m = tn.open_mask
    full_masks = list(tn.masks)

    # union of index occurrences per subset, to derive the subset's result
    # mask: an index survives iff it appears an odd number of... no — degree
    # model: index appears in exactly 2 tensors; survives the subset iff
    # exactly one owner is inside (or it is open).
    owners0: dict[int, list[int]] = {}
    for i, m in enumerate(full_masks):
        for b in bits(m):
            owners0.setdefault(b, []).append(i)

    def subset_mask(ss: int) -> int:
        out = 0
        for b, ow in owners0.items():
            inside = sum(1 for i in ow if ss >> i & 1)
            if inside == 0:
                continue
            if (1 << b) & open_m:
                out |= 1 << b
            elif inside < len(ow):
                out |= 1 << b
        return out

    smask_cache = {1 << i: full_masks[i] for i in range(n)}
    cost: dict[int, float] = {1 << i: 0.0 for i in range(n)}
    plan: dict[int, tuple[int, int] | None] = {1 << i: None for i in range(n)}

    by_size: list[list[int]] = [[] for _ in range(n + 1)]
    for ss in range(1, 1 << n):
        by_size[ss.bit_count()].append(ss)

    for size in range(2, n + 1):
        for ss in by_size[size]:
            best = float("inf")
            bplan = None
            sub = (ss - 1) & ss
            while sub:
                other = ss ^ sub
                if sub < other:  # canonical split order; visit each once
                    if sub in cost and other in cost:
                        ma = smask_cache.setdefault(sub, subset_mask(sub))
                        mb = smask_cache.setdefault(other, subset_mask(other))
                        c = (
                            cost[sub]
                            + cost[other]
                            + 2.0 ** popcount(ma | mb)
                        )
                        if c < best:
                            best = c
                            bplan = (sub, other)
                sub = (sub - 1) & ss
            if bplan is not None:
                cost[ss] = best
                plan[ss] = bplan
                smask_cache.setdefault(ss, subset_mask(ss))

    # reconstruct ssa path
    ssa_of: dict[int, int] = {1 << i: i for i in range(n)}
    counter = [n]
    path: list[tuple[int, int]] = []

    def build(ss: int) -> int:
        if plan[ss] is None:
            return ssa_of[ss]
        a, b = plan[ss]
        ia, ib = build(a), build(b)
        nid = counter[0]
        counter[0] += 1
        path.append((ia, ib))
        ssa_of[ss] = nid
        return nid

    build((1 << n) - 1)
    return ContractionTree.from_ssa_path(tn, path)
