"""Iterative tree tuning via branch exchange (Sec. IV-C, Algorithm 2).

The paper interleaves sliceFinder with *branch exchanges* on the stem:
swapping two neighbouring branches B1, B2,

    q = (T, B1), p = (q, B2)   →   q' = (T, B2), p' = (q', B1)

changes only the middle tensor (q's result) and therefore only the two
node costs — the exchange condition (Eq. 8/9) reduces to comparing those
two local sliced costs, O(1) with bitmask popcounts.  We evaluate the gain
*exactly* under Eq. 6 instead of the paper's closed-form inequality (same
decision, fewer special cases) and sweep the stem until a fixed point,
re-running sliceFinder between sweeps exactly as Algorithm 2 prescribes.

Deviation from the paper, recorded in DESIGN.md: Algorithm 2 picks a random
stem position and retries with a fail counter; we use deterministic full
sweeps (strictly a superset of the moves, reproducible in tests).
"""

from __future__ import annotations

import dataclasses

from .contraction_tree import ContractionTree
from .lifetime import detect_stem
from .slicing import ensure_width, slice_finder
from .tensor_network import popcount


def _local_sliced_cost(tree: ContractionTree, nodes, S: int) -> float:
    tot = 0.0
    for v in nodes:
        nm = tree.node_mask(v)
        tot += 2.0 ** (popcount(nm) - popcount(S & nm))
    return tot


def exchange_gain(
    tree: ContractionTree,
    p: int,
    q: int,
    branch_q: int,
    branch_p: int,
    S: int,
) -> tuple[float, int]:
    """(gain, new_mid_width): positive gain ⇒ exchanging lowers the local
    Eq. 6 cost.  ``new_mid_width`` is the post-slicing width of the new
    intermediate (memory guard)."""
    em = tree.emask
    spine = [c for c in tree.children[q] if c != branch_q][0]
    open_m = tree.tn.open_mask

    def res(ma: int, mb: int) -> int:
        return (ma ^ mb) | (ma & mb & open_m)

    before = _local_sliced_cost(tree, (p, q), S)
    new_q = res(em[spine], em[branch_p])
    nm_q = em[spine] | em[branch_p]
    nm_p = new_q | em[branch_q]
    after = (
        2.0 ** (popcount(nm_q) - popcount(S & nm_q))
        + 2.0 ** (popcount(nm_p) - popcount(S & nm_p))
    )
    return before - after, popcount(new_q & ~S)


@dataclasses.dataclass
class TuningResult:
    tree: ContractionTree
    smask: int
    sliced_cost: float
    rounds: int
    exchanges: int


def tuning_slice_finder(
    tree: ContractionTree,
    target_dim: int,
    max_rounds: int = 20,
    slicer=slice_finder,
) -> TuningResult:
    """Algorithm 2: alternate sliceFinder and branch-exchange sweeps.

    Keeps the best (tree, S) seen by total sliced cost; stops after a sweep
    with no improving exchange or ``max_rounds``.
    """
    work = tree.copy()
    best_tree = work.copy()
    best_S = ensure_width(work, slicer(work, target_dim), target_dim)
    best_cost = work.sliced_cost(best_S)
    total_exchanges = 0
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        stem = detect_stem(work)
        S = ensure_width(work, slicer(work, target_dim, stem=stem), target_dim)
        width_cap = max(target_dim, work.sliced_width(S))
        swept = 0
        for i in range(len(stem.nodes) - 1):
            args = stem.exchange_args(i)
            if args is None:
                continue
            pp, qq, bq, bp = args
            # surgery from earlier sweeps may have detached this pair
            if work.parent.get(qq) != pp:
                continue
            if bq not in work.children.get(qq, ()) or (
                bp not in work.children.get(pp, ())
            ):
                continue
            gain, new_w = exchange_gain(work, pp, qq, bq, bp, S)
            if gain > 0 and new_w <= width_cap:
                work.exchange_at(pp, qq, bq, bp)
                swept += 1
        total_exchanges += swept
        S2 = ensure_width(work, slicer(work, target_dim), target_dim)
        c2 = work.sliced_cost(S2)
        if c2 < best_cost:
            best_cost = c2
            best_S = S2
            best_tree = work.copy()
        if swept == 0:
            break
    return TuningResult(best_tree, best_S, best_cost, rounds, total_exchanges)
