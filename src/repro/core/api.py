"""End-to-end pipeline: circuit → network → path → slicing → tuning →
merging → lowering → sliced JAX contraction.  This is the public API the
examples and benchmarks drive.

``backend="gemm"`` compiles the planned tree through
:mod:`repro.lowering` into an explicit kernel schedule (Pallas tiled
GEMMs + refined fallbacks); the default ``"einsum"`` keeps the oracle
path.  Planned artifacts are memoized in the compiled-plan cache
(:data:`repro.lowering.cache.PLAN_CACHE`) keyed by the canonical network
fingerprint + planner parameters, so repeated requests for the same
circuit family skip planning and retracing — pass ``use_cache=False``
to force a fresh plan.
"""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from ..obs import trace as _trace
from .contraction_tree import ContractionTree
from .executor import (
    ContractionPlan,
    auto_slice_batch,
    default_backend,
    default_hoist,
    simplify_network,
)
from .merging import modeled_tree_time
from .tensor_network import popcount


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if b < 1024:
            return f"{b:.0f}{unit}"
        b /= 1024
    return f"{b:.1f}TB"


@dataclasses.dataclass
class PlanReport:
    """Planner metrics mirroring the paper's reported quantities."""

    num_tensors: int
    width_before: int
    width_after: int
    log2_cost: float
    log2_sliced_cost: float
    num_sliced: int
    slicing_overhead: float  # Eq. 4
    modeled_time_s: float  # Sec. V model, one chip
    plan_wall_s: float
    # execution backend + lowering/cache metrics (PR 2)
    backend: str = "einsum"
    cache_hit: bool = False
    cache_hits: int = 0
    cache_misses: int = 0
    lowered_backends: dict | None = None  # node counts per kernel backend
    pad_waste: float = 0.0  # FLOPs-weighted MXU padding fraction
    # two-phase (lifetime-partitioned) execution metrics (PR 3)
    hoist: bool = True  # whether two-phase execution is enabled
    invariant_fraction: float = 0.0  # share of C(B) hoisted out of slices
    measured_overhead: float = 1.0  # executed-FLOPs overhead of the mode
    modeled_time_hoisted_s: float = 0.0  # Sec. V model under hoisting
    # lifetime-based memory plan + fused-kernel metrics (PR 4)
    peak_bytes: int = 0  # exact live-set peak, naive subtask
    peak_bytes_hoisted: int = 0  # live-set peak under two-phase execution
    buffer_slots: int = 0  # linear-scan slot count (naive subtask)
    transpose_bytes_saved: float = 0.0  # HBM bytes fused kernels avoid/slice
    # anytime path–slice co-optimizer metrics (PR 5)
    optimize: str = "oneshot"  # planner mode: oneshot | anytime
    search_evals: int = 0  # candidate evaluations the search spent
    search_trace: list | None = None  # best-so-far improvements (dicts)
    # epilogue megakernel metrics (PR 6)
    fused_chains: int = 0  # multi-step VMEM-resident chains planned
    chain_hbm_bytes_saved: float = 0.0  # modeled HBM bytes chains avoid/slice
    # observability (PR 7): metrics snapshot + per-span aggregates from
    # repro.obs.telemetry_summary(), populated only when tracing is on
    # (REPRO_TRACE=1 or the telemetry= toggle) — None otherwise
    telemetry: dict | None = None
    # multi-host scheduling (PR 8): realized max/mean host load, ranges
    # stolen across hosts, and the fraction of the reduction hidden
    # behind slice compute — populated by contract_multihost when a
    # report is threaded through; defaults describe a single-host run
    schedule_imbalance: float = 0.0  # 0.0 = not a multi-host run
    steal_count: int = 0
    overlap_fraction: float = 0.0
    # mixed precision under an XEB error budget (PR 9)
    precision: str = "fp32"  # resolved mode: fp32 | bf16 | auto
    fidelity_tol: float = 0.0  # the XEB budget the plan was certified at
    precision_counts: dict | None = None  # GEMM-step counts per precision
    predicted_amp_error: float = 0.0  # forward-model relative amp error

    def row(self) -> str:
        row = (
            f"tensors={self.num_tensors} W={self.width_before}->"
            f"{self.width_after} log2C={self.log2_cost:.2f} "
            f"slices={self.num_sliced} overhead={self.slicing_overhead:.3f} "
            f"t_model={self.modeled_time_s:.3e}s plan={self.plan_wall_s:.2f}s "
            f"backend={self.backend}"
        )
        if self.num_sliced:
            row += (
                f" hoist={'on' if self.hoist else 'off'}"
                f"[inv={self.invariant_fraction:.2f}"
                f" ov={self.measured_overhead:.3f}]"
            )
        if self.optimize != "oneshot":
            row += f" opt={self.optimize}[evals={self.search_evals}]"
        if self.peak_bytes:
            row += f" peak={_fmt_bytes(self.peak_bytes)}"
            if self.peak_bytes_hoisted != self.peak_bytes:
                row += f"->{_fmt_bytes(self.peak_bytes_hoisted)}"
            row += f" slots={self.buffer_slots}"
        if self.cache_hit:
            row += " cache=hit"
        if self.lowered_backends:
            nodes = " ".join(
                f"{k}={v}" for k, v in sorted(self.lowered_backends.items())
            )
            row += f" lowered[{nodes}] pad_waste={self.pad_waste*100:.1f}%"
            if self.transpose_bytes_saved:
                row += f" tb_saved={_fmt_bytes(self.transpose_bytes_saved)}"
        if self.fused_chains:
            row += (
                f" chains={self.fused_chains}"
                f" chain_saved={_fmt_bytes(self.chain_hbm_bytes_saved)}"
            )
        if self.schedule_imbalance:
            row += (
                f" sched[imb={self.schedule_imbalance:.2f}"
                f" steals={self.steal_count}"
                f" overlap={self.overlap_fraction:.2f}]"
            )
        if self.precision != "fp32":
            counts = self.precision_counts or {}
            total = sum(counts.values())
            row += (
                f" prec={self.precision}"
                f"[bf16={counts.get('bf16', 0)}/{total}"
                f" tol={self.fidelity_tol:g}"
                f" amp_err={self.predicted_amp_error:.2e}]"
            )
        return row


@dataclasses.dataclass
class SimulationResult:
    value: np.ndarray | complex
    report: PlanReport
    tree: ContractionTree
    smask: int
    plan: ContractionPlan | None = None  # carries the lowered schedule


def _telemetry_snapshot() -> dict:
    from .. import obs  # lazy: obs is also importable standalone

    return obs.telemetry_summary()


@_trace.traced("plan.build", cat="plan")
def plan_contraction(
    tn,
    target_dim: int,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    seed: int = 0,
    slicing_mode: str = "width",
    itemsize: int = 8,
    optimize: str = "oneshot",
    search_evals: int = 64,
    search_workers: int = 4,
    search_wall_s: float | None = None,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
):
    """Full planning pipeline on a tensor network.

    ``slicing_mode="peak"`` re-judges the final slicing mask against the
    lifetime-based memory plan's live-set peak instead of the width
    proxy (see :func:`repro.core.slicing.refine_slices_for_peak`):
    indices the true peak never needed are dropped, shrinking the
    ``2^|S|`` subtask count at the same byte budget.

    ``optimize="anytime"`` replaces the staged pipeline with the
    path–slice–memory co-optimizer (:func:`repro.optimize.plan_search`):
    the slicer is re-invoked in place after every accepted tree move and
    candidates are scored by hoist-aware executed FLOPs under the
    certified peak budget.  ``search_evals`` / ``search_wall_s`` are the
    anytime budgets (stopping early always yields a plan no worse than
    the one-shot seed); the returned report carries the improvement
    trace in ``PlanReport.search_trace``."""
    from ..optimize import oneshot_plan, plan_search

    t0 = time.perf_counter()
    search_trace = None
    if optimize == "anytime":
        sr = plan_search(
            tn,
            target_dim,
            budget_bytes=budget_bytes,
            itemsize=itemsize,
            num_workers=search_workers,
            max_evals=search_evals,
            wall_clock_s=search_wall_s,
            seed=seed,
            method=method,
            tune=tune,
            merge=merge,
            repeats=repeats,
            slicing_mode=slicing_mode,
            precision=precision,
            fidelity_tol=fidelity_tol,
        )
        tree, smask = sr.tree, sr.smask
        width0 = sr.width_before  # raw greedy seed width, as in oneshot
        search_trace = [dataclasses.asdict(t) for t in sr.trace]
    elif optimize == "oneshot":
        shot = oneshot_plan(
            tn, target_dim, method=method, tune=tune, merge=merge,
            repeats=repeats, seed=seed, slicing_mode=slicing_mode,
            itemsize=itemsize, budget_bytes=budget_bytes,
            precision=precision, fidelity_tol=fidelity_tol,
        )
        tree, smask, width0 = shot.tree, shot.smask, shot.width_before
    else:
        raise ValueError(f"unknown optimize {optimize!r}")
    wall = time.perf_counter() - t0
    naive_overhead = tree.slicing_overhead(smask)
    hoist_on = default_hoist()
    invariant_fraction = 0.0
    hoisted_overhead = naive_overhead
    part = None
    if smask:
        from ..lowering.partition import partition_tree  # lazy: cycle

        part = partition_tree(tree, smask)
        invariant_fraction = part.invariant_fraction
        hoisted_overhead = part.hoisted_overhead()
    modeled = modeled_tree_time(tree, smask)
    from ..lowering.memory import plan_memory  # lazy: avoid cycle

    mem = plan_memory(tree, smask, itemsize=itemsize, part=part)
    report = PlanReport(
        num_tensors=tn.num_tensors,
        width_before=width0,
        width_after=tree.sliced_width(smask),
        log2_cost=tree.log2_total_cost(),
        log2_sliced_cost=math.log2(tree.sliced_cost(smask)),
        num_sliced=popcount(smask),
        slicing_overhead=naive_overhead,
        modeled_time_s=modeled,
        plan_wall_s=wall,
        hoist=hoist_on,
        invariant_fraction=invariant_fraction,
        measured_overhead=hoisted_overhead if hoist_on else naive_overhead,
        modeled_time_hoisted_s=modeled * hoisted_overhead / naive_overhead,
        peak_bytes=mem.peak_bytes,
        peak_bytes_hoisted=mem.peak_bytes_hoisted,
        buffer_slots=mem.buffer_slots,
        optimize=optimize,
        search_evals=sr.evaluations if optimize == "anytime" else 0,
        search_trace=search_trace,
    )
    return tree, smask, report


def plan_compiled(
    tn,
    target_dim: int,
    dtype=None,
    backend: str | None = None,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    seed: int = 0,
    use_cache: bool = True,
    slicing_mode: str = "width",
    optimize: str = "oneshot",
    search_evals: int = 64,
    search_workers: int = 4,
    search_wall_s: float | None = None,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
    telemetry: bool | None = None,
) -> tuple[ContractionPlan, PlanReport]:
    """Plan + lower a network into an executable :class:`ContractionPlan`,
    consulting the compiled-plan cache.

    ``precision`` (``None`` follows ``REPRO_PRECISION``, default
    ``"fp32"``) selects mixed-precision lowering: ``"auto"`` demotes MXU
    GEMM steps to bf16-input/fp32-accumulate while the forward error
    model keeps the predicted Linear-XEB fidelity loss within
    ``fidelity_tol`` (``None`` → the 0.05 default); ``"bf16"`` forces
    every eligible step.  The resolved mode and (for non-fp32 modes) the
    tolerance join the plan fingerprint, so plans at different budgets
    never alias; fp32 plans ignore the tolerance and share one entry.

    ``telemetry=True`` forces span tracing + metrics on for this call
    (``False`` forces off, ``None`` follows ``REPRO_TRACE``); when
    tracing is on the returned report carries
    ``PlanReport.telemetry`` — the :func:`repro.obs.telemetry_summary`
    snapshot taken after planning.  The toggle never joins the plan
    fingerprint: traced and untraced calls share cache entries and
    produce bitwise-identical plans.

    The cache key is the canonical network fingerprint (structure +
    dtype + open indices, invariant under index relabeling) plus every
    planner/lowering parameter, so a hit returns the *identical* plan
    object — its lowered schedule and memoized jitted executables ride
    along, which is what makes a hit skip retracing, not just planning.
    The slicing mask ``S`` is part of the cached artifact (it is a
    deterministic function of the key).

    ``optimize="anytime"`` plans through the co-optimizer
    (:func:`repro.optimize.plan_search`); the search parameters join the
    fingerprint, so a search *result* is cache-addressable — repeated
    requests for the same circuit family at the same budgets reuse the
    searched plan without re-running the search.  A wall-clock budget
    (``search_wall_s``) makes the searched plan machine-dependent, so
    such plans are still cached but only deterministic across processes
    when ``search_wall_s=None``.
    """
    with _trace.enabled_scope(telemetry):
        plan, report = _plan_compiled(
            tn, target_dim, dtype=dtype, backend=backend, method=method,
            tune=tune, merge=merge, repeats=repeats, seed=seed,
            use_cache=use_cache, slicing_mode=slicing_mode,
            optimize=optimize, search_evals=search_evals,
            search_workers=search_workers, search_wall_s=search_wall_s,
            budget_bytes=budget_bytes, precision=precision,
            fidelity_tol=fidelity_tol,
        )
        if _trace.enabled():
            report = dataclasses.replace(
                report, telemetry=_telemetry_snapshot()
            )
    return plan, report


def _plan_compiled(
    tn,
    target_dim: int,
    dtype=None,
    backend: str | None = None,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    seed: int = 0,
    use_cache: bool = True,
    slicing_mode: str = "width",
    optimize: str = "oneshot",
    search_evals: int = 64,
    search_workers: int = 4,
    search_wall_s: float | None = None,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
) -> tuple[ContractionPlan, PlanReport]:
    from ..lowering.cache import PLAN_CACHE, PlanEntry, network_fingerprint
    from ..lowering.precision import (
        DEFAULT_FIDELITY_TOL,
        PRECISION_MODES,
        default_precision,
    )
    from ..lowering.refiner import default_fused, default_megakernel

    import jax.numpy as jnp

    backend = backend if backend is not None else default_backend()
    dtype = jnp.dtype(dtype if dtype is not None else jnp.complex64)
    precision_mode = precision if precision is not None else default_precision()
    if precision_mode not in PRECISION_MODES:
        raise ValueError(
            f"precision {precision_mode!r} not in {PRECISION_MODES}"
        )
    tol = DEFAULT_FIDELITY_TOL if fidelity_tol is None else float(fidelity_tol)
    t0 = time.perf_counter()

    def _build() -> PlanEntry:
        plan, report = _plan_fresh(
            tn, target_dim, dtype=dtype, backend=backend, method=method,
            tune=tune, merge=merge, repeats=repeats, seed=seed,
            slicing_mode=slicing_mode, optimize=optimize,
            search_evals=search_evals, search_workers=search_workers,
            search_wall_s=search_wall_s, budget_bytes=budget_bytes,
            precision_mode=precision_mode, tol=tol, t0=t0,
        )
        return PlanEntry(plan, report)

    if not use_cache:
        ent = _build()
        return ent.plan, ent.report
    # REPRO_FUSED_GEMM changes the refined schedule, so it is part of
    # the key (like the backend itself)
    # search params only shape the plan under optimize="anytime" —
    # keep them out of the oneshot key so ignored knobs cannot
    # cause spurious cache misses
    search_key = (
        (search_evals, search_workers, search_wall_s)
        if optimize == "anytime"
        else ()
    )
    # REPRO_MEGAKERNEL changes the plan's chain dispatch the same way
    # REPRO_FUSED_GEMM changes its schedule — both join the key
    # the resolved precision mode always joins the key; the fidelity
    # tolerance only matters off fp32, so fp32 plans at different
    # tolerances share one entry instead of fragmenting the cache
    key = network_fingerprint(
        tn,
        dtype,
        extra=(backend, target_dim, method, tune, merge, repeats, seed,
               slicing_mode, default_fused(), default_megakernel(),
               optimize, budget_bytes, search_key,
               precision_mode,
               tol if precision_mode != "fp32" else None),
    )
    fresh: list[PlanEntry] = []

    def _factory() -> PlanEntry:
        ent = _build()
        fresh.append(ent)
        return ent

    # single-flight: concurrent misses on one family (threaded serving
    # dispatch) elect one planner; the rest wait for its entry instead of
    # replanning — and the get→plan→put race that let two threads each
    # plan and the loser overwrite the winner's jit-warmed plan is gone
    ent = PLAN_CACHE.single_flight(key, _factory)
    stats = PLAN_CACHE.stats()
    if fresh:
        # this thread planned: report the fresh-planning run
        return ent.plan, dataclasses.replace(
            ent.report,
            cache_hits=stats["hits"],
            cache_misses=stats["misses"],
            search_trace=(
                [dict(t) for t in ent.report.search_trace]
                if ent.report.search_trace is not None
                else None
            ),
        )
    # cache hit (or waited on another thread's in-flight planning).
    # hoist mode is an execution-time choice (REPRO_HOIST may have
    # changed since the plan was cached): re-derive it so the
    # report describes the mode that will actually run
    hoist_on = default_hoist()
    report = dataclasses.replace(
        ent.report,
        plan_wall_s=time.perf_counter() - t0,
        cache_hit=True,
        cache_hits=stats["hits"],
        cache_misses=stats["misses"],
        hoist=hoist_on,
        measured_overhead=ent.plan.executed_overhead(hoist_on),
        # copy the one mutable field so a caller mutating its
        # report can never corrupt the cached template
        search_trace=(
            [dict(t) for t in ent.report.search_trace]
            if ent.report.search_trace is not None
            else None
        ),
    )
    return ent.plan, report


def _plan_fresh(
    tn,
    target_dim: int,
    dtype,
    backend: str,
    method: str,
    tune: bool,
    merge: bool,
    repeats: int,
    seed: int,
    slicing_mode: str,
    optimize: str,
    search_evals: int,
    search_workers: int,
    search_wall_s: float | None,
    budget_bytes: int | None,
    precision_mode: str,
    tol: float,
    t0: float,
) -> tuple[ContractionPlan, PlanReport]:
    """One fresh planning + lowering run (no cache consultation) — the
    body a :meth:`PlanCache.single_flight` leader executes."""
    tree, smask, report = plan_contraction(
        tn, target_dim, method=method, tune=tune, merge=merge,
        repeats=repeats, seed=seed, slicing_mode=slicing_mode,
        itemsize=dtype.itemsize, optimize=optimize,
        search_evals=search_evals, search_workers=search_workers,
        search_wall_s=search_wall_s, budget_bytes=budget_bytes,
        precision=precision_mode, fidelity_tol=tol,
    )
    with _trace.span("plan.lower", cat="plan", backend=backend):
        plan = ContractionPlan(
            tree, smask, backend=backend, dtype=dtype,
            precision=precision_mode, fidelity_tol=tol,
        )
    report.backend = plan.backend
    report.precision = plan.precision_mode
    if plan.precision_mode != "fp32":
        report.fidelity_tol = plan.fidelity_tol
    # re-derive the two-phase metrics from the plan's own partition so the
    # report always describes the object that will execute (the memory
    # fields were already computed by plan_contraction with this dtype's
    # itemsize — no recompute needed)
    report.invariant_fraction = plan.invariant_fraction
    report.measured_overhead = plan.executed_overhead(report.hoist)
    if plan.schedule is not None:
        # refiner feedback: the modeled time now reflects the refined
        # schedule that will actually execute (per-slice × slice count)
        report.modeled_time_s = plan.schedule.modeled_time_s * (
            1 << plan.num_sliced
        )
        # hoisted variant: prologue specs run once, epilogue per slice
        prologue_t = sum(
            plan.schedule.specs[k].modeled_time_s for k in plan.prologue_idx
        )
        report.modeled_time_hoisted_s = prologue_t + (
            plan.schedule.modeled_time_s - prologue_t
        ) * (1 << plan.num_sliced)
        report.lowered_backends = plan.schedule.backend_counts()
        report.pad_waste = plan.schedule.pad_waste()
        report.transpose_bytes_saved = (
            plan.schedule.transpose_bytes_eliminated()
        )
        report.precision_counts = plan.schedule.precision_counts()
        report.predicted_amp_error = plan.schedule.predicted_amp_error
        if plan._itemsize_of:
            # bf16-stored intermediates shrink the true live-set peak —
            # re-derive the memory fields from the plan's own dtype-true
            # memory plan (plan_contraction counted fp32 storage)
            mem = plan.memory_plan()
            report.peak_bytes = mem.peak_bytes
            report.peak_bytes_hoisted = mem.peak_bytes_hoisted
            report.buffer_slots = mem.buffer_slots
    if plan.chain_plan is not None:
        report.fused_chains = plan.chain_plan.num_multi
        # per-slice saving in the mode that will execute: under hoisting
        # the epilogue is what runs once per slice
        seg = (
            "epilogue"
            if report.hoist and plan.can_hoist and plan.num_sliced
            else "naive"
        )
        report.chain_hbm_bytes_saved = plan.chain_plan.hbm_bytes_saved(seg)
        # cost-model correction: a chained step no longer pays the HBM
        # round-trip of its interior output nor the unfused backends'
        # transpose-copy traffic (kept disjoint in FusedChainSpec, so
        # nothing is double-charged) — feed the per-segment savings back
        # into the modeled times the planner reports
        cp = plan.chain_plan
        report.modeled_time_s = max(
            0.0,
            report.modeled_time_s
            - cp.modeled_time_saved_s("naive") * (1 << plan.num_sliced),
        )
        report.modeled_time_hoisted_s = max(
            0.0,
            report.modeled_time_hoisted_s
            - cp.modeled_time_saved_s("prologue")
            - cp.modeled_time_saved_s("epilogue") * (1 << plan.num_sliced),
        )
    report.plan_wall_s = time.perf_counter() - t0
    return plan, report


def simulate_amplitude(
    circuit,
    bitstring: str,
    target_dim: int = 20,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    seed: int = 0,
    slice_batch: int = 4,
    backend: str | None = None,
    use_cache: bool = True,
    hoist: bool | None = None,
    slicing_mode: str = "width",
    optimize: str = "oneshot",
    search_evals: int = 64,
    search_workers: int = 4,
    search_wall_s: float | None = None,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
    telemetry: bool | None = None,
) -> SimulationResult:
    """Amplitude <bitstring|C|0…0> via the full planner + executor stack.

    ``backend="gemm"`` executes the lowered kernel schedule (Pallas
    tiled GEMMs + refined fallbacks); the default follows
    ``REPRO_BACKEND`` / ``"einsum"``.  ``hoist`` selects two-phase
    (slice-invariant hoisted) execution, default ``REPRO_HOIST``.  Two
    calls on the same circuit share one compiled plan via the plan cache
    (different bitstrings change leaf *values*, never network structure).
    ``optimize="anytime"`` plans via the path–slice co-optimizer
    (:func:`repro.optimize.plan_search`) with ``search_evals``
    evaluations over ``search_workers`` annealing workers.
    """
    from ..quantum.circuits import circuit_to_network  # avoid import cycle

    with _trace.enabled_scope(telemetry):
        tn, arrays = circuit_to_network(circuit, bitstring=bitstring)
        tn, arrays = simplify_network(tn, arrays)
        plan, report = plan_compiled(
            tn,
            target_dim,
            dtype=arrays[0].dtype if arrays else None,
            backend=backend,
            method=method,
            tune=tune,
            merge=merge,
            seed=seed,
            use_cache=use_cache,
            slicing_mode=slicing_mode,
            optimize=optimize,
            search_evals=search_evals,
            search_workers=search_workers,
            search_wall_s=search_wall_s,
            budget_bytes=budget_bytes,
            precision=precision,
            fidelity_tol=fidelity_tol,
        )
        sb = auto_slice_batch(slice_batch, 1 << plan.num_sliced)
        value = plan.contract_all(arrays, slice_batch=sb, hoist=hoist)
        if hoist is not None:
            report = dataclasses.replace(
                report,
                hoist=bool(hoist),
                measured_overhead=plan.executed_overhead(bool(hoist)),
            )
        if _trace.enabled():
            report = dataclasses.replace(
                report, telemetry=_telemetry_snapshot()
            )
    return SimulationResult(
        np.asarray(value), report, plan.tree, plan.smask, plan
    )


def sample_bitstrings(
    circuit,
    num_samples: int = 1024,
    open_qubits=None,
    base_bitstring: str | None = None,
    target_dim: int = 20,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    seed: int = 0,
    slice_batch: int = 4,
    sampler: str = "frequency",
    mesh=None,
    axis_names: tuple[str, ...] = ("data",),
    backend: str | None = None,
    use_cache: bool = True,
    hoist: bool | None = None,
    slicing_mode: str = "width",
    optimize: str = "oneshot",
    search_evals: int = 64,
    search_workers: int = 4,
    search_wall_s: float | None = None,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
    telemetry: bool | None = None,
):
    """Draw correlated bitstring samples from one batched contraction —
    the paper's flagship workload (Sec. VI: 1M correlated Sycamore samples).

    ``open_qubits`` (default: the last ``min(6, n)`` qubits) stay open
    through the contraction stem, so a *single* sliced contraction yields
    all ``2^k`` amplitudes sharing the ``base_bitstring`` prefix (default
    all-zeros).  Bitstrings are then drawn from that batch with the chosen
    ``sampler`` ('frequency' — exact multinomial over |a|², 'rejection' —
    unbiased accept/reject, or 'topk' — heaviest outputs), and the sample
    set is scored with Linear XEB.

    Pass a jax ``mesh`` to shard the slice ids over ``axis_names``
    (shard_map + one psum); the open-batch axes are replicated so every
    device returns the full batch.  ``backend="gemm"`` lowers the stem
    to the refined kernel schedule (see :mod:`repro.lowering`) and the
    compiled plan is cached per circuit family like
    :func:`simulate_amplitude`.  Under two-phase execution (``hoist``,
    default ``REPRO_HOIST``) repeated sampler calls on the same batch
    network reuse the hoisted slice-invariant stem via the prologue
    cache.

    Returns a :class:`repro.sampling.SamplingResult`.

    Example::

        from repro.core import sample_bitstrings
        from repro.quantum.circuits import sycamore_like

        res = sample_bitstrings(
            sycamore_like(4, 4, 10), num_samples=1000,
            open_qubits=(12, 13, 14, 15), target_dim=12,
        )
        print(res.bitstrings[:3], res.xeb)
    """
    if num_samples <= 0:
        raise ValueError(f"num_samples must be positive, got {num_samples}")
    if sampler not in ("frequency", "rejection", "topk"):
        raise ValueError(f"unknown sampler {sampler!r}")  # fail pre-contraction

    with _trace.enabled_scope(telemetry):
        batch, report = open_amplitude_batch(
            circuit,
            open_qubits=open_qubits,
            base_bitstring=base_bitstring,
            target_dim=target_dim,
            method=method,
            tune=tune,
            merge=merge,
            seed=seed,
            slice_batch=slice_batch,
            mesh=mesh,
            axis_names=axis_names,
            backend=backend,
            use_cache=use_cache,
            hoist=hoist,
            slicing_mode=slicing_mode,
            optimize=optimize,
            search_evals=search_evals,
            search_workers=search_workers,
            search_wall_s=search_wall_s,
            budget_bytes=budget_bytes,
            precision=precision,
            fidelity_tol=fidelity_tol,
        )
        res = draw_from_batch(
            batch, num_samples, sampler=sampler, seed=seed
        )
        if _trace.enabled():
            report = dataclasses.replace(
                report, telemetry=_telemetry_snapshot()
            )
    res.report = report
    return res


def open_amplitude_batch(
    circuit,
    open_qubits=None,
    base_bitstring: str | None = None,
    target_dim: int = 20,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    seed: int = 0,
    slice_batch: int = 4,
    mesh=None,
    axis_names: tuple[str, ...] = ("data",),
    backend: str | None = None,
    use_cache: bool = True,
    hoist: bool | None = None,
    slicing_mode: str = "width",
    optimize: str = "oneshot",
    search_evals: int = 64,
    search_workers: int = 4,
    search_wall_s: float | None = None,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
):
    """Contract one open-qubit batch: the planning + execution half of
    :func:`sample_bitstrings`, without drawing any samples.

    Returns ``(AmplitudeBatch, PlanReport)`` — all ``2^k`` correlated
    amplitudes sharing ``base_bitstring`` outside ``open_qubits``.  The
    serving engine (:mod:`repro.engine.server`) calls this directly: one
    batch contraction answers a whole coalesced group of amplitude
    requests (read at their flat batch indices) or feeds any number of
    per-tenant :func:`draw_from_batch` calls.  Defaults mirror
    :func:`sample_bitstrings` (open the last ``min(6, n)`` qubits,
    all-zeros base)."""
    from ..sampling import AmplitudeBatch, batch as batch_mod

    n = circuit.num_qubits
    if open_qubits is None:
        k = min(6, n)
        open_qubits = tuple(range(n - k, n))
    open_qubits = tuple(sorted(set(open_qubits)))
    if not open_qubits:
        raise ValueError("need at least one open qubit")
    if base_bitstring is None:
        base_bitstring = "0" * n
    elif len(base_bitstring) != n or set(base_bitstring) - {"0", "1"}:
        raise ValueError(
            f"base_bitstring must be {n} chars of 0/1, got {base_bitstring!r}"
        )

    tn, arrays = batch_mod.open_batch_network(
        circuit, base_bitstring, open_qubits
    )
    # open indices cannot be sliced: the width floor is the batch rank
    plan, report = plan_compiled(
        tn,
        max(target_dim, len(open_qubits) + 1),
        dtype=arrays[0].dtype if arrays else None,
        backend=backend,
        method=method,
        tune=tune,
        merge=merge,
        seed=seed,
        use_cache=use_cache,
        slicing_mode=slicing_mode,
        optimize=optimize,
        search_evals=search_evals,
        search_workers=search_workers,
        search_wall_s=search_wall_s,
        budget_bytes=budget_bytes,
        precision=precision,
        fidelity_tol=fidelity_tol,
    )
    amps = batch_mod.contract_amplitude_batch(
        plan, arrays, slice_batch=slice_batch, mesh=mesh,
        axis_names=axis_names, hoist=hoist,
    )
    if hoist is not None:
        report = dataclasses.replace(
            report,
            hoist=bool(hoist),
            measured_overhead=plan.executed_overhead(bool(hoist)),
        )
    return AmplitudeBatch(amps, open_qubits, base_bitstring, n), report


def draw_from_batch(
    batch,
    num_samples: int,
    sampler: str = "frequency",
    seed: int = 0,
    report: PlanReport | None = None,
):
    """Draw + score a sample set from an already-contracted
    :class:`~repro.sampling.AmplitudeBatch`.

    The sampling half of :func:`sample_bitstrings`: many tenants (or
    repeated calls with different seeds/samplers) can share one batch
    contraction and each pay only the multinomial/rejection draw.
    Returns a :class:`~repro.sampling.SamplingResult`."""
    from ..quantum import xeb as xeb_mod  # avoid import cycle
    from ..sampling import samplers

    if num_samples <= 0:
        raise ValueError(f"num_samples must be positive, got {num_samples}")
    if sampler not in ("frequency", "rejection", "topk"):
        raise ValueError(f"unknown sampler {sampler!r}")
    idx = samplers.draw(batch, num_samples, sampler=sampler, seed=seed)
    flat = batch.flat()
    sampled_amps = flat[idx]
    probs = np.abs(sampled_amps) ** 2
    return samplers.SamplingResult(
        bitstrings=batch.bitstrings_for(idx),
        amplitudes=sampled_amps,
        probs=probs,
        xeb=xeb_mod.linear_xeb(batch.num_qubits, probs),
        batch=batch,
        sampler=sampler,
        report=report,
    )


def open_session(
    circuit,
    bitstring: str,
    target_dim: int = 20,
    hoist: bool | None = None,
    backend: str | None = None,
    use_cache: bool = True,
    **plan_kwargs,
):
    """Plan a circuit amplitude and return a live
    :class:`~repro.engine.session.ContractionSession` plus its report.

    The session is the engine-level handle the slice drivers share: the
    compiled plan bound to this bitstring's leaf arrays, hoist mode
    resolved, ready for ``run_slice`` / ``run_slices`` / ``run_all``.
    Callers that want to schedule slice execution themselves (custom
    drivers, the serving engine, incremental/resumable loops) start
    here instead of :func:`simulate_amplitude`."""
    from ..engine.session import ContractionSession
    from ..quantum.circuits import circuit_to_network  # avoid import cycle

    tn, arrays = circuit_to_network(circuit, bitstring=bitstring)
    tn, arrays = simplify_network(tn, arrays)
    plan, report = plan_compiled(
        tn,
        target_dim,
        dtype=arrays[0].dtype if arrays else None,
        backend=backend,
        use_cache=use_cache,
        **plan_kwargs,
    )
    return ContractionSession(plan, arrays, hoist=hoist), report
