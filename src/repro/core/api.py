"""End-to-end pipeline: circuit → network → path → slicing → tuning →
merging → sliced JAX contraction.  This is the public API the examples and
benchmarks drive."""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from .contraction_tree import ContractionTree
from .executor import ContractionPlan, simplify_network
from .lifetime import detect_stem
from .merging import merge_branches, modeled_tree_time, orient_gemms
from .pathfinder import random_greedy_tree
from .slicing import find_slices
from .tensor_network import popcount
from .tuning import tuning_slice_finder


@dataclasses.dataclass
class PlanReport:
    """Planner metrics mirroring the paper's reported quantities."""

    num_tensors: int
    width_before: int
    width_after: int
    log2_cost: float
    log2_sliced_cost: float
    num_sliced: int
    slicing_overhead: float  # Eq. 4
    modeled_time_s: float  # Sec. V model, one chip
    plan_wall_s: float

    def row(self) -> str:
        return (
            f"tensors={self.num_tensors} W={self.width_before}->"
            f"{self.width_after} log2C={self.log2_cost:.2f} "
            f"slices={self.num_sliced} overhead={self.slicing_overhead:.3f} "
            f"t_model={self.modeled_time_s:.3e}s plan={self.plan_wall_s:.2f}s"
        )


@dataclasses.dataclass
class SimulationResult:
    value: np.ndarray | complex
    report: PlanReport
    tree: ContractionTree
    smask: int


def plan_contraction(
    tn,
    target_dim: int,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    seed: int = 0,
):
    """Full planning pipeline on a tensor network."""
    t0 = time.perf_counter()
    tree = random_greedy_tree(tn, repeats=repeats, seed=seed)
    width0 = tree.width()
    if tune and method == "lifetime":
        res = tuning_slice_finder(tree, target_dim)
        tree, smask = res.tree, res.smask
    else:
        smask = find_slices(tree, target_dim, method=method, seed=seed)
    if merge:
        tree = merge_branches(tree, smask).tree
        smask = find_slices(tree, target_dim, method=method, seed=seed)
    tree = orient_gemms(tree)
    wall = time.perf_counter() - t0
    report = PlanReport(
        num_tensors=tn.num_tensors,
        width_before=width0,
        width_after=tree.sliced_width(smask),
        log2_cost=tree.log2_total_cost(),
        log2_sliced_cost=math.log2(tree.sliced_cost(smask)),
        num_sliced=popcount(smask),
        slicing_overhead=tree.slicing_overhead(smask),
        modeled_time_s=modeled_tree_time(tree, smask),
        plan_wall_s=wall,
    )
    return tree, smask, report


def simulate_amplitude(
    circuit,
    bitstring: str,
    target_dim: int = 20,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    seed: int = 0,
    slice_batch: int = 4,
) -> SimulationResult:
    """Amplitude <bitstring|C|0…0> via the full planner + executor stack."""
    from ..quantum.circuits import circuit_to_network  # avoid import cycle

    tn, arrays = circuit_to_network(circuit, bitstring=bitstring)
    tn, arrays = simplify_network(tn, arrays)
    tree, smask, report = plan_contraction(
        tn, target_dim, method=method, tune=tune, merge=merge, seed=seed
    )
    plan = ContractionPlan(tree, smask)
    n_slices = 1 << plan.num_sliced
    sb = 1
    while sb * 2 <= min(slice_batch, n_slices) and n_slices % (sb * 2) == 0:
        sb *= 2
    value = plan.contract_all(arrays, slice_batch=sb)
    return SimulationResult(np.asarray(value), report, tree, smask)
