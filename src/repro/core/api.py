"""End-to-end pipeline: circuit → network → path → slicing → tuning →
merging → sliced JAX contraction.  This is the public API the examples and
benchmarks drive."""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from .contraction_tree import ContractionTree
from .executor import ContractionPlan, auto_slice_batch, simplify_network
from .lifetime import detect_stem
from .merging import merge_branches, modeled_tree_time, orient_gemms
from .pathfinder import random_greedy_tree
from .slicing import find_slices
from .tensor_network import popcount
from .tuning import tuning_slice_finder


@dataclasses.dataclass
class PlanReport:
    """Planner metrics mirroring the paper's reported quantities."""

    num_tensors: int
    width_before: int
    width_after: int
    log2_cost: float
    log2_sliced_cost: float
    num_sliced: int
    slicing_overhead: float  # Eq. 4
    modeled_time_s: float  # Sec. V model, one chip
    plan_wall_s: float

    def row(self) -> str:
        return (
            f"tensors={self.num_tensors} W={self.width_before}->"
            f"{self.width_after} log2C={self.log2_cost:.2f} "
            f"slices={self.num_sliced} overhead={self.slicing_overhead:.3f} "
            f"t_model={self.modeled_time_s:.3e}s plan={self.plan_wall_s:.2f}s"
        )


@dataclasses.dataclass
class SimulationResult:
    value: np.ndarray | complex
    report: PlanReport
    tree: ContractionTree
    smask: int


def plan_contraction(
    tn,
    target_dim: int,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    seed: int = 0,
):
    """Full planning pipeline on a tensor network."""
    t0 = time.perf_counter()
    tree = random_greedy_tree(tn, repeats=repeats, seed=seed)
    width0 = tree.width()
    if tune and method == "lifetime":
        res = tuning_slice_finder(tree, target_dim)
        tree, smask = res.tree, res.smask
    else:
        smask = find_slices(tree, target_dim, method=method, seed=seed)
    if merge:
        tree = merge_branches(tree, smask).tree
        smask = find_slices(tree, target_dim, method=method, seed=seed)
    tree = orient_gemms(tree)
    wall = time.perf_counter() - t0
    report = PlanReport(
        num_tensors=tn.num_tensors,
        width_before=width0,
        width_after=tree.sliced_width(smask),
        log2_cost=tree.log2_total_cost(),
        log2_sliced_cost=math.log2(tree.sliced_cost(smask)),
        num_sliced=popcount(smask),
        slicing_overhead=tree.slicing_overhead(smask),
        modeled_time_s=modeled_tree_time(tree, smask),
        plan_wall_s=wall,
    )
    return tree, smask, report


def simulate_amplitude(
    circuit,
    bitstring: str,
    target_dim: int = 20,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    seed: int = 0,
    slice_batch: int = 4,
) -> SimulationResult:
    """Amplitude <bitstring|C|0…0> via the full planner + executor stack."""
    from ..quantum.circuits import circuit_to_network  # avoid import cycle

    tn, arrays = circuit_to_network(circuit, bitstring=bitstring)
    tn, arrays = simplify_network(tn, arrays)
    tree, smask, report = plan_contraction(
        tn, target_dim, method=method, tune=tune, merge=merge, seed=seed
    )
    plan = ContractionPlan(tree, smask)
    sb = auto_slice_batch(slice_batch, 1 << plan.num_sliced)
    value = plan.contract_all(arrays, slice_batch=sb)
    return SimulationResult(np.asarray(value), report, tree, smask)


def sample_bitstrings(
    circuit,
    num_samples: int = 1024,
    open_qubits=None,
    base_bitstring: str | None = None,
    target_dim: int = 20,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    seed: int = 0,
    slice_batch: int = 4,
    sampler: str = "frequency",
    mesh=None,
    axis_names: tuple[str, ...] = ("data",),
):
    """Draw correlated bitstring samples from one batched contraction —
    the paper's flagship workload (Sec. VI: 1M correlated Sycamore samples).

    ``open_qubits`` (default: the last ``min(6, n)`` qubits) stay open
    through the contraction stem, so a *single* sliced contraction yields
    all ``2^k`` amplitudes sharing the ``base_bitstring`` prefix (default
    all-zeros).  Bitstrings are then drawn from that batch with the chosen
    ``sampler`` ('frequency' — exact multinomial over |a|², 'rejection' —
    unbiased accept/reject, or 'topk' — heaviest outputs), and the sample
    set is scored with Linear XEB.

    Pass a jax ``mesh`` to shard the slice ids over ``axis_names``
    (shard_map + one psum); the open-batch axes are replicated so every
    device returns the full batch.

    Returns a :class:`repro.sampling.SamplingResult`.

    Example::

        from repro.core import sample_bitstrings
        from repro.quantum.circuits import sycamore_like

        res = sample_bitstrings(
            sycamore_like(4, 4, 10), num_samples=1000,
            open_qubits=(12, 13, 14, 15), target_dim=12,
        )
        print(res.bitstrings[:3], res.xeb)
    """
    from ..quantum import xeb as xeb_mod  # avoid import cycle
    from ..sampling import AmplitudeBatch, batch as batch_mod, samplers

    n = circuit.num_qubits
    if num_samples <= 0:
        raise ValueError(f"num_samples must be positive, got {num_samples}")
    if sampler not in ("frequency", "rejection", "topk"):
        raise ValueError(f"unknown sampler {sampler!r}")  # fail pre-contraction
    if open_qubits is None:
        k = min(6, n)
        open_qubits = tuple(range(n - k, n))
    open_qubits = tuple(sorted(set(open_qubits)))
    if not open_qubits:
        raise ValueError("need at least one open qubit to sample")
    if base_bitstring is None:
        base_bitstring = "0" * n
    elif len(base_bitstring) != n or set(base_bitstring) - {"0", "1"}:
        raise ValueError(
            f"base_bitstring must be {n} chars of 0/1, got {base_bitstring!r}"
        )

    tn, arrays = batch_mod.open_batch_network(
        circuit, base_bitstring, open_qubits
    )
    # open indices cannot be sliced, so the width floor is the batch rank
    tree, smask, report = plan_contraction(
        tn,
        max(target_dim, len(open_qubits) + 1),
        method=method,
        tune=tune,
        merge=merge,
        seed=seed,
    )
    plan = ContractionPlan(tree, smask)
    amps = batch_mod.contract_amplitude_batch(
        plan, arrays, slice_batch=slice_batch, mesh=mesh, axis_names=axis_names
    )
    batch = AmplitudeBatch(amps, open_qubits, base_bitstring, n)
    idx = samplers.draw(batch, num_samples, sampler=sampler, seed=seed)
    flat = batch.flat()
    sampled_amps = flat[idx]
    probs = np.abs(sampled_amps) ** 2
    return samplers.SamplingResult(
        bitstrings=batch.bitstrings_for(idx),
        amplitudes=sampled_amps,
        probs=probs,
        xeb=xeb_mod.linear_xeb(n, probs),
        batch=batch,
        sampler=sampler,
        report=report,
    )
