"""Rooted binary contraction trees with the paper's complexity algebra.

A contraction tree B = (N_B, E_B): every tree edge carries the index set of
an (input or intermediate) tensor, every internal node is a pairwise
contraction.  We keep the paper's quantities:

  width  W(B)   = max_e |s_e|                       (Eq. 2, log2 memory)
  cost   C(B)   = sum_node 2^{|s_node|}             (Eq. 3)
  sliced C(B,S) = sum_node 2^{|s_node|+|S|-|S∩s_node|}   (Eq. 6)

Index sets are int bitmasks (see tensor_network.py).  The tree is mutable:
branch exchange and branch merging (Secs. IV-C / V-B) are local surgeries
with incremental mask updates.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from .tensor_network import TensorNetwork, bits, popcount


class ContractionTree:
    """Binary contraction tree over a :class:`TensorNetwork`.

    Leaves are node ids ``0..n-1`` (matching ``tn.inputs``); internal nodes
    get fresh ids.  ``emask[v]`` is the index bitmask of the tensor produced
    by the subtree rooted at ``v`` (for leaves: the input tensor's mask).
    """

    def __init__(self, tn: TensorNetwork):
        self.tn = tn
        n = tn.num_tensors
        self.children: dict[int, tuple[int, int]] = {}
        self.parent: dict[int, int] = {}
        self.emask: dict[int, int] = {i: tn.masks[i] for i in range(n)}
        self.root: int | None = None
        self._next_id = n

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_ssa_path(
        cls, tn: TensorNetwork, ssa_path: Sequence[tuple[int, int]]
    ) -> "ContractionTree":
        """Build from an SSA path: leaves are 0..n-1; contraction ``k``
        combines two existing ssa ids and produces ssa id ``n + k``."""
        t = cls(tn)
        if tn.num_tensors == 1:
            t.root = 0
            return t
        if len(ssa_path) != tn.num_tensors - 1:
            raise ValueError(
                f"path has {len(ssa_path)} contractions for "
                f"{tn.num_tensors} tensors"
            )
        for a, b in ssa_path:
            t._contract(a, b)
        t.root = t._next_id - 1
        return t

    def _result_mask(self, ma: int, mb: int) -> int:
        open_m = self.tn.open_mask
        return (ma ^ mb) | (ma & mb & open_m)

    def _contract(self, a: int, b: int) -> int:
        nid = self._next_id
        self._next_id += 1
        self.children[nid] = (a, b)
        self.parent[a] = nid
        self.parent[b] = nid
        self.emask[nid] = self._result_mask(self.emask[a], self.emask[b])
        return nid

    def is_leaf(self, v: int) -> bool:
        return v not in self.children

    # ------------------------------------------------------------------
    # complexity algebra
    # ------------------------------------------------------------------
    def node_mask(self, v: int) -> int:
        """s_node = union of the two contracted tensors' indices."""
        l, r = self.children[v]
        return self.emask[l] | self.emask[r]

    def internal_nodes(self) -> list[int]:
        return list(self.children.keys())

    def width(self) -> int:
        return max(popcount(m) for m in self.emask.values())

    def cost_log2s(self) -> dict[int, int]:
        return {v: popcount(self.node_mask(v)) for v in self.children}

    def total_cost(self) -> float:
        return sum(2.0 ** popcount(self.node_mask(v)) for v in self.children)

    def log2_total_cost(self) -> float:
        import math

        return math.log2(self.total_cost())

    def sliced_cost(self, smask: int) -> float:
        """Eq. 6: total cost over all 2^|S| subtasks."""
        s = popcount(smask)
        tot = 0.0
        for v in self.children:
            nm = self.node_mask(v)
            tot += 2.0 ** (popcount(nm) + s - popcount(smask & nm))
        return tot

    def slicing_overhead(self, smask: int) -> float:
        """Eq. 4: O(B,S) = C_slice(B)·2^|S| / C(B)."""
        return self.sliced_cost(smask) / self.total_cost()

    def sliced_width(self, smask: int) -> int:
        return max(popcount(m & ~smask) for m in self.emask.values())

    # ------------------------------------------------------------------
    # traversal / export
    # ------------------------------------------------------------------
    def contract_order(self) -> list[int]:
        """Internal nodes in a valid (post-order) execution order."""
        order: list[int] = []
        stack = [(self.root, False)]
        while stack:
            v, done = stack.pop()
            if self.is_leaf(v):
                continue
            if done:
                order.append(v)
            else:
                l, r = self.children[v]
                stack.append((v, True))
                stack.append((r, False))
                stack.append((l, False))
        return order

    def leaves_under(self, v: int) -> list[int]:
        out: list[int] = []
        stack = [v]
        while stack:
            u = stack.pop()
            if self.is_leaf(u):
                out.append(u)
            else:
                stack.extend(self.children[u])
        return out

    def check_valid(self) -> None:
        """Structural invariants (used by property tests)."""
        leaves = sorted(self.leaves_under(self.root))
        assert leaves == list(range(self.tn.num_tensors)), "leaf cover broken"
        for v, (l, r) in self.children.items():
            assert self.parent[l] == v and self.parent[r] == v
            expect = self._result_mask(self.emask[l], self.emask[r])
            assert self.emask[v] == expect, f"stale mask at node {v}"

    def copy(self) -> "ContractionTree":
        t = ContractionTree(self.tn)
        t.children = dict(self.children)
        t.parent = dict(self.parent)
        t.emask = dict(self.emask)
        t.root = self.root
        t._next_id = self._next_id
        return t

    # ------------------------------------------------------------------
    # local surgery (branch exchange / merge) — Secs. IV-C, V-B
    # ------------------------------------------------------------------
    def _replace_child(self, p: int, old: int, new: int) -> None:
        l, r = self.children[p]
        self.children[p] = (new, r) if l == old else (l, new)
        self.parent[new] = p

    def _refresh_up(self, v: int) -> None:
        """Recompute emasks from ``v`` up to the root (stops early when a
        mask is unchanged)."""
        while v is not None and v in self.children:
            l, r = self.children[v]
            m = self._result_mask(self.emask[l], self.emask[r])
            if m == self.emask[v]:
                return
            self.emask[v] = m
            v = self.parent.get(v)

    def exchange_at(self, p: int, q: int, branch_q: int, branch_p: int) -> None:
        """Exchange ``branch_q`` (child of q) with ``branch_p`` (child of p),
        where p is the parent of q.  The spine child of q stays put."""
        assert self.parent[q] == p
        assert branch_q in self.children[q], "stale branch id"
        assert branch_p in self.children[p], "stale branch id"
        self._replace_child(q, branch_q, branch_p)
        self._replace_child(p, branch_p, branch_q)
        # q's result changes; p's does not (same leaves), but refresh both
        # for safety (refresh stops as soon as masks stabilize).
        l, r = self.children[q]
        self.emask[q] = self._result_mask(self.emask[l], self.emask[r])
        self._refresh_up(p)

    def merge_branches_at(self, p: int, q: int, branch_q: int, branch_p: int) -> int:
        """Pre-contract two adjacent branches (Sec. V-B):

        q = (T, B1), p = (q, B2)  →  p' = (T, M), M = (B1, B2).

        Node q is re-purposed as the merge node M to keep ids stable.
        Returns the id of the merge node.
        """
        assert self.parent[q] == p
        assert branch_q in self.children[q], "stale branch id"
        assert branch_p in self.children[p], "stale branch id"
        spine = [c for c in self.children[q] if c != branch_q][0]
        # rewire: p takes the spine tensor directly plus the merged branch
        self.children[q] = (branch_q, branch_p)
        self.parent[branch_p] = q
        self.parent[branch_q] = q
        self.children[p] = (spine, q)
        self.parent[spine] = p
        self.parent[q] = p
        l, r = self.children[q]
        self.emask[q] = self._result_mask(self.emask[l], self.emask[r])
        self._refresh_up(p)
        return q


def ssa_to_linear(ssa_path: Sequence[tuple[int, int]], n: int) -> list[tuple[int, int]]:
    """Convert an SSA path to opt_einsum-style linear format (positions in a
    shrinking list)."""
    ids = list(range(n))
    out = []
    for k, (a, b) in enumerate(ssa_path):
        ia, ib = ids.index(a), ids.index(b)
        if ia > ib:
            ia, ib = ib, ia
        out.append((ia, ib))
        ids.pop(ib)
        ids.pop(ia)
        ids.append(n + k)
    return out


def linear_to_ssa(linear_path: Sequence[tuple[int, int]], n: int) -> list[tuple[int, int]]:
    ids = list(range(n))
    out = []
    for k, (ia, ib) in enumerate(linear_path):
        if ia > ib:
            ia, ib = ib, ia
        a, b = ids[ia], ids[ib]
        out.append((a, b))
        ids.pop(ib)
        ids.pop(ia)
        ids.append(n + k)
    return out
