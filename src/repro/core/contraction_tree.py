"""Rooted binary contraction trees with the paper's complexity algebra.

A contraction tree B = (N_B, E_B): every tree edge carries the index set of
an (input or intermediate) tensor, every internal node is a pairwise
contraction.  We keep the paper's quantities:

  width  W(B)   = max_e |s_e|                       (Eq. 2, log2 memory)
  cost   C(B)   = sum_node 2^{|s_node|}             (Eq. 3)
  sliced C(B,S) = sum_node 2^{|s_node|+|S|-|S∩s_node|}   (Eq. 6)

Index sets are int bitmasks (see tensor_network.py).  The tree is mutable:
branch exchange and branch merging (Secs. IV-C / V-B) are local surgeries
with incremental mask updates.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .tensor_network import TensorNetwork, bits, popcount


class ContractionTree:
    """Binary contraction tree over a :class:`TensorNetwork`.

    Leaves are node ids ``0..n-1`` (matching ``tn.inputs``); internal nodes
    get fresh ids.  ``emask[v]`` is the index bitmask of the tensor produced
    by the subtree rooted at ``v`` (for leaves: the input tensor's mask).
    """

    def __init__(self, tn: TensorNetwork):
        self.tn = tn
        n = tn.num_tensors
        self.children: dict[int, tuple[int, int]] = {}
        self.parent: dict[int, int] = {}
        self.emask: dict[int, int] = {i: tn.masks[i] for i in range(n)}
        self.root: int | None = None
        self._next_id = n

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_ssa_path(
        cls, tn: TensorNetwork, ssa_path: Sequence[tuple[int, int]]
    ) -> "ContractionTree":
        """Build from an SSA path: leaves are 0..n-1; contraction ``k``
        combines two existing ssa ids and produces ssa id ``n + k``."""
        t = cls(tn)
        if tn.num_tensors == 1:
            t.root = 0
            return t
        if len(ssa_path) != tn.num_tensors - 1:
            raise ValueError(
                f"path has {len(ssa_path)} contractions for "
                f"{tn.num_tensors} tensors"
            )
        for a, b in ssa_path:
            t._contract(a, b)
        t.root = t._next_id - 1
        return t

    def _result_mask(self, ma: int, mb: int) -> int:
        open_m = self.tn.open_mask
        return (ma ^ mb) | (ma & mb & open_m)

    def _contract(self, a: int, b: int) -> int:
        nid = self._next_id
        self._next_id += 1
        self.children[nid] = (a, b)
        self.parent[a] = nid
        self.parent[b] = nid
        self.emask[nid] = self._result_mask(self.emask[a], self.emask[b])
        return nid

    def is_leaf(self, v: int) -> bool:
        return v not in self.children

    # ------------------------------------------------------------------
    # complexity algebra
    # ------------------------------------------------------------------
    def node_mask(self, v: int) -> int:
        """s_node = union of the two contracted tensors' indices."""
        l, r = self.children[v]
        return self.emask[l] | self.emask[r]

    def internal_nodes(self) -> list[int]:
        return list(self.children.keys())

    def width(self) -> int:
        return max(popcount(m) for m in self.emask.values())

    def node_cost(self, v: int) -> float:
        """2^|s_node| — one term of Eq. 3."""
        return 2.0 ** popcount(self.node_mask(v))

    def cost_log2s(self) -> dict[int, int]:
        return {v: popcount(self.node_mask(v)) for v in self.children}

    def total_cost(self) -> float:
        return sum(2.0 ** popcount(self.node_mask(v)) for v in self.children)

    def log2_total_cost(self) -> float:
        import math

        return math.log2(self.total_cost())

    def sliced_cost(self, smask: int) -> float:
        """Eq. 6: total cost over all 2^|S| subtasks."""
        s = popcount(smask)
        tot = 0.0
        for v in self.children:
            nm = self.node_mask(v)
            tot += 2.0 ** (popcount(nm) + s - popcount(smask & nm))
        return tot

    def slicing_overhead(self, smask: int) -> float:
        """Eq. 4: O(B,S) = C_slice(B)·2^|S| / C(B)."""
        return self.sliced_cost(smask) / self.total_cost()

    def sliced_width(self, smask: int) -> int:
        return max(popcount(m & ~smask) for m in self.emask.values())

    # ------------------------------------------------------------------
    # traversal / export
    # ------------------------------------------------------------------
    def contract_order(self) -> list[int]:
        """Internal nodes in a valid (post-order) execution order."""
        order: list[int] = []
        stack = [(self.root, False)]
        while stack:
            v, done = stack.pop()
            if self.is_leaf(v):
                continue
            if done:
                order.append(v)
            else:
                l, r = self.children[v]
                stack.append((v, True))
                stack.append((r, False))
                stack.append((l, False))
        return order

    def leaves_under(self, v: int) -> list[int]:
        out: list[int] = []
        stack = [v]
        while stack:
            u = stack.pop()
            if self.is_leaf(u):
                out.append(u)
            else:
                stack.extend(self.children[u])
        return out

    def check_valid(self) -> None:
        """Structural invariants (used by property tests)."""
        leaves = sorted(self.leaves_under(self.root))
        assert leaves == list(range(self.tn.num_tensors)), "leaf cover broken"
        for v, (l, r) in self.children.items():
            assert self.parent[l] == v and self.parent[r] == v
            expect = self._result_mask(self.emask[l], self.emask[r])
            assert self.emask[v] == expect, f"stale mask at node {v}"

    def copy(self) -> "ContractionTree":
        t = ContractionTree(self.tn)
        t.children = dict(self.children)
        t.parent = dict(self.parent)
        t.emask = dict(self.emask)
        t.root = self.root
        t._next_id = self._next_id
        return t

    # ------------------------------------------------------------------
    # local surgery (branch exchange / merge) — Secs. IV-C, V-B
    # ------------------------------------------------------------------
    def _replace_child(self, p: int, old: int, new: int) -> None:
        l, r = self.children[p]
        self.children[p] = (new, r) if l == old else (l, new)
        self.parent[new] = p

    def _refresh_up(self, v: int) -> None:
        """Recompute emasks from ``v`` up to the root (stops early when a
        mask is unchanged)."""
        while v is not None and v in self.children:
            l, r = self.children[v]
            m = self._result_mask(self.emask[l], self.emask[r])
            if m == self.emask[v]:
                return
            self.emask[v] = m
            v = self.parent.get(v)

    def exchange_at(self, p: int, q: int, branch_q: int, branch_p: int) -> None:
        """Exchange ``branch_q`` (child of q) with ``branch_p`` (child of p),
        where p is the parent of q.  The spine child of q stays put."""
        assert self.parent[q] == p
        assert branch_q in self.children[q], "stale branch id"
        assert branch_p in self.children[p], "stale branch id"
        self._replace_child(q, branch_q, branch_p)
        self._replace_child(p, branch_p, branch_q)
        # q's result changes; p's does not (same leaves), but refresh both
        # for safety (refresh stops as soon as masks stabilize).
        l, r = self.children[q]
        self.emask[q] = self._result_mask(self.emask[l], self.emask[r])
        self._refresh_up(p)

    def merge_branches_at(self, p: int, q: int, branch_q: int, branch_p: int) -> int:
        """Pre-contract two adjacent branches (Sec. V-B):

        q = (T, B1), p = (q, B2)  →  p' = (T, M), M = (B1, B2).

        Node q is re-purposed as the merge node M to keep ids stable.
        Returns the id of the merge node.
        """
        assert self.parent[q] == p
        assert branch_q in self.children[q], "stale branch id"
        assert branch_p in self.children[p], "stale branch id"
        spine = [c for c in self.children[q] if c != branch_q][0]
        # rewire: p takes the spine tensor directly plus the merged branch
        self.children[q] = (branch_q, branch_p)
        self.parent[branch_p] = q
        self.parent[branch_q] = q
        self.children[p] = (spine, q)
        self.parent[spine] = p
        self.parent[q] = p
        l, r = self.children[q]
        self.emask[q] = self._result_mask(self.emask[l], self.emask[r])
        self._refresh_up(p)
        return q

    # ------------------------------------------------------------------
    # subtree splice (reconfiguration surgery for the anytime co-optimizer)
    # ------------------------------------------------------------------
    def subtree_frontier(self, v: int, max_roots: int = 8) -> list[int]:
        """A frontier of subtree roots under ``v``: start from v's two
        children and repeatedly expand the *most expensive* internal
        frontier member until ``max_roots`` roots (or all leaves).  The
        frontier partitions the leaves under ``v``, so any pairwise
        order over it rebuilds a valid subtree with the same result
        mask.  Deterministic (ties broken by node id)."""
        assert not self.is_leaf(v), "frontier needs an internal node"
        frontier = list(self.children[v])
        while len(frontier) < max_roots:
            cands = [u for u in frontier if not self.is_leaf(u)]
            if not cands:
                break
            u = max(cands, key=lambda u_: (self.node_cost(u_), u_))
            frontier.remove(u)
            frontier.extend(self.children[u])
        return frontier

    def _internal_between(self, v: int, frontier: Sequence[int]) -> list[int]:
        """Internal nodes of the subtree at ``v`` above the frontier
        (``v`` included, frontier roots excluded)."""
        stop = set(frontier)
        out: list[int] = []
        stack = [v]
        while stack:
            u = stack.pop()
            assert not self.is_leaf(u), "frontier does not cover subtree"
            out.append(u)
            for c in self.children[u]:
                if c not in stop:
                    stack.append(c)
        return out

    def splice_subtree(
        self,
        v: int,
        frontier: Sequence[int],
        ssa_pairs: Sequence[tuple[int, int]],
    ) -> "SpliceResult":
        """Rebuild the internal structure joining ``frontier`` up to ``v``
        along a new pairwise order, in place.

        ``ssa_pairs`` is an SSA path over *positions*: entry ``j`` pairs
        two members of the growing list ``frontier + results``, its
        result taking position ``len(frontier) + j``.  The freed internal
        ids are recycled (the last rebuilt node is ``v`` itself, so the
        linkage above ``v`` never changes), and ``emask[v]`` is invariant
        — the leaf set under ``v`` is untouched — so no upward refresh is
        needed.  Returns a :class:`SpliceResult` carrying the undo record
        and the local Eq. 3 cost delta; :meth:`unsplice` reverts the
        surgery exactly."""
        frontier = list(frontier)
        internal = self._internal_between(v, frontier)
        if len(ssa_pairs) != len(frontier) - 1 or len(internal) != len(
            ssa_pairs
        ):
            raise ValueError(
                f"splice needs |frontier|-1 = {len(frontier) - 1} pairs "
                f"over {len(internal)} recycled ids"
            )
        # validate the whole SSA sequence BEFORE the first mutation, so a
        # bad input raises with the tree untouched (no undo needed)
        used: set[int] = set()
        for j, (pa, pb) in enumerate(ssa_pairs):
            if pa == pb or pa in used or pb in used:
                raise ValueError(f"ssa pair {j} reuses a position")
            if not (0 <= pa < len(frontier) + j and 0 <= pb < len(frontier) + j):
                raise ValueError(f"ssa pair {j} out of range")
            used.update((pa, pb))
        old_children = {u: self.children[u] for u in internal}
        old_emask = {u: self.emask[u] for u in internal}
        old_parent = {u: self.parent.get(u) for u in frontier}
        cost_before = sum(self.node_cost(u) for u in internal)
        # recycle ids; v must come last so the subtree root keeps its id
        recycled = sorted(u for u in internal if u != v) + [v]
        ids = list(frontier)
        for j, (pa, pb) in enumerate(ssa_pairs):
            a, b = ids[pa], ids[pb]
            nid = recycled[j]
            self.children[nid] = (a, b)
            self.parent[a] = nid
            self.parent[b] = nid
            self.emask[nid] = self._result_mask(self.emask[a], self.emask[b])
            ids.append(nid)
        assert ids[-1] == v
        assert self.emask[v] == old_emask[v], "leaf cover changed by splice"
        cost_after = sum(self.node_cost(u) for u in internal)
        return SpliceResult(
            v=v,
            frontier=tuple(frontier),
            rebuilt=tuple(recycled),
            old_children=old_children,
            old_emask=old_emask,
            old_parent=old_parent,
            cost_before=cost_before,
            cost_after=cost_after,
        )

    def unsplice(self, res: "SpliceResult") -> None:
        """Exactly revert a :meth:`splice_subtree` (cheap: only the
        rebuilt internal nodes and their child links are restored)."""
        for u, (l, r) in res.old_children.items():
            self.children[u] = (l, r)
            self.parent[l] = u
            self.parent[r] = u
            self.emask[u] = res.old_emask[u]
        for u, p in res.old_parent.items():
            if p is not None:
                self.parent[u] = p


@dataclasses.dataclass(frozen=True)
class SpliceResult:
    """Undo record + incremental deltas for one subtree splice."""

    v: int
    frontier: tuple[int, ...]
    rebuilt: tuple[int, ...]
    old_children: dict[int, tuple[int, int]]
    old_emask: dict[int, int]
    old_parent: dict[int, int | None]
    cost_before: float  # Σ 2^|s_node| over the rebuilt region, before
    cost_after: float  # … after — total_cost delta without a full resum

    @property
    def cost_delta(self) -> float:
        return self.cost_after - self.cost_before


def ssa_to_linear(ssa_path: Sequence[tuple[int, int]], n: int) -> list[tuple[int, int]]:
    """Convert an SSA path to opt_einsum-style linear format (positions in a
    shrinking list)."""
    ids = list(range(n))
    out = []
    for k, (a, b) in enumerate(ssa_path):
        ia, ib = ids.index(a), ids.index(b)
        if ia > ib:
            ia, ib = ib, ia
        out.append((ia, ib))
        ids.pop(ib)
        ids.pop(ia)
        ids.append(n + k)
    return out


def linear_to_ssa(linear_path: Sequence[tuple[int, int]], n: int) -> list[tuple[int, int]]:
    ids = list(range(n))
    out = []
    for k, (ia, ib) in enumerate(linear_path):
        if ia > ib:
            ia, ib = ib, ia
        a, b = ids[ia], ids[ib]
        out.append((a, b))
        ids.pop(ib)
        ids.pop(ia)
        ids.append(n + k)
    return out
