"""Three-term roofline analysis from compiled dry-run artifacts.

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device — the SPMD
module is the per-chip program).  Collective bytes are not in
cost_analysis: we parse the optimized HLO text and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather-start", "all-gather",
    "all-reduce-start", "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute-start", "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|[a-z]+\d+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:(?:pred|[a-z]+\d+)\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(_COLLECTIVES) + r")\("
)


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Result-shape bytes per collective kind (…-start/done pairs counted
    once via the -start form; bare ops counted directly)."""
    out: dict[str, int] = {}
    seen_start_ids: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.groups()
        base = kind.replace("-start", "")
        if kind.endswith("-start"):
            pass  # counted here; the matching -done has no '=shape op(' form
        elif f"{base}-start" in line:
            continue
        out[base] = out.get(base, 0) + shape_bytes(shape_txt)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    bytes_accessed: float  # per device
    coll_bytes: dict[str, int]  # per device
    n_devices: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": dict(self.coll_bytes),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
        }


def analyze_compiled(compiled, n_devices: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception as e:
        # some backends can't render HLO text (e.g. AOT-deserialized
        # executables); collective traffic then reads as zero — say so
        # instead of silently under-reporting the roofline
        from ..obs import log as obs_log

        obs_log.warning(
            f"roofline: compiled.as_text() failed ({e}); "
            "collective bytes will read as 0",
            error=str(e),
        )
        hlo = ""
    coll = collective_bytes(hlo)
    return Roofline(flops, bytes_accessed, coll, n_devices)


def model_flops(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference forward."""
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * active_params * tokens


def active_param_count(cfg, defs_count: int) -> int:
    """Active params per token for MoE archs (routed experts count only
    k/E of their weights); dense archs: all params."""
    if not cfg.num_experts:
        return defs_count
    # approximate: routed expert params scale by k/E
    Fm = cfg.moe_d_ff or cfg.d_ff
    n_moe = cfg.num_layers - cfg.first_k_dense
    routed = n_moe * cfg.num_experts * 3 * cfg.d_model * Fm
    active_routed = routed * cfg.experts_per_token / cfg.num_experts
    return int(defs_count - routed + active_routed)
