"""Analytic FLOPs/bytes model per (arch × shape) cell.

Why this exists: XLA:CPU's ``HloCostAnalysis`` counts a ``while``-loop
(scan-over-layers) body ONCE instead of ×trip-count, so the dry-run's
measured HLO FLOPs undercount deep scanned models by ~num_layers; it also
wildly overcounts ``cumsum`` (reduce-window) in the MoE router.  The
analytic model is standard MFU accounting (6ND + attention quadratic
terms for training; 2ND + cache reads for inference) and is reported
side-by-side with the measured numbers; the roofline compute term uses
the analytic value whenever the two disagree by >2x (methodology note in
EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

from ..configs.base import ArchConfig, ShapeCell


def _attn_flops_per_layer(cfg: ArchConfig, S: int, B: int, causal=True,
                          window: int = 0) -> float:
    """QK^T + PV flops for one layer over the whole batch."""
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    eff = min(window, S) if window else S
    ctx = eff / 2 if causal and not window else eff  # triangular average
    return 2.0 * 2.0 * B * H * S * ctx * hd


def forward_flops(cfg: ArchConfig, B: int, S: int) -> float:
    """Forward-pass FLOPs (matmul 2·MNK accounting), whole batch."""
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    T = B * S
    total = 0.0
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * D
        proj = 2.0 * T * D * (2 * d_inner + 2 * cfg.ssm_state +
                              d_inner // cfg.ssm_head_dim)
        ssd = 2.0 * T * d_inner * cfg.ssm_state * 2  # B/C contractions
        chunkq = 2.0 * T * 64 * d_inner  # intra-chunk quadratic (L=64)
        out = 2.0 * T * d_inner * D
        total += cfg.num_layers * (proj + ssd + chunkq + out)
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * D
        proj = 2.0 * T * D * (2 * d_inner + 2 * cfg.ssm_state +
                              d_inner // cfg.ssm_head_dim)
        ssd = 2.0 * T * d_inner * cfg.ssm_state * 2
        chunkq = 2.0 * T * 64 * d_inner
        outp = 2.0 * T * d_inner * D
        total += cfg.num_layers * (proj + ssd + chunkq + outp)
        n_attn = cfg.num_layers // cfg.attn_every
        qkvo = 2.0 * T * D * (cfg.num_heads + 2 * cfg.num_kv_heads +
                              cfg.num_heads) * hd
        mlp = 3 * 2.0 * T * D * cfg.d_ff
        total += n_attn * (
            qkvo + mlp + _attn_flops_per_layer(cfg, S, B, window=cfg.window)
        )
    else:
        n_dense = cfg.first_k_dense if cfg.num_experts else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.num_experts else 0
        qkvo = 2.0 * T * D * (2 * cfg.num_heads + 2 * cfg.num_kv_heads) * hd
        attn = _attn_flops_per_layer(cfg, S, B)
        total += cfg.num_layers * (qkvo + attn)
        total += n_dense * 3 * 2.0 * T * D * cfg.d_ff
        if n_moe:
            Fm = cfg.moe_d_ff or cfg.d_ff
            per_tok = (cfg.experts_per_token +
                       cfg.num_shared_experts) * 3 * 2.0 * D * Fm
            router = 2.0 * D * cfg.num_experts
            total += n_moe * T * (per_tok + router)
        if cfg.family == "encdec":
            # encoder layers + decoder cross-attention
            enc = cfg.encoder_layers * (
                qkvo + _attn_flops_per_layer(cfg, S, B, causal=False)
                + 3 * 2.0 * T * D * cfg.d_ff
            )
            cross = cfg.num_layers * (
                qkvo + _attn_flops_per_layer(cfg, S, B, causal=False)
            )
            total += enc + cross
    # lm head
    total += 2.0 * T * D * cfg.vocab_size
    return total


def decode_flops(cfg: ArchConfig, B: int, ctx: int) -> float:
    """One-token decode FLOPs with a ctx-long cache."""
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    total = 0.0
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * D
        per_layer = 2.0 * B * D * (2 * d_inner + 2 * cfg.ssm_state +
                                   d_inner // cfg.ssm_head_dim)
        per_layer += 2.0 * B * d_inner * cfg.ssm_state * 2
        per_layer += 2.0 * B * d_inner * D
        total += cfg.num_layers * per_layer
        if cfg.family == "hybrid":
            n_attn = cfg.num_layers // cfg.attn_every
            eff = min(cfg.window, ctx) if cfg.window else ctx
            qkvo = 2.0 * B * D * 2 * (cfg.num_heads + cfg.num_kv_heads) * hd
            attn = 2.0 * 2.0 * B * cfg.num_heads * eff * hd
            mlp = 3 * 2.0 * B * D * cfg.d_ff
            total += n_attn * (qkvo + attn + mlp)
    else:
        qkvo = 2.0 * B * D * 2 * (cfg.num_heads + cfg.num_kv_heads) * hd
        attn = 2.0 * 2.0 * B * cfg.num_heads * ctx * hd
        n_dense = cfg.first_k_dense if cfg.num_experts else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.num_experts else 0
        total += cfg.num_layers * (qkvo + attn)
        total += n_dense * 3 * 2.0 * B * D * cfg.d_ff
        if n_moe:
            Fm = cfg.moe_d_ff or cfg.d_ff
            total += n_moe * B * (
                (cfg.experts_per_token + cfg.num_shared_experts)
                * 3 * 2.0 * D * Fm
                + 2.0 * D * cfg.num_experts
            )
        if cfg.family == "encdec":
            total += cfg.num_layers * (
                qkvo + 2.0 * 2.0 * B * cfg.num_heads * ctx * hd
            )
    total += 2.0 * B * D * cfg.vocab_size
    return total


def cell_flops(cfg: ArchConfig, shape: ShapeCell) -> float:
    """Analytic total FLOPs for the cell's step (global, all devices)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 3.0 * forward_flops(cfg, B, S)  # fwd + 2x bwd
    if shape.kind == "prefill":
        return forward_flops(cfg, B, S)
    return decode_flops(cfg, B, S)


def cell_hbm_bytes(cfg: ArchConfig, shape: ShapeCell, n_params: int) -> float:
    """Analytic minimum HBM traffic (global): parameters read (bf16) per
    step + KV/state cache traffic for decode."""
    B, S = shape.global_batch, shape.seq_len
    param_bytes = 2.0 * n_params
    if shape.kind == "train":
        # fwd + bwd read params, write grads + opt state update (fp32 m,v)
        return 3 * param_bytes + 2 * 4.0 * n_params
    if shape.kind == "prefill":
        act = 2.0 * B * S * cfg.d_model * max(cfg.num_layers // 4, 1)
        return param_bytes + act
    # decode: whole cache read once + params
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        cache = 4.0 * cfg.num_layers * B * nheads * cfg.ssm_state * cfg.ssm_head_dim
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        cache = 4.0 * cfg.num_layers * B * nheads * cfg.ssm_state * cfg.ssm_head_dim
        eff = min(cfg.window, S) if cfg.window else S
        cache += 2.0 * 2 * (cfg.num_layers // cfg.attn_every) * B * eff \
            * cfg.num_kv_heads * hd
    else:
        cache = 2.0 * 2 * cfg.num_layers * B * S * cfg.num_kv_heads * hd
        if cfg.family == "encdec":
            cache *= 2  # self + cross
    return param_bytes + cache
