"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    sub_quadratic=True,
    # §Perf iteration 1: at 130M params, FSDP/TP across 256 chips costs
    # 437x more in collectives than it saves — pure DP is the right recipe.
    sharding_recipe="dp_only",
)
