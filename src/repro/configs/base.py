"""Architecture configuration schema + input-shape cells.

One ``<arch>.py`` per assigned architecture defines ``CONFIG`` with the
exact published hyperparameters.  ``smoke()`` derives a reduced config of
the same family for CPU tests; full configs are only ever touched by the
dry-run (ShapeDtypeStructs, no allocation).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 500000.0
    mrope: bool = False  # qwen2-vl M-RoPE
    embed_inputs: bool = False  # modality frontend stub (vlm/audio)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # routed-expert hidden size (deepseek fine-grained)
    first_k_dense: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: shared attn block every k ssm layers
    window: int = 0  # sliding-window attention (hybrid long-context)
    # enc-dec
    encoder_layers: int = 0
    # capabilities
    sub_quadratic: bool = False  # can run long_500k
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # production sharding recipe (parallel/sharding.RECIPES) — set per arch
    # from the §Perf hillclimbs (small models must not shard params over
    # hundreds of chips).
    sharding_recipe: str = "default"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Skip rules from the assignment (recorded in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k context is quadratic"
    return True, ""


def smoke_shrink(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        d_ff=128,
        vocab_size=512,
        head_dim=16,
    )
    if cfg.family in ("moe",):
        kw.update(
            num_experts=4,
            experts_per_token=min(2, cfg.experts_per_token),
            num_shared_experts=cfg.num_shared_experts,
            moe_d_ff=64,
            first_k_dense=min(1, cfg.first_k_dense),
        )
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, num_layers=4)
    if cfg.family == "hybrid":
        kw.update(attn_every=2, window=64)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2)
    return dataclasses.replace(cfg, **kw)
