"""seamless-m4t-medium [audio] — enc-dec backbone, multimodal
[arXiv:2308.11596; hf].  Audio frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings (batch, frames,
d_model); the text decoder is standard causal with cross-attention."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    embed_inputs=True,      # encoder consumes frame embeddings
    rope_theta=10000.0,
)
