"""Architecture registry: ``get_config(name)`` / ``ARCHS``."""

from __future__ import annotations

from .base import SHAPES, ArchConfig, ShapeCell, cell_applicable, smoke_shrink
from . import (
    deepseek_7b,
    deepseek_moe_16b,
    llama3_2_3b,
    llama3_405b,
    llama4_scout_17b_a16e,
    mamba2_130m,
    qwen2_vl_72b,
    qwen3_4b,
    seamless_m4t_medium,
    zamba2_7b,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        llama3_405b,
        llama3_2_3b,
        qwen3_4b,
        deepseek_7b,
        zamba2_7b,
        seamless_m4t_medium,
        deepseek_moe_16b,
        llama4_scout_17b_a16e,
        qwen2_vl_72b,
        mamba2_130m,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells() -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its applicability + skip reason."""
    out = []
    for aname, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, why = cell_applicable(cfg, shape)
            out.append((aname, sname, ok, why))
    return out


__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "ShapeCell",
    "get_config",
    "all_cells",
    "cell_applicable",
    "smoke_shrink",
]
