"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
Backbone only per the assignment: the vision frontend is a STUB —
input_specs() provides precomputed patch/text embeddings plus (3, B, S)
M-RoPE position ids."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    mrope=True,
    embed_inputs=True,
    rope_theta=1000000.0,
)
