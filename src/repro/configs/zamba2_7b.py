"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].  Shared transformer block applied every 6
mamba layers (one weight set, zamba's signature trick); sliding-window
attention keeps the 500k decode sub-quadratic."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    window=4096,
    sub_quadratic=True,
)
