"""Fault-tolerant checkpointing.

Design goals (the 1000-node story):
  * atomic — write to ``step_N.tmp`` then ``os.replace``; a crash mid-save
    never corrupts the latest checkpoint;
  * async — saving happens on a background thread from host copies so the
    train loop only blocks for the device→host transfer;
  * mesh-agnostic — arrays are saved unsharded by logical path; restore
    re-binds them to whatever mesh/device-count the restarted job has
    (elastic restart after losing a pod);
  * bounded — keeps the newest ``keep`` checkpoints, deletes older ones.

Storage is a directory of ``.npz`` shards + ``meta.json`` per step (no
external deps; the orbax-shaped API keeps the swap cheap on a real
cluster).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _fsync_dir(path: str) -> None:
    """fsync the directory entry so a rename survives power loss."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs without dir fds
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_slice_checkpoint(path: str, state) -> None:
    """Atomically persist a :class:`~repro.core.distributed.
    SliceRangeCheckpoint` to ``path`` (.npz).

    Write-to-temp + flush + ``os.fsync`` + ``os.replace`` + directory
    fsync: a host killed at any instant leaves either the previous
    complete checkpoint or the new complete checkpoint on disk — never a
    truncated file that would silently drop completed slice ids on
    resume (the resumed run would then re-execute them and double-count
    their contribution into ``partial``)."""
    iv = np.asarray(state._intervals(), dtype=np.int64).reshape(-1, 2)
    partial = np.asarray(state.partial)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(
            f,
            n_slices=np.int64(state.n_slices),
            intervals=iv,
            partial=partial,
        )
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))


def load_slice_checkpoint(path: str):
    """Load a checkpoint written by :func:`save_slice_checkpoint`."""
    from ..core.distributed import SliceRangeCheckpoint  # lazy: no cycle

    with np.load(path) as z:
        n_slices = int(z["n_slices"])
        intervals = z["intervals"]
        partial = z["partial"]
    done = {(int(s), int(e)) for s, e in intervals}
    if partial.ndim == 0:
        partial = partial[()]
    return SliceRangeCheckpoint(n_slices, done, partial)


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Flatten to numpy, encoding non-native dtypes (bfloat16 & friends)
    as uint16/uint8 views with the true dtype recorded in meta."""
    flat = {}
    exotic: dict[str, str] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            exotic[key] = arr.dtype.name
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        flat[key] = arr
    return flat, exotic


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        host, exotic = _flatten(tree)  # device→host happens synchronously

        def work():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                    np.savez(f, **host)
                    f.flush()
                    os.fsync(f.fileno())
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(
                        {"step": step, "keys": sorted(host),
                         "dtypes": exotic}, f
                    )
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                _fsync_dir(self.dir)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.check()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.check()

    def check(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``; with ``shardings``
        the arrays are placed directly on the (possibly different) mesh —
        the elastic-restart path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        exotic = meta.get("dtypes", {})
        if exotic:
            import ml_dtypes

            for key, dname in exotic.items():
                data[key] = data[key].view(np.dtype(dname))

        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None else None
        )
        leaves = []
        for i, (p, leaf) in enumerate(flat_t):
            key = "/".join(
                str(getattr(q, "key", getattr(q, "idx", q))) for q in p
            )
            arr = data[key]
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
