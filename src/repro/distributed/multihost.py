"""Multi-host sliced-contraction driver: scheduler × transport × claims.

This is the composition root of the package — the loop every host of an
N-process run executes identically:

  1. build the same LPT queues from the same ``(missing, costs, n_hosts,
     seed)`` (no communication needed to agree on the assignment);
  2. claim ranges through the :class:`~repro.distributed.scheduler.
     Arbiter` — own queue first, then steal — and execute each as one
     :meth:`~repro.engine.session.ContractionSession.run_slices` batch
     (wrapped ids + validity mask, the engine's shared ragged-batch
     contract — the same masked-vmap program every driver runs);
  3. persist every completed range's partial delta to the elastic
     :class:`~repro.distributed.elastic.ClaimStore` (when a checkpoint
     dir is given): fault tolerance is a side effect of the hot loop,
     not a separate mode;
  4. emit exactly ``transport.rounds`` reduction pushes — the fixed
     collective-call count that makes overlapped reduction deadlock-safe
     under stealing (hosts whose work drained pad with zero deltas);
  5. finalize the transport for the reduced amplitude and report
     ``schedule_imbalance`` / ``steal_count`` / ``overlap_fraction``.

World-size-1 invariance: with one process the scheduler degenerates to a
single queue in id order, the transport to a local sum, and the executed
program is the same jitted masked-vmap batch the single-host paths run —
`tests/test_multihost.py` pins agreement with ``contract_all``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..obs import metrics as _metrics, trace as _trace
from .elastic import ClaimStore
from .scheduler import LocalArbiter, SliceScheduler
from .transport import (
    CollectiveTransport,
    FileTransport,
    NullTransport,
    Transport,
    world,
)


@dataclasses.dataclass
class MultiHostResult:
    """Outcome of one host's :func:`contract_multihost` participation.

    ``value`` is the globally reduced amplitude (identical on every host
    for collective/file transports); ``complete`` is False when coverage
    has holes — a dead peer's unfinished ids, recoverable by a resumed
    run with a bumped epoch."""

    value: np.ndarray
    complete: bool
    n_slices: int
    executed_slices: int
    padded_slices: int
    executed_ranges: list
    schedule_imbalance: float
    initial_imbalance: float
    steal_count: int
    steal_order: list
    overlap_fraction: float
    state: object | None = None  # merged SliceRangeCheckpoint (store runs)


def _resolve_transport(
    transport, size: int, mesh, store, reduce_rounds: int, reduce_chunks: int
) -> Transport:
    if isinstance(transport, Transport):
        return transport
    name = transport
    if name == "auto":
        name = "null" if size == 1 else "collective"
    if name == "null":
        return NullTransport(rounds=reduce_rounds)
    if name == "collective":
        tp = CollectiveTransport(mesh=mesh, chunks=reduce_chunks)
        tp.rounds = max(1, int(reduce_rounds))
        return tp
    if name == "file":
        if store is None:
            raise ValueError(
                "transport='file' requires checkpoint_dir (the partials "
                "travel through the claim store's merged checkpoint)"
            )
        return FileTransport(store)
    raise ValueError(
        f"transport {transport!r} not in ('auto', 'null', 'collective', "
        "'file') and not a Transport instance"
    )


def contract_multihost(
    plan,
    arrays,
    *,
    slice_batch: int = 1,
    hoist: bool | None = None,
    costs=None,
    transport="auto",
    mesh=None,
    checkpoint_dir: str | None = None,
    epoch: int = 0,
    policy: str = "lpt",
    seed: int = 0,
    reduce_rounds: int = 4,
    reduce_chunks: int = 4,
    fail_after: int | None = None,
    report=None,
    rank: int | None = None,
    world_size: int | None = None,
) -> MultiHostResult:
    """Contract all slices across the processes of a jax.distributed run.

    Every process calls this with identical arguments (plus its own
    implicit ``jax.process_index()``); the per-slice modeled FLOPs
    (``costs``, default the co-optimizer's
    :func:`~repro.optimize.search.per_slice_cost_vector`) seed the LPT
    queues, ``checkpoint_dir`` turns on elastic claims + resume, and
    ``transport`` picks the reduction plane (``"auto"``:
    :class:`NullTransport` at world size 1, overlapped
    :class:`CollectiveTransport` otherwise; ``"file"`` reduces through
    the claim store — the transport that survives a peer dying mid-run).

    ``fail_after=k`` simulates a host failure: this host executes ``k``
    ranges, then dies *holding its next claim* — the stale-claim shape a
    bumped-``epoch`` resume must reclaim.  ``report`` (a
    :class:`~repro.core.api.PlanReport`) receives
    ``schedule_imbalance`` / ``steal_count`` / ``overlap_fraction``.

    ``rank``/``world_size`` default to the jax.distributed world; the
    overrides let collective-free transports (``"file"``) emulate an
    N-host run as N sequential driver calls in one process — the
    deterministic harness the host-failure resume tests use (a real
    dead peer would hang a collective rendezvous, so failure runs are
    file-transport by construction).
    """
    from ..core.distributed import SliceRangeCheckpoint
    from ..core.executor import auto_slice_batch
    from ..engine.session import ContractionSession, record_execution

    jrank, jsize = world()
    rank = jrank if rank is None else int(rank)
    size = jsize if world_size is None else int(world_size)
    sess = ContractionSession(plan, arrays, hoist=hoist)
    n_slices = sess.n_slices
    sb = auto_slice_batch(slice_batch, n_slices)
    hoist = sess.hoist

    if costs is None and plan.num_sliced:
        from ..optimize.search import per_slice_cost_vector

        costs = per_slice_cost_vector(plan.tree, plan.smask)

    store = None
    if checkpoint_dir is not None:
        store = ClaimStore(checkpoint_dir, n_slices, host=rank, epoch=epoch)
        store.reclaim_stale()
        store.sync_dirs()
        base = store.merged()
    else:
        base = SliceRangeCheckpoint(n_slices, set(), 0.0)
    missing = base.missing(sb)

    scheduler = SliceScheduler(
        missing, size, costs, policy=policy, seed=seed
    )
    arbiter = store if store is not None else LocalArbiter()
    # cross-host stealing needs a cross-host arbiter; without a claim
    # store an N-process run falls back to its static (but still LPT)
    # assignment — each host executes exactly its own queue.
    allow_steal = store is not None or size == 1

    tp = _resolve_transport(
        transport, size, mesh, store, reduce_rounds, reduce_chunks
    )
    rounds = max(1, tp.rounds)

    sess.hoisted()  # materialize the prologue outside the claim loop
    zero = sess.zeros()

    own0 = len(scheduler.queues[rank])
    per_round = max(1, -(-own0 // rounds))  # ranges between pushes
    _metrics.set_gauge(f"sched.queue_depth.h{rank}", own0)

    pushes = 0
    since_push = None  # accumulated (async) delta since the last push
    executed_ranges: list = []
    executed_ids = 0
    padded = 0

    def emit_push():
        nonlocal pushes, since_push
        tp.push(np.asarray(since_push) if since_push is not None else zero)
        pushes += 1
        since_push = None

    with _trace.span(
        "exec.multihost", cat="exec", rank=rank, size=size,
        slices=n_slices, slice_batch=sb, hoist=hoist, policy=policy,
        rounds=rounds, transport=type(tp).__name__,
    ):
        while True:
            rng = scheduler.next_range(rank, arbiter, steal=allow_steal)
            if rng is None:
                break
            if fail_after is not None and len(executed_ranges) >= fail_after:
                # die *holding* this claim: nobody completes it, and only
                # a bumped-epoch resume may reclaim it (a live same-epoch
                # peer must never — we might just be slow, not dead).
                raise RuntimeError(
                    f"simulated host {rank} failure holding claim "
                    f"[{rng.start},{rng.end})"
                )
            ids = (
                np.arange(rng.start, rng.start + sb, dtype=np.int32)
                % n_slices
            )
            valid = np.arange(rng.start, rng.start + sb) < rng.end
            with _trace.span(
                "exec.mh_range", cat="exec", start=rng.start, end=rng.end,
                stolen=rng.home != rank,
            ):
                delta = sess.run_slices(ids, valid)
            since_push = delta if since_push is None else since_push + delta
            executed_ranges.append(rng.key())
            executed_ids += rng.n_ids
            padded += sb - rng.n_ids
            if store is not None:
                store.complete(rng, np.asarray(delta))
            if pushes < rounds - 1 and (
                len(executed_ranges) % per_round == 0
            ):
                emit_push()
        # drain the fixed collective schedule: every host must emit
        # exactly `rounds` pushes or a peer's rendezvous never completes
        while pushes < rounds:
            emit_push()
        value = tp.finalize()

    if value is None:
        value = zero
    if store is not None and not isinstance(tp, FileTransport):
        # resumed work completed in earlier epochs travelled through the
        # store, not this run's pushes; fold the merged base back in
        # (identical on every host — base is the global pre-run state)
        value = value + np.asarray(base.partial)

    final_state = None
    complete = True
    if store is not None:
        final_state = store.merged()
        complete = not final_state.missing(1)

    record_execution(plan, executed_ids, padded, hoist)
    imb = scheduler.realized_imbalance()
    if report is not None:
        report.schedule_imbalance = imb
        report.steal_count = scheduler.steal_count
        report.overlap_fraction = tp.overlap_fraction

    return MultiHostResult(
        value=value,
        complete=complete,
        n_slices=n_slices,
        executed_slices=executed_ids,
        padded_slices=padded,
        executed_ranges=executed_ranges,
        schedule_imbalance=imb,
        initial_imbalance=scheduler.initial_imbalance,
        steal_count=scheduler.steal_count,
        steal_order=list(scheduler.steal_order),
        overlap_fraction=tp.overlap_fraction,
        state=final_state,
    )
