"""Multi-process transport: ``jax.distributed`` init + overlapped
chunked all-reduce.

The paper ends every sliced contraction with "only one all-reduce
operation ... after the computation" — a terminal barrier.  "Closing the
gap" (arXiv 2110.14502) showed the cross-node reduction can instead be
overlapped with the remaining slice computation.  This module provides
that as a transport abstraction the multi-host driver composes with the
scheduler:

  * :func:`init_multi_host` wraps ``jax.distributed.initialize`` with
    gloo CPU collectives, env-var defaults (``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``), and a no-op
    single-process path — the same script runs unchanged as 1 or N CPU
    processes (the CI matrix leg launches 2);
  * :class:`CollectiveTransport` reduces the partial amplitude in a
    **fixed number of rounds × chunks** of psum calls.  Fixing the call
    count up front is what makes overlapping safe under work stealing:
    hosts execute *different* numbers of slice batches, but every host
    dispatches the identical sequence of collectives (zero-padded when
    its work ran out), so gloo's order-matched rendezvous can never
    deadlock.  Rounds are dispatched asynchronously mid-run — jax's
    async dispatch reduces round ``r`` on the collective thread while
    the host's Python thread is already dispatching the next slice
    batch — and only :meth:`finalize` blocks, yielding the measured
    ``overlap_fraction``;
  * :class:`FileTransport` is the collective-free control-plane-only
    fallback: partials travel through the elastic claim store's merged
    checkpoint (a host crash can never hang a rendezvous — the
    host-failure resume test runs on this transport);
  * :class:`NullTransport` is world-size-1: local sum, zero overhead.
"""

from __future__ import annotations

import os
import time

import numpy as np


def world() -> tuple[int, int]:
    """(process_index, process_count) of the current jax runtime."""
    import jax

    return jax.process_index(), jax.process_count()


def init_multi_host(
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> tuple[int, int]:
    """Initialize ``jax.distributed`` for an N-process CPU/TPU run.

    Arguments default to ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES``
    / ``REPRO_PROCESS_ID``; with no configuration at all (or
    ``num_processes == 1``) this is a no-op and the run stays
    single-process — the world-size-1 invariance contract.  On CPU the
    gloo collectives backend is selected *before* backend init so
    cross-process psum works without MPI (xpc-free: plain subprocesses).
    Returns ``(process_index, process_count)``."""
    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR")
    if num_processes is None:
        num_processes = int(os.environ.get("REPRO_NUM_PROCESSES", "1"))
    if process_id is None:
        process_id = int(os.environ.get("REPRO_PROCESS_ID", "0"))
    if num_processes <= 1 or coordinator is None:
        return world()
    import jax

    try:  # newer jax: plugin-selectable CPU collectives; gloo ships in-tree
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - config absent on old jax
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    return world()


class Transport:
    """Reduction transport for the multi-host driver.

    The driver calls :meth:`push` exactly ``rounds`` times per host with
    the local partial-sum *delta* accumulated since the previous push
    (zeros when the host's work has drained), then :meth:`finalize` once
    for the fully reduced value.  ``overlap_fraction`` is only
    meaningful after finalize."""

    #: number of push rounds the driver must emit (uniform across hosts)
    rounds: int = 1
    overlap_fraction: float = 0.0

    def push(self, delta) -> None:
        raise NotImplementedError

    def finalize(self):
        raise NotImplementedError


class NullTransport(Transport):
    """World-size-1: the local accumulator *is* the reduction."""

    def __init__(self, rounds: int = 1):
        self.rounds = max(1, int(rounds))
        self._acc = None

    def push(self, delta) -> None:
        d = np.asarray(delta)
        self._acc = d if self._acc is None else self._acc + d

    def finalize(self):
        return self._acc


class CollectiveTransport(Transport):
    """Chunked, overlapped cross-process all-reduce via shard_map psum.

    The complex accumulator is viewed as a flat float32/float64 buffer,
    zero-padded to ``chunks`` equal pieces (one traced program serves
    every chunk), and each :meth:`push` dispatches ``chunks`` psum calls
    *without blocking* — on CPU the gloo rendezvous runs on XLA's
    execution threads while Python keeps dispatching compute.
    :meth:`finalize` blocks on all outstanding reductions, sums the
    rounds, and restores shape/dtype.

    ``overlap_fraction`` = 1 − (blocked wall in finalize) / (wall from
    the first push to the end of finalize): 1.0 means the reduction was
    fully hidden behind slice compute, 0.0 means it degenerated to the
    paper's terminal barrier."""

    def __init__(self, mesh=None, axis_name: str = "data", chunks: int = 4):
        import jax

        if mesh is None:
            from ..launch.mesh import multi_host_mesh

            mesh = multi_host_mesh(axis_name)
        self.mesh = mesh
        self.axis_name = axis_name
        self.chunks = max(1, int(chunks))
        # the local delta enters the shard_map replicated (in_specs=P()),
        # so every *local* device contributes a copy to the psum; scale
        # by this process's device count in the mesh so each process's
        # delta is counted exactly once (exact for power-of-2 counts)
        me = jax.process_index()
        self._nlocal = max(
            1,
            sum(
                1 for d in np.asarray(mesh.devices).flat
                if d.process_index == me
            ),
        )
        self.rounds = 1  # driver overrides before the run starts
        self._pending: list = []  # per round: list of reduced chunk arrays
        self._template = None  # (shape, dtype, view_dtype, flat_len)
        self._t_first_push = None
        self._reduce = None
        self._jax = jax

    # -- lazily traced collective (one program, every chunk reuses it) --
    def _reducer(self):
        if self._reduce is None:
            import jax
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            axis = self.axis_name

            def psum_chunk(x):
                return jax.lax.psum(x, axis)

            self._reduce = jax.jit(
                shard_map(
                    psum_chunk,
                    mesh=self.mesh,
                    in_specs=P(),
                    out_specs=P(),
                    check_rep=False,
                )
            )
        return self._reduce

    @staticmethod
    def _as_flat(d, view):
        """Flatten to a 1-d real view (complex dtypes reinterpreted as
        interleaved re/im pairs — gloo reduces real buffers only)."""
        flat = np.ascontiguousarray(d).reshape(-1)
        if d.dtype.kind == "c":
            return flat.view(view)
        return flat.astype(view, copy=False)

    def push(self, delta) -> None:
        import jax.numpy as jnp

        d = np.asarray(delta)
        if self._template is None:
            view = np.float64 if d.dtype == np.complex128 else np.float32
            flat = self._as_flat(d, view)
            pad = -len(flat) % self.chunks
            self._template = (d.shape, d.dtype, view, len(flat), pad)
        shape, dtype, view, n, pad = self._template
        flat = self._as_flat(d, view) / view(self._nlocal)
        if pad:
            flat = np.concatenate([flat, np.zeros(pad, view)])
        if self._t_first_push is None:
            self._t_first_push = time.perf_counter()
        reduce = self._reducer()
        csize = len(flat) // self.chunks
        outs = [
            reduce(jnp.asarray(flat[i * csize:(i + 1) * csize]))
            for i in range(self.chunks)
        ]
        self._pending.append(outs)

    def finalize(self):
        import jax

        if not self._pending:
            return None
        t0 = time.perf_counter()
        jax.block_until_ready(self._pending)
        t_block = time.perf_counter() - t0
        window = time.perf_counter() - (self._t_first_push or t0)
        self.overlap_fraction = (
            max(0.0, 1.0 - t_block / window) if window > 0 else 0.0
        )
        shape, dtype, view, n, pad = self._template
        total = None
        for outs in self._pending:
            flat = np.concatenate([np.asarray(o) for o in outs])[:n]
            total = flat if total is None else total + flat
        if np.dtype(dtype).kind == "c":
            return total.view(dtype).reshape(shape)
        return total.astype(dtype).reshape(shape)


class FileTransport(Transport):
    """Reduce through the elastic claim store's merged checkpoint.

    The driver already persists every completed range's partial delta to
    the store (that is the fault-tolerance contract), so the reduction
    is simply the merged checkpoint's partial sum — no collectives, no
    rendezvous to hang when a host dies mid-run.  ``finalize`` returns
    the merged partial *regardless of coverage*; the driver checks
    coverage and reports incompleteness (a dead host's unfinished ids
    stay missing until a resumed run steals them)."""

    def __init__(self, store):
        self.store = store
        self.rounds = 1

    def push(self, delta) -> None:  # partials travel via the store
        pass

    def finalize(self):
        state = self.store.merged()
        return np.asarray(state.partial)
