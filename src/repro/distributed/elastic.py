"""Elastic multi-host coordination: atomic slice-range claims + merged
checkpoints on a shared filesystem.

The fault-tolerance unit of the whole stack is the slice id
(:class:`~repro.core.distributed.SliceRangeCheckpoint` tracks completed
*ids*, chunk-agnostically), which makes elasticity cheap: a host is just
a loop that claims id ranges, executes them, and persists its partial —
any host can die, join, or steal at range granularity.  This module is
the coordination substrate:

  * **claims** — ownership of a range is an ``O_CREAT | O_EXCL`` file
    create in ``claims/`` (atomic on POSIX and NFSv4+): exactly one host
    wins, which is precisely the :class:`~repro.distributed.scheduler.
    Arbiter` contract, so work stealing across *processes* is the same
    code path as across threads;
  * **completion** — each host owns one checkpoint file
    (``hosts/host_<h>.npz``, single-writer) updated atomically via
    :func:`repro.checkpoint.manager.save_slice_checkpoint` (temp +
    fsync + ``os.replace``) after every completed range: a kill at any
    instant leaves a consistent prefix of its work;
  * **merge** — :meth:`ClaimStore.merged` unions every host file into
    one :class:`SliceRangeCheckpoint` (interval union + partial sum);
    ``missing()`` of the merge is what a resumed run schedules, so a
    host joining or leaving mid-run steals exactly the ids nobody
    finished;
  * **stale-claim reclaim** — claims carry the run ``epoch``; a resumed
    run (higher epoch) deletes claims from dead epochs whose ranges were
    never completed, returning a crashed host's in-flight work to the
    pool.  Same-epoch claims are never reclaimed (their owner may be a
    live peer mid-execution).
"""

from __future__ import annotations

import json
import os

import numpy as np

from ..checkpoint.manager import (
    _fsync_dir,
    load_slice_checkpoint,
    save_slice_checkpoint,
)
from ..obs import log as _log, metrics as _metrics
from .scheduler import Arbiter, SliceRange


class ClaimStore(Arbiter):
    """Filesystem-backed claim/checkpoint store for one sliced
    contraction (one ``(plan, arrays)`` run family).

    Layout under ``root``::

        claims/claim_<start>_<end>.json   # atomic ownership records
        hosts/host_<h>.npz                # per-host SliceRangeCheckpoint

    ``host`` is this process's stable identity (defaults to the jax
    process index upstream); ``epoch`` increments across restarts of the
    same logical run and gates stale-claim reclaim."""

    def __init__(self, root: str, n_slices: int, host: int, epoch: int = 0):
        self.root = root
        self.n_slices = int(n_slices)
        self.host = int(host)
        self.epoch = int(epoch)
        self.claims_dir = os.path.join(root, "claims")
        self.hosts_dir = os.path.join(root, "hosts")
        os.makedirs(self.claims_dir, exist_ok=True)
        os.makedirs(self.hosts_dir, exist_ok=True)
        self._own_state = None  # lazily loaded own host checkpoint

    # ------------------------------------------------------------ claims
    def _claim_path(self, start: int, end: int) -> str:
        return os.path.join(self.claims_dir, f"claim_{start}_{end}.json")

    def try_claim(self, rng: SliceRange, host: int) -> bool:
        """Atomically claim ``[rng.start, rng.end)`` — True exactly once
        across every process sharing ``root`` (O_EXCL create)."""
        path = self._claim_path(rng.start, rng.end)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        try:
            os.write(
                fd,
                json.dumps(
                    {"host": int(host), "epoch": self.epoch,
                     "start": rng.start, "end": rng.end}
                ).encode(),
            )
            os.fsync(fd)
        finally:
            os.close(fd)
        return True

    def reclaim_stale(self) -> int:
        """Delete claims from *older epochs* whose ranges were never
        completed — the ids a dead host took to its grave go back to the
        pool for this run to steal.  Returns the number reclaimed."""
        merged = self.merged()
        done = merged._intervals()

        def covered(s: int, e: int) -> bool:
            return any(a <= s and e <= b for a, b in done)

        reclaimed = 0
        for name in sorted(os.listdir(self.claims_dir)):
            path = os.path.join(self.claims_dir, name)
            try:
                with open(path) as f:
                    rec = json.load(f)
            except (OSError, json.JSONDecodeError):
                rec = None  # truncated claim (killed mid-write): reclaim
            if rec is not None and rec.get("epoch", -1) >= self.epoch:
                continue
            if rec is not None and covered(rec["start"], rec["end"]):
                continue  # completed work: claim is just a record now
            try:
                os.unlink(path)
                reclaimed += 1
            except FileNotFoundError:  # pragma: no cover - racing peer
                pass
        if reclaimed:
            _metrics.inc("elastic.claims_reclaimed", reclaimed)
            _log.info(
                f"reclaimed {reclaimed} stale claims (epoch < {self.epoch})",
                reclaimed=reclaimed,
            )
        return reclaimed

    # -------------------------------------------------------- completion
    def _host_path(self, host: int) -> str:
        return os.path.join(self.hosts_dir, f"host_{host}.npz")

    def _fresh_state(self):
        from ..core.distributed import SliceRangeCheckpoint  # lazy

        return SliceRangeCheckpoint(self.n_slices, set(), 0.0)

    def own_state(self):
        """This host's checkpoint (loaded once, then kept in memory — the
        host file is single-writer by construction)."""
        if self._own_state is None:
            path = self._host_path(self.host)
            if os.path.exists(path):
                self._own_state = load_slice_checkpoint(path)
            else:
                self._own_state = self._fresh_state()
        return self._own_state

    def complete(self, rng: SliceRange, partial_delta) -> None:
        """Record ``rng`` done with its partial-sum contribution and
        atomically persist this host's checkpoint.  The delta is added
        exactly once (the driver only executes ranges it claimed, and a
        claim is granted exactly once)."""
        state = self.own_state()
        state.partial = state.partial + np.asarray(partial_delta)
        state.add_range(rng.start, rng.end)
        save_slice_checkpoint(self._host_path(self.host), state)
        _metrics.inc("elastic.ranges_completed")

    # ------------------------------------------------------------- merge
    def merged(self):
        """Union of every host's checkpoint: interval union + partial
        sum — the global run state any host (or a fresh resume) can
        derive alone.  Atomic per-file (``os.replace`` publishes whole
        checkpoints), so a concurrent reader sees a consistent, possibly
        slightly stale, snapshot."""
        state = self._fresh_state()
        if self._own_state is not None:
            state.done |= set(self._own_state._intervals())
            state.partial = state.partial + np.asarray(
                self._own_state.partial
            )
        for name in sorted(os.listdir(self.hosts_dir)):
            if not name.endswith(".npz"):
                continue
            h = int(name[len("host_"):-len(".npz")])
            if self._own_state is not None and h == self.host:
                continue  # in-memory copy is at least as fresh
            try:
                other = load_slice_checkpoint(
                    os.path.join(self.hosts_dir, name)
                )
            except (OSError, ValueError, KeyError):  # pragma: no cover
                continue  # mid-replace read on exotic fs: skip this pass
            state.done |= set(other._intervals())
            state.partial = state.partial + np.asarray(other.partial)
        state.done = set(state._intervals())
        return state

    def sync_dirs(self) -> None:
        """fsync both store directories (called once after setup so the
        directory entries themselves survive power loss)."""
        _fsync_dir(self.claims_dir)
        _fsync_dir(self.hosts_dir)
