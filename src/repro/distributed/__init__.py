"""Hierarchical multi-host slice parallelism (paper Sec. V-D, extended).

The paper distributes the ``2^|S|`` slice subtasks over processes with a
static uniform split and ends with one terminal all-reduce.  This
package is the dynamic successor to that scheme, in three decoupled
layers the driver composes:

  * :mod:`~repro.distributed.scheduler` — LPT work queues seeded by the
    co-optimizer's per-slice modeled FLOPs, with deterministic tail
    stealing between hosts (plus a virtual-time simulator for tests and
    modeled benchmark rows);
  * :mod:`~repro.distributed.transport` — ``jax.distributed`` init (gloo
    CPU collectives; N plain subprocesses in CI) and the overlapped
    chunked all-reduce with a fixed, steal-proof collective call count;
  * :mod:`~repro.distributed.elastic` — filesystem claim store: atomic
    range claims (``O_EXCL``), single-writer per-host checkpoints,
    epoch-gated stale-claim reclaim, and the merged-checkpoint resume.

:func:`~repro.distributed.multihost.contract_multihost` is the driver;
``contract_sharded`` (device-level, single process) remains in
:mod:`repro.core.distributed` and is unchanged at world size 1.
"""

from .elastic import ClaimStore
from .multihost import MultiHostResult, contract_multihost
from .scheduler import (
    Arbiter,
    LocalArbiter,
    SimResult,
    SliceRange,
    SliceScheduler,
    imbalance,
    lpt_assignment,
    make_ranges,
    simulate,
    uniform_assignment,
)
from .transport import (
    CollectiveTransport,
    FileTransport,
    NullTransport,
    Transport,
    init_multi_host,
    world,
)

__all__ = [
    "Arbiter",
    "ClaimStore",
    "CollectiveTransport",
    "FileTransport",
    "LocalArbiter",
    "MultiHostResult",
    "NullTransport",
    "SimResult",
    "SliceRange",
    "SliceScheduler",
    "Transport",
    "contract_multihost",
    "imbalance",
    "init_multi_host",
    "lpt_assignment",
    "make_ranges",
    "simulate",
    "uniform_assignment",
    "world",
]
