"""Slice work-queue scheduler: LPT assignment + work stealing.

The paper's Sec. V-D scheme distributes the ``2^|S|`` slice subtasks over
processes with a *static* uniform split — fine when every subtask costs
the same, which the cost model guarantees only in expectation.  Its
successors (SW-TNC, arXiv 2504.09186) replace the static split with
dynamic slice scheduling because measured per-slice costs are ragged:
cache effects, ragged final batches, heterogeneous or flaky hosts.  This
module is that scheduler, kept deliberately decoupled from jax:

  * slice ids are grouped into contiguous :class:`SliceRange` units of at
    most ``slice_batch`` ids (the executor's vmapped batch — per-host
    batch sizing goes through :func:`repro.core.executor.auto_slice_batch`
    upstream);
  * the initial assignment is **longest-processing-time** (LPT): ranges
    sorted by modeled cost descending feed the least-loaded host queue —
    the classic 4/3-approximation, seeded by the co-optimizer's per-slice
    modeled FLOPs (:func:`repro.optimize.search.per_slice_cost_vector`);
  * between dispatch rounds a host whose queue has drained **steals**
    from the victim with the most modeled work remaining, from the tail
    of the victim's queue (the cheapest pending ranges — the head is what
    the victim itself starts next, so tail steals minimize conflict);
  * every transfer of ownership goes through an :class:`Arbiter` —
    in-process (:class:`LocalArbiter`) for threads and benchmarks, or the
    filesystem claim store of :mod:`repro.distributed.elastic` for real
    multi-process runs — so the same scheduler code serves both and a
    steal is exactly "my claim won".

Everything is deterministic for a given ``(costs, n_hosts, seed)``: ties
break by range start, victim order by (remaining cost, host id), so a
run's assignment and steal order replay bit-identically — the property
the plan cache and the 2-process conformance tests rely on.
"""

from __future__ import annotations

import dataclasses
import threading
import time

from ..obs import metrics as _metrics


@dataclasses.dataclass(frozen=True)
class SliceRange:
    """A contiguous run of slice ids ``[start, end)`` — the unit of
    scheduling, claiming, checkpointing, and stealing.  ``cost`` is the
    summed modeled FLOPs of its ids; ``home`` the LPT-assigned host."""

    start: int
    end: int
    cost: float
    home: int

    @property
    def n_ids(self) -> int:
        return self.end - self.start

    def key(self) -> tuple[int, int]:
        return (self.start, self.end)


def make_ranges(
    missing: list[tuple[int, int]], costs
) -> list[tuple[int, int, float]]:
    """Attach summed per-slice costs to ``[start, end)`` id runs (the
    output of :meth:`SliceRangeCheckpoint.missing`, already capped at the
    per-host slice batch)."""
    out = []
    for s, e in missing:
        c = float(sum(costs[s:e])) if costs is not None else float(e - s)
        out.append((s, e, c))
    return out


def lpt_assignment(
    ranges: list[tuple[int, int, float]], n_hosts: int
) -> list[list[SliceRange]]:
    """Longest-processing-time initial assignment: ranges by cost
    descending (ties by start ascending) onto the least-loaded host
    (ties by host id).  Deterministic; per-host queues come back in
    assignment order, i.e. biggest work first."""
    if n_hosts < 1:
        raise ValueError("n_hosts must be >= 1")
    queues: list[list[SliceRange]] = [[] for _ in range(n_hosts)]
    loads = [0.0] * n_hosts
    for s, e, c in sorted(ranges, key=lambda r: (-r[2], r[0])):
        h = min(range(n_hosts), key=lambda i: (loads[i], i))
        queues[h].append(SliceRange(s, e, c, h))
        loads[h] += c
    return queues


def uniform_assignment(
    ranges: list[tuple[int, int, float]], n_hosts: int
) -> list[list[SliceRange]]:
    """The paper's static split: contiguous, near-equal *count* of ranges
    per host, blind to cost — the baseline the work-stealing scheduler is
    benchmarked against."""
    if n_hosts < 1:
        raise ValueError("n_hosts must be >= 1")
    ordered = sorted(ranges, key=lambda r: r[0])
    n = len(ordered)
    queues: list[list[SliceRange]] = []
    base, extra = divmod(n, n_hosts)
    pos = 0
    for h in range(n_hosts):
        take = base + (1 if h < extra else 0)
        queues.append(
            [SliceRange(s, e, c, h) for s, e, c in ordered[pos:pos + take]]
        )
        pos += take
    return queues


def imbalance(queues: list[list[SliceRange]]) -> float:
    """Max over mean modeled host load (1.0 = perfectly balanced; the
    value ``PlanReport.schedule_imbalance`` reports for the realized
    assignment)."""
    loads = [sum(r.cost for r in q) for q in queues]
    total = sum(loads)
    if total <= 0 or not loads:
        return 1.0
    return max(loads) / (total / len(loads))


class Arbiter:
    """Ownership arbitration: ``try_claim`` returns True exactly once per
    range across all hosts.  Subclasses: :class:`LocalArbiter` (threads,
    benchmarks) and :class:`repro.distributed.elastic.ClaimStore`
    (multi-process, atomic claim files on a shared filesystem)."""

    def try_claim(self, rng: SliceRange, host: int) -> bool:
        raise NotImplementedError


class LocalArbiter(Arbiter):
    """In-process arbiter: a lock-protected claimed set."""

    def __init__(self):
        self._lock = threading.Lock()
        self._claimed: set[tuple[int, int]] = set()

    def try_claim(self, rng: SliceRange, host: int) -> bool:
        with self._lock:
            if rng.key() in self._claimed:
                return False
            self._claimed.add(rng.key())
            return True


class SliceScheduler:
    """Per-host slice work queues with LPT seeding and tail stealing.

    One instance may be shared by threads (benchmarks — pops are
    lock-protected) or instantiated identically on every process of a
    multi-host run (the queues are a deterministic function of
    ``(missing, costs, n_hosts, seed)``, so all hosts agree on the
    assignment without communicating; the :class:`Arbiter` is the only
    cross-host coordination point).
    """

    def __init__(
        self,
        missing: list[tuple[int, int]],
        n_hosts: int,
        costs=None,
        *,
        policy: str = "lpt",
        seed: int = 0,
    ):
        if policy not in ("lpt", "uniform"):
            raise ValueError(f"policy {policy!r} not in ('lpt', 'uniform')")
        self.n_hosts = n_hosts
        self.seed = seed
        self.policy = policy
        ranges = make_ranges(missing, costs)
        assign = lpt_assignment if policy == "lpt" else uniform_assignment
        self.queues: list[list[SliceRange]] = assign(ranges, n_hosts)
        self.initial_imbalance = imbalance(self.queues)
        self._lock = threading.Lock()
        self.steal_count = 0
        self.steal_order: list[tuple[int, int, int]] = []  # (thief, s, e)
        self.executed_cost = [0.0] * n_hosts
        self._drained_at: dict[int, float] = {}  # host -> wall queue drained

    # ------------------------------------------------------------------
    def remaining_cost(self, host: int) -> float:
        return sum(r.cost for r in self.queues[host])

    def queue_depth(self, host: int) -> int:
        return len(self.queues[host])

    def next_range(
        self, host: int, arbiter: Arbiter, steal: bool = True
    ) -> SliceRange | None:
        """Pop the next range ``host`` should execute: own queue head
        first, then steal from the most-loaded victim's tail.  Returns
        ``None`` when no range anywhere can be claimed (all work is
        owned).  ``steal=False`` restricts the host to its own queue —
        the static-assignment mode used when no cross-host arbiter
        exists (collective transport without a claim store).
        Thread-safe for a shared instance; claim latency of a
        successful steal lands in the ``sched.steal_latency_s``
        histogram and queue depth in the ``sched.queue_depth`` gauge."""
        while True:
            with self._lock:
                q = self.queues[host]
                rng = q.pop(0) if q else None
            if rng is None:
                break
            _metrics.set_gauge(
                f"sched.queue_depth.h{host}", self.queue_depth(host)
            )
            if arbiter.try_claim(rng, host):
                with self._lock:
                    self.executed_cost[host] += rng.cost
                return rng
            # claimed elsewhere (a thief got it, or a resumed run raced):
            # just drop it and keep draining
        if not steal:
            return None  # static assignment: own queue only
        # own queue drained: steal
        t_drain = self._drained_at.setdefault(host, time.perf_counter())
        while True:
            with self._lock:
                victims = sorted(
                    (h for h in range(self.n_hosts) if h != host),
                    key=lambda h: (-self.remaining_cost(h), h),
                )
                rng = None
                victim = None
                for v in victims:
                    if self.queues[v]:
                        rng = self.queues[v].pop()  # tail: cheapest pending
                        victim = v
                        break
            if rng is None:
                return None
            if arbiter.try_claim(rng, host):
                with self._lock:
                    self.steal_count += 1
                    self.steal_order.append((host, rng.start, rng.end))
                    self.executed_cost[host] += rng.cost
                _metrics.inc("sched.steals")
                _metrics.observe(
                    "sched.steal_latency_s", time.perf_counter() - t_drain
                )
                _metrics.set_gauge(
                    f"sched.queue_depth.h{victim}", self.queue_depth(victim)
                )
                return rng

    # ------------------------------------------------------------------
    def realized_imbalance(self) -> float:
        """Max/mean of the modeled cost each host actually claimed."""
        total = sum(self.executed_cost)
        if total <= 0:
            return 1.0
        return max(self.executed_cost) / (total / self.n_hosts)

    def summary(self) -> dict:
        return {
            "n_hosts": self.n_hosts,
            "policy": self.policy,
            "initial_imbalance": self.initial_imbalance,
            "realized_imbalance": self.realized_imbalance(),
            "steal_count": self.steal_count,
            "queue_depths": [len(q) for q in self.queues],
        }


# ----------------------------------------------------------------------
# deterministic virtual-time simulation (tests + modeled benchmark rows)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SimResult:
    """Virtual-time execution of a scheduler: no sleeping, no threads —
    events advance in deterministic ``(time, host)`` order, so two
    simulations of the same inputs are bit-identical (the seeded
    determinism contract the tests pin)."""

    makespan: float
    host_busy: list[float]
    steal_count: int
    steal_order: list[tuple[int, int, int]]
    executed: list[list[tuple[int, int]]]  # per host, in execution order

    @property
    def imbalance(self) -> float:
        total = sum(self.host_busy)
        if total <= 0:
            return 1.0
        return max(self.host_busy) / (total / len(self.host_busy))


def simulate(
    scheduler: SliceScheduler,
    host_speed=None,
    cost_scale=None,
) -> SimResult:
    """Run ``scheduler`` to completion in virtual time.

    ``host_speed[h]`` scales host ``h``'s execution rate (0.5 = half
    speed — the heterogeneity that makes stealing matter even under a
    perfect cost model); ``cost_scale(start, end) -> float`` optionally
    maps a range to its *true* execution cost (modeled-cost noise).
    Mutates ``scheduler`` (queues drain); build a fresh one per run."""
    n = scheduler.n_hosts
    speed = list(host_speed) if host_speed is not None else [1.0] * n
    arbiter = LocalArbiter()
    clock = [0.0] * n
    executed: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    done = [False] * n
    while not all(done):
        # next event: the idle-most host asks for work (ties by host id)
        h = min((i for i in range(n) if not done[i]), key=lambda i: (clock[i], i))
        rng = scheduler.next_range(h, arbiter)
        if rng is None:
            done[h] = True
            continue
        true_cost = (
            cost_scale(rng.start, rng.end) if cost_scale is not None
            else rng.cost
        )
        clock[h] += true_cost / max(speed[h], 1e-12)
        executed[h].append(rng.key())
    return SimResult(
        makespan=max(clock) if clock else 0.0,
        host_busy=clock,
        steal_count=scheduler.steal_count,
        steal_order=list(scheduler.steal_order),
        executed=executed,
    )
