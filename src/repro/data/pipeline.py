"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step, shape) — counter-based RNG
(same recipe as JAX's threefry philosophy: hash the coordinates).  That
gives the fault-tolerance substrate for free: restart at step N
reproduces batch N exactly, on any host count (each host slices its rows
of the global batch), so checkpoint-resume and straggler re-execution are
bit-identical.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTextDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: int = 0  # >0: also emit frame/patch embeddings (stub fronts)
    mrope: bool = False

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )

    def batch(self, step: int, host_slice: slice | None = None) -> dict:
        rng = self._rng(step)
        B, S = self.global_batch, self.seq_len
        # learnable Markov stream: next = prev + δ (mod V), δ ∈ {1,2,3}
        # with fixed probabilities — entropy ≈ 1.16 bits, so a working
        # model's loss drops well below ln(V) (random-token streams are
        # unlearnable and make "loss decreases" meaningless).
        start = rng.integers(0, self.vocab_size, size=(B, 1), dtype=np.int64)
        deltas = rng.choice(
            np.array([1, 2, 3]), size=(B, S), p=[0.7, 0.2, 0.1]
        )
        tokens = (
            start + np.concatenate(
                [np.zeros((B, 1), np.int64), np.cumsum(deltas, axis=1)],
                axis=1,
            )
        ) % self.vocab_size
        tokens = tokens.astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.embed_dim:
            out["embeds"] = rng.normal(size=(B, S, self.embed_dim)).astype(
                np.float32
            )
        if self.mrope:
            base = np.arange(S, dtype=np.int32)
            out["positions"] = np.broadcast_to(
                base, (3, B, S)
            ).copy()
        if host_slice is not None:
            out = {
                k: (v[:, host_slice] if k == "positions" else v[host_slice])
                for k, v in out.items()
            }
        return out

    # resumability contract
    def state_dict(self, step: int) -> dict:
        return {"seed": self.seed, "step": step}

    @classmethod
    def from_state(cls, state: dict, **kw) -> tuple["SyntheticTextDataset", int]:
        return cls(seed=state["seed"], **kw), state["step"]
