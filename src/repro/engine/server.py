"""Contraction-as-a-service: a multi-tenant engine over compiled plans.

The serving observation behind this module: a quantum-circuit simulation
service sees *families* — many amplitude/sampling requests against the
same circuit structure (verification sweeps, XEB scoring, spoofing
studies), differing only in bitstring or sampler seed.  Planning and
tracing are expensive and family-keyed (the compiled-plan cache);
execution is cheap and request-keyed.  A server that runs requests one
at a time re-pays dispatch overhead per request and leaves the engine's
batch axis idle; a server that groups by family amortizes the plan
across tenants and can answer many amplitude requests from *one*
contraction.

:class:`EngineServer` implements that:

  * **bounded intake** — :meth:`~EngineServer.submit` enqueues onto a
    bounded queue and returns a :class:`Ticket` immediately; a full
    queue rejects with :class:`ServerOverloaded` (carrying a
    ``retry_after_s`` estimate) instead of accepting unbounded latency,
  * **continuous batching** — background dispatch thread(s) drain up to
    ``max_batch`` tickets at a time and group them by family fingerprint
    (circuit structure + target width + plan kwargs),
  * **amplitude coalescing** — a group of amplitude requests whose
    bitstrings differ on at most ``max_open`` positions is served from a
    single open-qubit batch contraction (the positions that differ
    become the open axes); each request reads its amplitude at its flat
    batch index.  The open set is stabilized grow-only per family (the
    *coalescing window*), so successive groups converge on one batch
    network and one compiled plan instead of replanning per diff-subset.
    Sampling requests against one batch network share one contraction
    and draw per-tenant,
  * **warm/cold paths** — the first group of a family (cold: planning
    dominates) runs on a planner thread pool so the dispatch thread
    never blocks on a plan search; once the family's plan is cached,
    groups run warm on the dispatch thread itself,
  * **per-request accounting** — every ticket records queue/compute/
    total latency; the server keeps coalescing/rejection counters and
    feeds the :mod:`repro.obs.metrics` registry when tracing is on.

Execution rides entirely on the session layer: a group is one
:func:`repro.core.api.open_amplitude_batch` /
:func:`~repro.core.api.simulate_amplitude` call, which contracts through
:class:`~repro.engine.session.ContractionSession` under the shared plan
and hoist caches — so concurrent tenants on one family converge on one
traced program and one hoisted prologue.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..obs import metrics as _metrics, trace as _trace

_SAMPLERS = ("frequency", "rejection", "topk")


def circuit_fingerprint(circuit) -> str:
    """Structural digest of a circuit: qubit count + the exact gate
    sequence (name, qubits, params).  Two requests share a serving
    family iff their circuits share this fingerprint — equal gate
    sequences produce equal amplitudes, so coalescing across distinct
    but structurally identical Circuit objects is sound."""
    h = hashlib.sha256()
    h.update(str(int(circuit.num_qubits)).encode())
    for op in circuit.ops:
        h.update(
            repr((op.name, tuple(op.qubits), tuple(op.params))).encode()
        )
    return h.hexdigest()[:16]


class ServerOverloaded(RuntimeError):
    """Backpressure rejection: the bounded request queue is full.

    ``retry_after_s`` estimates when capacity frees up (queue depth ×
    recent per-group service time / batch size) — clients should back
    off at least that long before resubmitting."""

    def __init__(self, retry_after_s: float, depth: int):
        super().__init__(
            f"request queue full ({depth} queued); "
            f"retry in ~{retry_after_s:.2f}s"
        )
        self.retry_after_s = float(retry_after_s)
        self.depth = int(depth)


@dataclasses.dataclass
class AmplitudeRequest:
    """One amplitude <bitstring|C|0…0>.  ``plan_kwargs`` are forwarded to
    the planner (backend/precision/optimize…) and join the family key —
    requests planned differently never coalesce."""

    circuit: object
    bitstring: str
    target_dim: int = 20
    plan_kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SampleRequest:
    """One correlated-sampling job (``num_samples`` draws from one
    open-qubit batch).  ``open_qubits``/``base_bitstring`` default as in
    :func:`repro.core.api.sample_bitstrings`; requests sharing the
    resolved batch network share one contraction and differ only in
    their per-tenant draw (sampler, seed, count)."""

    circuit: object
    num_samples: int = 1024
    open_qubits: tuple | None = None
    base_bitstring: str | None = None
    sampler: str = "frequency"
    seed: int = 0
    target_dim: int = 20
    plan_kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Ticket:
    """Handle returned by :meth:`EngineServer.submit`.

    ``value`` is a complex amplitude (AmplitudeRequest) or a
    :class:`~repro.sampling.SamplingResult` (SampleRequest); ``batched``
    marks tickets answered from a shared/coalesced contraction.  The
    latency split is the server's accounting unit: ``queue_s`` (submit →
    group start), ``compute_s`` (group start → done), ``total_s``."""

    id: int
    request: object
    status: str = "queued"  # queued|running|done|failed
    t_submit: float = 0.0
    t_start: float = 0.0
    t_done: float = 0.0
    value: object = None
    error: BaseException | None = None
    report: object = None
    batched: bool = False
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False
    )

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        """Block until served; raise the group's error if it failed."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket {self.id} not served within {timeout}s"
            )
        if self.status == "failed":
            raise self.error
        return self.value

    @property
    def queue_s(self) -> float:
        return max(0.0, self.t_start - self.t_submit)

    @property
    def compute_s(self) -> float:
        return max(0.0, self.t_done - self.t_start)

    @property
    def total_s(self) -> float:
        return max(0.0, self.t_done - self.t_submit)


class EngineServer:
    """Multi-tenant contraction server (see module docstring).

    Use as a context manager or call :meth:`start`/:meth:`stop`::

        with EngineServer(max_batch=8) as srv:
            t = srv.submit(AmplitudeRequest(circuit, "0" * 16, target_dim=12))
            amp = t.result(timeout=120)

    ``stop()`` drains the queue before returning — every accepted ticket
    is served or failed, never abandoned.
    """

    def __init__(
        self,
        max_queue: int = 64,
        max_batch: int = 8,
        max_open: int = 6,
        slice_batch: int = 4,
        dispatchers: int = 1,
        planner_threads: int = 2,
    ):
        self.max_queue = int(max_queue)
        self.max_batch = max(1, int(max_batch))
        self.max_open = max(1, int(max_open))
        self.slice_batch = int(slice_batch)
        self.dispatchers = max(1, int(dispatchers))
        self.planner_threads = max(1, int(planner_threads))
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: deque[Ticket] = deque()
        self._threads: list[threading.Thread] = []
        self._planner: ThreadPoolExecutor | None = None
        self._running = False
        self._next_id = 0
        self._warm: set = set()
        self._amp_window: dict[tuple, frozenset] = {}
        self._ewma_group_s: float | None = None
        self._stats = {
            "submitted": 0,
            "completed": 0,
            "failed": 0,
            "rejected": 0,
            "coalesced": 0,
            "groups": 0,
            "warm_groups": 0,
            "cold_groups": 0,
        }

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "EngineServer":
        with self._cond:
            if self._running:
                return self
            self._running = True
        self._planner = ThreadPoolExecutor(
            max_workers=self.planner_threads,
            thread_name_prefix="repro-serve-planner",
        )
        self._threads = [
            threading.Thread(
                target=self._dispatch_loop,
                name=f"repro-serve-dispatch-{i}",
                daemon=True,
            )
            for i in range(self.dispatchers)
        ]
        for th in self._threads:
            th.start()
        return self

    def stop(self) -> None:
        """Stop intake, drain the queue, join every worker."""
        with self._cond:
            self._running = False
            self._cond.notify_all()
        for th in self._threads:
            th.join()
        self._threads = []
        if self._planner is not None:
            self._planner.shutdown(wait=True)
            self._planner = None

    def __enter__(self) -> "EngineServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # intake
    # ------------------------------------------------------------------
    def submit(self, request) -> Ticket:
        """Validate + enqueue; returns immediately with a :class:`Ticket`.

        Raises :class:`ServerOverloaded` when the bounded queue is full
        (backpressure — the request was *not* accepted) and
        ``ValueError`` on malformed requests (fail fast, before they
        occupy queue capacity)."""
        self._normalize(request)
        with self._cond:
            if not self._running:
                raise RuntimeError(
                    "EngineServer is not running; use start() or `with`"
                )
            depth = len(self._queue)
            if depth >= self.max_queue:
                self._stats["rejected"] += 1
                _metrics.inc("serve.rejected")
                per_group = self._ewma_group_s or 0.1
                retry = max(
                    0.01, per_group * (depth / self.max_batch)
                )
                raise ServerOverloaded(retry, depth)
            self._next_id += 1
            ticket = Ticket(
                id=self._next_id, request=request,
                t_submit=time.monotonic(),
            )
            self._queue.append(ticket)
            self._stats["submitted"] += 1
            _metrics.set_gauge("serve.queue_depth", depth + 1)
            self._cond.notify()
        return ticket

    def _normalize(self, request) -> None:
        if isinstance(request, AmplitudeRequest):
            n = request.circuit.num_qubits
            bs = request.bitstring
            if len(bs) != n or set(bs) - {"0", "1"}:
                raise ValueError(
                    f"bitstring must be {n} chars of 0/1, got {bs!r}"
                )
            return
        if isinstance(request, SampleRequest):
            n = request.circuit.num_qubits
            if request.num_samples <= 0:
                raise ValueError(
                    f"num_samples must be positive, got {request.num_samples}"
                )
            if request.sampler not in _SAMPLERS:
                raise ValueError(f"unknown sampler {request.sampler!r}")
            # resolve the batch-network defaults here so the family key
            # (and hence coalescing) sees the resolved values
            if request.open_qubits is None:
                k = min(6, n)
                request.open_qubits = tuple(range(n - k, n))
            request.open_qubits = tuple(sorted(set(request.open_qubits)))
            if not request.open_qubits:
                raise ValueError("need at least one open qubit to sample")
            if request.base_bitstring is None:
                request.base_bitstring = "0" * n
            elif len(request.base_bitstring) != n or set(
                request.base_bitstring
            ) - {"0", "1"}:
                raise ValueError(
                    f"base_bitstring must be {n} chars of 0/1, "
                    f"got {request.base_bitstring!r}"
                )
            return
        raise TypeError(
            f"expected AmplitudeRequest or SampleRequest, got {request!r}"
        )

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def _family_key(self, req) -> tuple:
        pk = tuple(sorted(req.plan_kwargs.items()))
        fp = circuit_fingerprint(req.circuit)
        if isinstance(req, AmplitudeRequest):
            return ("amp", fp, req.target_dim, pk)
        return (
            "smp", fp, req.open_qubits, req.base_bitstring,
            req.target_dim, pk,
        )

    def _amp_open_set(self, key, reqs) -> tuple | None:
        """Open positions for a coalesced amplitude group, or ``None``
        when the group can't coalesce (singleton, identical bitstrings,
        or spread over more than ``max_open`` positions).

        The positions where the group's bitstrings differ are unioned
        grow-only into the family's *coalescing window*: successive
        groups of one family quickly converge on a stable open set and
        therefore ONE batch network + compiled plan, instead of planning
        a fresh network for every distinct diff-subset the arrival
        pattern happens to produce.  (Reading a few extra amplitudes out
        of a 2^k batch is far cheaper than replanning.)  When the union
        would exceed ``max_open`` the group falls back to its own diff
        set."""
        base = reqs[0].bitstring
        n = reqs[0].circuit.num_qubits
        diff = {
            i
            for r in reqs
            for i in range(n)
            if r.bitstring[i] != base[i]
        }
        if len(reqs) == 1 or not diff or len(diff) > self.max_open:
            return None
        with self._lock:
            merged = diff | self._amp_window.get(key, frozenset())
            if len(merged) <= self.max_open:
                self._amp_window[key] = frozenset(merged)
                return tuple(sorted(merged))
        return tuple(sorted(diff))

    def _plan_sig(self, key, tickets) -> tuple:
        """What the group will actually contract — the warm/cold unit.

        Amplitude families serve from different compiled plans depending
        on how the group coalesces (scalar network vs open-qubit batch
        over a specific open set), so warmth is per (family, plan), not
        per family: a family whose scalar path is warm still plans cold
        the first time a coalesced group shows up, and that planning
        must not run inline on the dispatch thread."""
        if key[0] == "amp":
            open_set = self._amp_open_set(
                key, [t.request for t in tickets]
            )
            return (key, "scalar" if open_set is None else open_set)
        return key

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait(timeout=0.1)
                if not self._queue:
                    if not self._running:
                        return
                    continue
                take = min(self.max_batch, len(self._queue))
                batch = [self._queue.popleft() for _ in range(take)]
                _metrics.set_gauge("serve.queue_depth", len(self._queue))
            groups: dict[tuple, list[Ticket]] = {}
            for t in batch:
                groups.setdefault(self._family_key(t.request), []).append(t)
            for key, tickets in groups.items():
                sig = self._plan_sig(key, tickets)
                with self._lock:
                    warm = sig in self._warm
                    self._stats["warm_groups" if warm else "cold_groups"] += 1
                if warm:
                    # plan is cached: serve inline, no planning stall
                    self._run_group(key, tickets, warm=True)
                else:
                    # cold: planning dominates — keep it off the dispatch
                    # thread so warm tenants behind it are not stalled
                    self._planner.submit(
                        self._run_group, key, tickets, False
                    )

    def _run_group(self, key, tickets, warm: bool) -> None:
        t0 = time.monotonic()
        for t in tickets:
            t.t_start = t0
            t.status = "running"
        try:
            with _trace.span(
                "serve.group", cat="serve", kind=key[0],
                size=len(tickets), warm=warm,
            ):
                if key[0] == "amp":
                    self._serve_amplitudes(key, tickets)
                else:
                    self._serve_samples(tickets)
        except BaseException as e:  # noqa: BLE001 — fail the tickets, not the loop
            now = time.monotonic()
            for t in tickets:
                t.error = e
                t.status = "failed"
                t.t_done = now
                t._event.set()
            with self._lock:
                self._stats["failed"] += len(tickets)
            _metrics.inc("serve.failed", len(tickets))
            return
        now = time.monotonic()
        for t in tickets:
            t.t_done = now
            t.status = "done"
            t._event.set()
            _metrics.observe("serve.queue_s", t.queue_s)
            _metrics.observe("serve.compute_s", t.compute_s)
        # per-family accounting: labeled series are cardinality-bounded
        # by the registry (overflow collapses into `{_other}`)
        _metrics.inc("serve.family_requests", len(tickets), label=key[1])
        dt = now - t0
        sig = self._plan_sig(key, tickets)
        with self._lock:
            self._warm.add(sig)
            self._stats["completed"] += len(tickets)
            self._stats["groups"] += 1
            self._ewma_group_s = (
                dt
                if self._ewma_group_s is None
                else 0.5 * self._ewma_group_s + 0.5 * dt
            )
        _metrics.inc("serve.completed", len(tickets))

    # ------------------------------------------------------------------
    # group execution (on sessions, through the plan/hoist caches)
    # ------------------------------------------------------------------
    def _serve_amplitudes(self, key, tickets) -> None:
        from ..core import api

        reqs = [t.request for t in tickets]
        circuit = reqs[0].circuit
        base = reqs[0].bitstring
        open_set = self._amp_open_set(key, reqs)
        pk = dict(reqs[0].plan_kwargs)
        if open_set is not None:
            # coalesce: the family's stabilized open window covers every
            # position where the group's bitstrings differ; ONE batch
            # contraction answers every tenant
            batch, report = api.open_amplitude_batch(
                circuit,
                open_qubits=open_set,
                base_bitstring=base,
                target_dim=reqs[0].target_dim,
                slice_batch=self.slice_batch,
                **pk,
            )
            flat = batch.flat()
            for t in tickets:
                idx = 0
                for q in open_set:  # MSB-first: bit j ↔ open_qubits[j]
                    idx = (idx << 1) | int(t.request.bitstring[q])
                t.value = complex(flat[idx])
                t.report = report
                t.batched = True
            with self._lock:
                self._stats["coalesced"] += len(tickets)
            _metrics.inc("serve.coalesced", len(tickets))
            return
        # singleton group / identical bitstrings / too spread to batch:
        # scalar contractions, deduped by bitstring (plan shared via cache)
        done: dict[str, object] = {}
        for t in tickets:
            bs = t.request.bitstring
            if bs not in done:
                done[bs] = api.simulate_amplitude(
                    circuit, bs,
                    target_dim=t.request.target_dim,
                    slice_batch=self.slice_batch,
                    **pk,
                )
            res = done[bs]
            t.value = complex(np.asarray(res.value))
            t.report = res.report
        if len(tickets) > len(done):  # duplicates shared a contraction
            for t in tickets:
                t.batched = True

    def _serve_samples(self, tickets) -> None:
        from ..core import api

        r0 = tickets[0].request
        # one contraction for the whole sub-group (same batch network by
        # family-key construction); per-tenant draws on the shared batch
        batch, report = api.open_amplitude_batch(
            r0.circuit,
            open_qubits=r0.open_qubits,
            base_bitstring=r0.base_bitstring,
            target_dim=r0.target_dim,
            slice_batch=self.slice_batch,
            **dict(r0.plan_kwargs),
        )
        for t in tickets:
            r = t.request
            res = api.draw_from_batch(
                batch, r.num_samples, sampler=r.sampler, seed=r.seed,
                report=report,
            )
            t.value = res
            t.report = report
            t.batched = len(tickets) > 1
        if len(tickets) > 1:
            with self._lock:
                self._stats["coalesced"] += len(tickets)
            _metrics.inc("serve.coalesced", len(tickets))

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Point-in-time serving counters (+ live queue depth and the
        number of warm families)."""
        with self._lock:
            out = dict(self._stats)
            out["queue_depth"] = len(self._queue)
            out["warm_families"] = len(self._warm)
            out["ewma_group_s"] = self._ewma_group_s or 0.0
        return out
