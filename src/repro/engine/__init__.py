"""Unified execution engine + contraction-as-a-service.

:mod:`repro.engine.session` is the single session layer every slice
driver executes through (``contract_all`` / ``contract_sharded`` /
``contract_resumable`` / ``contract_multihost`` are thin strategy
adapters over :class:`ContractionSession.run_slices`);
:mod:`repro.engine.server` is the multi-tenant continuous-batching
amplitude/sampling engine built on top of sessions.
"""

from .session import (
    ContractionSession,
    mask_invalid,
    padded_ids,
    record_execution,
)
from .server import (
    AmplitudeRequest,
    EngineServer,
    SampleRequest,
    ServerOverloaded,
    Ticket,
    circuit_fingerprint,
)

__all__ = [
    "ContractionSession",
    "mask_invalid",
    "padded_ids",
    "record_execution",
    "AmplitudeRequest",
    "EngineServer",
    "SampleRequest",
    "ServerOverloaded",
    "Ticket",
    "circuit_fingerprint",
]
