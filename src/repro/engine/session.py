"""Unified execution engine: one session layer under every slice driver.

Before this module existed the per-slice dispatch/hoist/mask/metrics
logic was quadruplicated across ``contract_all`` (vmapped scan),
``contract_sharded`` (shard_map + psum), ``contract_resumable``
(per-slice jit calls) and ``contract_multihost`` (scheduler-driven
ranges) — every new capability (telemetry, megakernel, precision) had to
be threaded through four paths.  A :class:`ContractionSession` is the
single owner of that logic: a compiled
:class:`~repro.core.executor.ContractionPlan` bound to concrete leaf
arrays, with the two-phase hoist mode resolved once and the hoisted
prologue materialized once (through the plan's HoistCache, so sessions
on the same plan + leaves share the buffers across calls *and* across
server tenants).

The primitive is :meth:`ContractionSession.run_slices`: one jitted
masked-vmap batch over explicit slice ids — the unit the multi-host
scheduler claims, the unit the serving engine dispatches, and the unit
the scan/shard_map strategies iterate.  Everything a strategy needs
beyond it is shared here exactly once:

  * :func:`mask_invalid` — the ragged-batch validity select
    (``jnp.where``, never a weight multiply: ``0 * NaN`` leaks),
  * :func:`padded_ids` — wrapped-around slice-id padding to a chunk
    multiple,
  * :func:`record_execution` — the executed/padded/FLOPs/chain-call
    work accounting,
  * jit memoization on the plan's ``_compiled`` dict (all sessions on a
    cached plan share traced programs),
  * per-step free schedules and fused-chain dispatch (via
    ``plan.contract_slice`` → ``_run_steps`` — already single-sited).

The four public drivers are thin strategy adapters over this class; the
serving layer (:mod:`repro.engine.server`) builds directly on it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics, trace as _trace


def mask_invalid(contrib: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Zero the padded lanes of a leading batch axis.

    ``valid`` is a boolean vector over ``contrib``'s leading axis.  The
    mask is a select, NOT a weight multiply: a NaN/Inf in a padded
    contribution would leak through ``0 * NaN == NaN`` (a legitimately
    overflowing slice would corrupt the whole sum), and a float32 weight
    multiply is dtype-lossy under x64."""
    return jnp.where(
        valid.reshape((-1,) + (1,) * (contrib.ndim - 1)),
        contrib,
        jnp.zeros((), contrib.dtype),
    )


def padded_ids(
    n_slices: int, multiple: int
) -> tuple[np.ndarray, np.ndarray, int]:
    """Slice ids padded (by wrap-around) to a multiple of ``multiple``.

    Returns ``(ids, valid, total)``: int32 ids of length ``total`` (the
    ceiling multiple), a boolean validity vector marking the real ids,
    and ``total`` itself.  Padding with *wrapped* ids keeps every lane a
    legal slice id (shape-stable indexing); the validity mask is what
    keeps the duplicates out of the sum."""
    total = -(-n_slices // multiple) * multiple
    ids = np.arange(total, dtype=np.int32) % n_slices
    valid = np.arange(total) < n_slices
    return ids, valid, total


def record_execution(plan, executed: int, padded: int, hoist: bool) -> None:
    """Work accounting shared by every strategy adapter.

    ``executed`` counts *real* slice ids summed into the amplitude;
    ``padded`` counts masked lanes (wrapped-around ids whose contribution
    a validity select zeroes out).  The two are disjoint by contract —
    inflating ``exec.slices_executed`` with padded lanes historically
    made multi-host FLOPs/chain accounting drift from the single-host
    scan's on the same plan.  Prologue FLOPs are counted where the
    prologue actually runs (``contract_prologue`` — a hoist-cache hit
    executes nothing), so only the per-slice epilogue cost lands here
    under hoisting."""
    _metrics.inc("exec.slices_executed", executed)
    if padded:
        _metrics.inc("exec.padded_slices", padded)
    if hoist:
        _metrics.inc(
            "exec.flops_executed", plan.partition.per_slice_cost * executed
        )
    else:
        _metrics.inc(
            "exec.flops_executed", plan.executed_flops(executed, hoist=False)
        )
    chains = plan._chain_dispatch.get("epilogue" if hoist else "naive")
    if chains:
        _metrics.inc("exec.chain_calls", len(chains) * executed)


class ContractionSession:
    """A compiled plan bound to leaf arrays, ready to execute slices.

    The session resolves the execution-time choices once — two-phase
    hoist mode (``hoist``, default ``REPRO_HOIST``, silently off when
    the plan has nothing to hoist) — and materializes the slice-invariant
    prologue lazily on first use, through the plan's leaf-keyed
    HoistCache so repeated sessions over the same leaves (sampler calls,
    serving tenants) skip it entirely.

    Strategies:

      * :meth:`run_slice` — one subtask, one jit call (the resumable
        driver's unit),
      * :meth:`run_slices` — THE primitive: one jitted masked-vmap batch
        over explicit ids (the multi-host scheduler's and the serving
        engine's unit),
      * :meth:`run_all` — all ``2^|S|`` subtasks as a scan of vmapped
        batches (single host),
      * :meth:`run_sharded` — slice ids sharded over a mesh via
        shard_map, one psum.

    All jitted programs are memoized on ``plan._compiled`` (keyed by
    strategy + hoist mode), so every session on a plan-cache hit reuses
    the traced executables; concurrent sessions converge on one program
    via ``setdefault``.
    """

    def __init__(self, plan, arrays, hoist: bool | None = None):
        from ..core.executor import default_hoist  # lazy: avoid cycle

        self.plan = plan
        self.arrays = list(arrays)
        h = default_hoist() if hoist is None else bool(hoist)
        self.hoist = bool(h and plan.can_hoist)
        self._hoisted: list | None = None

    # ------------------------------------------------------------------
    @property
    def n_slices(self) -> int:
        return 1 << self.plan.num_sliced

    def hoisted(self) -> list:
        """The materialized slice-invariant prologue buffers (``[]``
        when hoisting is off) — computed once per session, served from
        the plan's HoistCache across sessions on the same leaves."""
        if not self.hoist:
            return []
        if self._hoisted is None:
            self._hoisted = self.plan.contract_prologue(self.arrays)
        return self._hoisted

    def hoisted_replicated(self, mesh) -> list:
        """Prologue buffers device-put replicated over ``mesh`` (the
        form the shard_map strategy captures); cached per (leaves, mesh)
        in the same HoistCache entry as the host-side outputs."""
        if not self.hoist:
            return []
        return self.plan.contract_prologue_replicated(self.arrays, mesh)

    def out_struct(self):
        """``jax.ShapeDtypeStruct`` of one subtask's output (and of the
        final amplitude) — memoized on the plan: every session over one
        plan shares the same network shapes."""
        plan = self.plan
        key = ("out_struct",)
        s = plan._compiled.get(key)
        if s is None:
            s = plan._compiled.setdefault(
                key,
                jax.eval_shape(
                    lambda: plan.contract_slice(
                        list(self.arrays), jnp.int32(0)
                    )
                ),
            )
        return s

    def zeros(self) -> np.ndarray:
        """A host-side zero accumulator of the output shape/dtype."""
        s = self.out_struct()
        return np.zeros(s.shape, s.dtype)

    # ------------------------------------------------------------------
    # strategy: one subtask per jit call (resumable driver's unit)
    # ------------------------------------------------------------------
    def run_slice(self, slice_id) -> jnp.ndarray:
        """Contract one subtask as an independent jit call."""
        plan, hoist = self.plan, self.hoist
        ck = ("sess_slice", hoist)
        fn = plan._compiled.get(ck) or plan._compiled.setdefault(
            ck,
            jax.jit(
                lambda arrs, hbufs, sid: plan.contract_slice(
                    arrs, sid, hbufs if hoist else None
                )
            ),
        )
        return fn(list(self.arrays), list(self.hoisted()), jnp.int32(slice_id))

    # ------------------------------------------------------------------
    # THE primitive: one jitted masked-vmap batch over explicit ids
    # ------------------------------------------------------------------
    def run_slices(self, slice_ids, valid=None) -> jnp.ndarray:
        """Execute a batch of slice ids and return the masked partial sum.

        ``slice_ids`` may contain wrapped-around padding ids; ``valid``
        (default all-true) marks the lanes that contribute.  One jitted
        program serves every batch size (jit re-specializes per shape
        and caches internally); the masking select and the vmapped
        ``contract_slice`` dispatch — free schedules, fused chains,
        precision — are the single shared implementation."""
        plan, hoist = self.plan, self.hoist
        ck = ("sess_batch", hoist)
        fn = plan._compiled.get(ck)
        if fn is None:

            @jax.jit
            def fn(arrs, hbufs, ids_, valid_):
                contract = lambda sid: plan.contract_slice(  # noqa: E731
                    arrs, sid, hbufs if hoist else None
                )
                contrib = jax.vmap(contract)(ids_)
                return jnp.sum(mask_invalid(contrib, valid_), axis=0)

            fn = plan._compiled.setdefault(ck, fn)
        ids = np.asarray(slice_ids, dtype=np.int32)
        if valid is None:
            valid = np.ones(ids.shape, dtype=bool)
        return fn(
            list(self.arrays), list(self.hoisted()),
            jnp.asarray(ids), jnp.asarray(valid),
        )

    # ------------------------------------------------------------------
    # strategy: all slices, scan of vmapped batches (single host)
    # ------------------------------------------------------------------
    def run_all(self, slice_batch: int = 8) -> jnp.ndarray:
        """Sum over all ``2^|S|`` subtasks on one host.

        Subtasks run in vmapped batches of ``slice_batch`` accumulated
        with a ``lax.scan`` so peak memory is bounded; a ragged final
        batch is padded with wrapped-around slice ids masked by the
        validity select.  Within the jitted scan, buffer reclamation is
        driven by the memory plan's deterministic free schedule
        (``_run_steps`` drops each tracer at its planned last use, which
        is what lets XLA's allocator reuse the slot); jit-argument
        donation of the hoisted buffers would be a no-op here — donated
        inputs are only reclaimed via input→output aliasing and the
        scan's sole output is the small amplitude accumulator."""
        plan, hoist, arrays = self.plan, self.hoist, self.arrays
        n_slices = self.n_slices
        if plan.num_sliced == 0:
            key = ("dense",)
            # setdefault: concurrent serving threads race to publish, but
            # all end up calling the one surviving jitted fn (single trace)
            fn = plan._compiled.get(key) or plan._compiled.setdefault(
                key, jax.jit(lambda a: plan.contract_slice(a, 0))
            )
            with _trace.span(
                "exec.contract_all", cat="exec", slices=1, hoist=False
            ):
                out = fn(list(arrays))
                _trace.sync(out)
            _metrics.inc("exec.slices_executed", 1)
            _metrics.inc(
                "exec.flops_executed", plan.executed_flops(1, hoist=False)
            )
            return out
        slice_batch = max(1, min(slice_batch, n_slices))
        n_batches = -(-n_slices // slice_batch)
        flat_ids, flat_valid, total = padded_ids(n_slices, slice_batch)
        padded = total != n_slices
        key = ("all", slice_batch, hoist)
        fn = plan._compiled.get(key)
        if fn is None:
            ids = jnp.asarray(flat_ids).reshape(n_batches, slice_batch)
            w = jnp.asarray(flat_valid).reshape(n_batches, slice_batch)

            @jax.jit
            def run(arrs, hbufs):
                batched = jax.vmap(
                    lambda sid: plan.contract_slice(
                        arrs, sid, hbufs if hoist else None
                    )
                )

                def body(acc, chunk_w):
                    chunk, wk = chunk_w
                    contrib = batched(chunk)
                    if padded:
                        contrib = mask_invalid(contrib, wk)
                    return acc + jnp.sum(contrib, axis=0), None

                out_shape = jax.eval_shape(
                    lambda: jnp.sum(batched(ids[0]), axis=0)
                )
                acc0 = jnp.zeros(out_shape.shape, out_shape.dtype)
                acc, _ = jax.lax.scan(body, acc0, (ids, w))
                return acc

            fn = plan._compiled.setdefault(key, run)
        with _trace.span(
            "exec.contract_all",
            cat="exec",
            slices=n_slices,
            slice_batch=slice_batch,
            hoist=hoist,
            backend=plan.backend,
        ):
            out = fn(list(arrays), list(self.hoisted()))
            _trace.sync(out)
        record_execution(plan, n_slices, total - n_slices, hoist)
        return out

    # ------------------------------------------------------------------
    # strategy: slice ids sharded over a mesh (shard_map + one psum)
    # ------------------------------------------------------------------
    def run_sharded(
        self, mesh, axis_names: tuple[str, ...] = ("data",),
        slice_batch: int = 1,
    ) -> jnp.ndarray:
        """Contract all slices with slice-parallelism over ``axis_names``.

        Every device scans its chunk of slice ids and contributes to one
        psum; each scan step runs ``slice_batch`` subtasks under ``vmap``.
        Open-batch axes are replicated — only the slice axis is sharded —
        so the one psum returns the complete amplitude batch on every
        device.  The hoisted prologue enters the worker as a replicated
        capture, broadcast once per (leaves, mesh) via the HoistCache."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        plan, hoist = self.plan, self.hoist
        ndev = 1
        for ax in axis_names:
            ndev *= mesh.shape[ax]
        n_slices = self.n_slices
        slice_batch = max(1, min(slice_batch, n_slices))
        # Ragged-batch contract: padding to a multiple of ndev*slice_batch
        # is what guarantees every device's local id chunk reshapes exactly
        # into (n_batches, slice_batch) — no divisibility assumption.
        ids, valid, total = padded_ids(n_slices, ndev * slice_batch)

        # invariant prologue: once per process, outside the slice loop
        hoisted = self.hoisted_replicated(mesh) if hoist else []

        spec = P(axis_names)
        key = ("sharded", mesh, tuple(axis_names), slice_batch, hoist)
        fn = plan._compiled.get(key)
        cached = fn is not None
        if fn is None:

            @jax.jit
            def run(arrs, hbufs, ids_, valid_):
                def worker(ids_local, valid_local):
                    # arrs/hbufs are closure captures: replicated devices
                    contract = lambda sid: plan.contract_slice(  # noqa: E731
                        arrs, sid, hbufs if hoist else None
                    )
                    batched = jax.vmap(contract)
                    idb = ids_local.reshape(-1, slice_batch)
                    vb = valid_local.reshape(-1, slice_batch)

                    out_shape = jax.eval_shape(
                        lambda: contract(jnp.int32(0))
                    )

                    def body(acc, iv):
                        sids, ok = iv
                        contrib = mask_invalid(batched(sids), ok)
                        return acc + jnp.sum(contrib, axis=0), None

                    acc0 = jnp.zeros(out_shape.shape, out_shape.dtype)
                    acc, _ = jax.lax.scan(body, acc0, (idb, vb))
                    return jax.lax.psum(acc, axis_names)

                return shard_map(
                    worker,
                    mesh=mesh,
                    in_specs=(spec, spec),
                    out_specs=P(),
                    check_rep=False,
                )(ids_, valid_)

            # setdefault so concurrent threads converge on one program
            fn = plan._compiled.setdefault(key, run)
        with _trace.span(
            "exec.sharded", cat="exec", slices=n_slices, devices=ndev,
            hoist=hoist, cached=cached,
        ):
            out = fn(
                list(self.arrays), list(hoisted),
                jnp.asarray(ids), jnp.asarray(valid),
            )
            _trace.sync(out)
        record_execution(plan, n_slices, total - n_slices, hoist)
        return out
