"""Anytime path–slice–memory co-optimizer.

The paper's headline planner result (Fig. 8: slicing overhead below the
Cotengra baseline) comes from running the in-place slicer *inside* an
iterated path search — every candidate contraction tree is re-sliced on
the spot and judged by what would actually execute — not from a one-shot
pathfinder → slicer → refiner pipeline.  :func:`plan_search` is that
loop:

  * a pool of deterministic simulated-annealing workers mutates
    ``(tree, S)`` pairs with **subtree-reconfiguration** moves
    (:func:`repro.core.pathfinder.reconfigure_subtree`: cut a subtree at
    a small frontier, splice a freshly searched local order back) and
    **Boltzmann restarts** out of stalled basins;
  * after every tree move the slicer is re-invoked in place
    (:func:`repro.core.slicing.reslice`: warm-started from the previous
    mask, peak-refined via :func:`~repro.core.slicing.
    refine_slices_for_peak`);
  * candidates are scored by **hoist-aware executed FLOPs** — the
    two-phase accounting of :func:`repro.lowering.partition.
    partition_tree` (one prologue + ``2^|S|`` epilogues, the runtime
    counterpart of Eq. 4) — subject to the **certified live-set peak**
    (:func:`repro.lowering.memory.certified_peak`) fitting the byte
    budget;
  * the search is **anytime-monotone**: the global best-so-far is only
    ever replaced by a strictly better feasible candidate, so stopping
    at any evaluation/wall budget yields a valid plan no worse than the
    one-shot baseline it starts from.

Workers are cooperative (round-robin over one thread) with per-worker
seeded RNGs, so a run is bit-reproducible for a given
``(seed, num_workers)`` — crucial for the plan cache, which addresses a
search *result* by the network fingerprint plus the search parameters.
"""

from __future__ import annotations

import dataclasses
import math
import time

from ..core.contraction_tree import ContractionTree
from ..core.merging import merge_branches, orient_gemms
from ..core.pathfinder import (
    boltzmann_restart_tree,
    random_greedy_tree,
    reconfigure_subtree,
)
from ..core.slicing import (
    find_slices,
    peak_budget_for_width,
    refine_slices_for_peak,
    reslice,
)
from ..core.tensor_network import popcount
from ..core.tuning import tuning_slice_finder
from ..lowering.memory import certified_peak
from ..lowering.partition import partition_tree
from ..obs import metrics as _metrics, trace as _trace

OBJECTIVES = ("flops", "modeled_time")


# ----------------------------------------------------------------------
# the staged baseline (extracted from the API layer so the search can
# seed itself with — and therefore never do worse than — the one-shot
# pipeline)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class OneShot:
    """Result of the staged pathfinder → slicer → refiner pipeline."""

    tree: ContractionTree
    smask: int
    width_before: int  # width of the raw greedy tree, pre-tuning


def oneshot_plan(
    tn,
    target_dim: int,
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    seed: int = 0,
    slicing_mode: str = "width",
    itemsize: int = 8,
    budget_bytes: int | None = None,
    precision: str | None = None,
    fidelity_tol: float | None = None,
) -> OneShot:
    """The classic staged pipeline, each stage run exactly once:
    multi-restart greedy path, Alg.-2 tuning, branch merging, GEMM
    orientation, then slicing (optionally peak-refined).  This is both
    the default planner of :func:`repro.core.api.plan_contraction` and
    the baseline/seed of :func:`plan_search`.

    Under a mixed-precision mode (``precision`` ∈ {"bf16", "auto"}) with
    peak-mode slicing, the refined mask gets a second, *prune-only* pass
    at the same fp32-derived budget using the plan's per-node storage
    itemsizes: bf16-stored intermediates halve the certified peak, so
    the bf16 mask is always a subset of the fp32 one (|S| never larger).
    """
    tree = random_greedy_tree(tn, repeats=repeats, seed=seed)
    width0 = tree.width()
    if tune and method == "lifetime":
        res = tuning_slice_finder(tree, target_dim)
        tree, smask = res.tree, res.smask
    else:
        smask = find_slices(tree, target_dim, method=method, seed=seed)
    if merge:
        tree = merge_branches(tree, smask).tree
        smask = find_slices(tree, target_dim, method=method, seed=seed)
    tree = orient_gemms(tree)
    if slicing_mode == "peak" and smask:
        smask = refine_slices_for_peak(
            tree, smask, target_dim, itemsize=itemsize,
            budget_bytes=budget_bytes,
        )
        if smask and precision is not None and precision != "fp32":
            from ..lowering.precision import tree_storage_itemsizes

            iso = tree_storage_itemsizes(
                tree, smask, itemsize=itemsize, mode=precision,
                fidelity_tol=fidelity_tol,
            )
            if iso:
                fp32_budget = budget_bytes
                if fp32_budget is None:
                    fp32_budget = max(
                        peak_budget_for_width(target_dim, itemsize),
                        certified_peak(tree, smask, itemsize),
                    )
                smask = refine_slices_for_peak(
                    tree, smask, target_dim, itemsize=itemsize,
                    budget_bytes=fp32_budget, itemsize_of=iso,
                )
    elif slicing_mode not in ("width", "peak"):
        raise ValueError(f"unknown slicing_mode {slicing_mode!r}")
    return OneShot(tree, smask, width0)


def per_slice_cost_vector(tree: ContractionTree, smask: int):
    """Modeled FLOPs of each of the ``2^|S|`` slice subtasks — the cost
    vector that seeds the multi-host scheduler's LPT queues
    (:class:`repro.distributed.scheduler.SliceScheduler`).

    Under the paper's cost model every subtask fixes its sliced indices
    to one bit assignment of the *same* tree, so the modeled epilogue
    cost is identical across slice ids: the vector is uniform at
    :attr:`~repro.lowering.partition.TreePartition.per_slice_cost`
    (Eq. 6 dependent cost / ``2^|S|``).  Raggedness — the reason dynamic
    scheduling beats the paper's static split — enters from *outside*
    the model: measured per-slice walls from the telemetry calibrator
    (PR 7) or synthetic overlays in the scaling benchmark replace
    entries of this vector; the scheduler only requires that every host
    sees the same vector."""
    import numpy as np

    n_slices = 1 << popcount(smask)
    if smask == 0:
        return np.ones(1)
    part = partition_tree(tree, smask)
    return np.full(n_slices, float(part.per_slice_cost))


# ----------------------------------------------------------------------
# search state
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TracePoint:
    """One improvement of the global best-so-far.

    Anytime contract: ``objective`` is strictly decreasing along the
    trace *within a feasibility class* — best-so-far ordering is
    feasibility-first, so the single upgrade from an infeasible seed to
    the first feasible candidate (possible only under an explicit
    ``budget_bytes`` tighter than the seed's certified peak) may raise
    the objective once; with the default derived budget the seed is
    feasible and the trace is strictly decreasing throughout."""

    evaluation: int  # 1-based evaluation count when the best improved
    wall_s: float
    objective: float  # hoist-aware executed FLOPs (or modeled seconds)
    log2_objective: float
    num_sliced: int
    peak_bytes: int
    worker: int
    move: str  # "init" | "reconfigure" | "restart"


@dataclasses.dataclass
class SearchResult:
    """Best ``(tree, S)`` found plus the anytime search trace.

    ``objective``/``peak_bytes``/``feasible`` describe the *returned*
    tree — re-certified after the final GEMM orientation pass, so the
    budget guarantee holds for the object that will execute."""

    tree: ContractionTree
    smask: int
    objective: float
    peak_bytes: int
    budget_bytes: int
    feasible: bool  # certified peak fits the budget
    evaluations: int
    wall_s: float
    trace: list[TracePoint]
    baseline_objective: float | None  # one-shot seed (init="oneshot")
    num_workers: int
    seed: int
    objective_kind: str
    width_before: int = 0  # width of the raw greedy seed tree, pre-search

    @property
    def num_sliced(self) -> int:
        return popcount(self.smask)

    @property
    def improvement(self) -> float:
        """baseline / best executed cost (>= 1.0 when seeded one-shot)."""
        if not self.baseline_objective:
            return 1.0
        return self.baseline_objective / self.objective

    def summary(self) -> dict:
        return {
            "objective": self.objective,
            "log2_objective": math.log2(self.objective),
            "num_sliced": self.num_sliced,
            "peak_bytes": self.peak_bytes,
            "budget_bytes": self.budget_bytes,
            "feasible": self.feasible,
            "evaluations": self.evaluations,
            "wall_s": self.wall_s,
            "improvement": self.improvement,
            "trace_points": len(self.trace),
            "num_workers": self.num_workers,
            "seed": self.seed,
        }


@dataclasses.dataclass
class _Worker:
    rng: object  # random.Random
    tree: ContractionTree
    smask: int
    log2_obj: float
    steps: int = 0
    stall: int = 0


@dataclasses.dataclass
class _Eval:
    smask: int
    objective: float
    peak_bytes: int
    feasible: bool


# ----------------------------------------------------------------------
# the co-optimizer
# ----------------------------------------------------------------------
def plan_search(
    tn,
    target_dim: int,
    *,
    budget_bytes: int | None = None,
    itemsize: int = 8,
    num_workers: int = 4,
    max_evals: int = 64,
    wall_clock_s: float | None = None,
    seed: int = 0,
    objective: str = "flops",
    dtype=None,
    init: str = "oneshot",
    method: str = "lifetime",
    tune: bool = True,
    merge: bool = True,
    repeats: int = 8,
    slicing_mode: str = "peak",
    max_roots: int = 8,
    stall_limit: int = 6,
    temperature: float = 1.0,
    cooling: float = 0.95,
    precision: str | None = None,
    fidelity_tol: float | None = None,
) -> SearchResult:
    """Anytime co-optimization of ``(tree, S)`` under a certified peak
    budget.

    ``max_evals`` bounds candidate evaluations (each slicer+partition
    scoring pass counts one, including worker seeds) and is the
    deterministic budget; ``wall_clock_s`` additionally stops the loop
    on elapsed time.  ``budget_bytes=None`` derives the budget from the
    seed candidate: ``max(peak_budget_for_width(target_dim),
    certified_peak(seed))`` — the same certified-peak envelope the
    one-shot pipeline already needs, so the comparison between the two
    is at equal memory.

    ``objective="flops"`` scores hoist-aware executed FLOPs
    (prologue + ``2^|S|`` epilogues, Eq. 4's runtime counterpart);
    ``"modeled_time"`` scores the refiner's modeled two-phase seconds
    (:func:`repro.lowering.refiner.modeled_plan_time`) — slower per
    evaluation, kernel-shape aware.

    ``init="oneshot"`` (the default, also what the benchmarks compare
    with) seeds worker 0 with the staged pipeline's result, which with
    the anytime-monotone contract guarantees the search never returns a
    worse plan than the one-shot baseline at the default budget;
    ``init="greedy"`` seeds every worker with a fresh Boltzmann-greedy
    tree — an ablation mode measuring what the search finds *without*
    the one-shot seed (no ≥-baseline guarantee).
    """
    import random as _random

    if objective not in OBJECTIVES:
        raise ValueError(f"objective {objective!r} not in {OBJECTIVES}")
    if init not in ("oneshot", "greedy"):
        raise ValueError(f"init {init!r} not in ('oneshot', 'greedy')")
    if num_workers < 1:
        raise ValueError("num_workers must be >= 1")
    if max_evals < 1:
        raise ValueError("max_evals must be >= 1")
    t0 = time.perf_counter()

    if objective == "modeled_time":
        import jax.numpy as jnp

        from ..lowering.refiner import modeled_plan_time

        obj_dtype = jnp.dtype(dtype) if dtype is not None else jnp.complex64

    def score(tree: ContractionTree, smask: int, part) -> float:
        if objective == "flops":
            return part.hoisted_cost() if part else tree.total_cost()
        return modeled_plan_time(
            tree, smask, dtype=obj_dtype, part=part,
            precision=precision or "fp32", fidelity_tol=fidelity_tol,
        )

    budget = budget_bytes  # resolved after the first seed evaluation
    evals = 0

    def evaluate(tree: ContractionTree, smask: int) -> _Eval:
        """Score one candidate; re-invokes the peak slicer in place when
        the mask overshoots the budget (top-up), never mutates ``tree``."""
        nonlocal evals
        evals += 1
        with _trace.span("search.eval", cat="search", evaluation=evals):
            part = partition_tree(tree, smask) if smask else None
            peak = certified_peak(tree, smask, itemsize, part=part)
            if budget is not None and peak > budget:
                refined = refine_slices_for_peak(
                    tree, smask, target_dim, itemsize=itemsize,
                    budget_bytes=budget,
                )
                if refined != smask:
                    smask = refined
                    part = partition_tree(tree, smask) if smask else None
                    peak = certified_peak(tree, smask, itemsize, part=part)
            feasible = budget is None or peak <= budget
            res = _Eval(smask, score(tree, smask, part), peak, feasible)
        _metrics.inc("search.evals")
        return res

    # ------------------------------------------------------------------
    # seed the workers
    # ------------------------------------------------------------------
    workers: list[_Worker] = []
    best_tree: ContractionTree | None = None
    best: _Eval | None = None
    baseline_objective: float | None = None
    width_before = 0
    trace: list[TracePoint] = []

    def consider(tree: ContractionTree, ev: _Eval, w: int, move: str) -> None:
        """The anytime-monotone contract: the global best only ever
        moves to a strictly better feasible candidate."""
        nonlocal best, best_tree
        better = best is None or (
            (ev.feasible and not best.feasible)
            or (ev.feasible == best.feasible and ev.objective < best.objective)
        )
        if better:
            best = ev
            best_tree = tree.copy()
            trace.append(
                TracePoint(
                    evaluation=evals,
                    wall_s=time.perf_counter() - t0,
                    objective=ev.objective,
                    log2_objective=math.log2(ev.objective),
                    num_sliced=popcount(ev.smask),
                    peak_bytes=ev.peak_bytes,
                    worker=w,
                    move=move,
                )
            )

    for w in range(num_workers):
        if evals >= max_evals and workers:
            break
        rng = _random.Random(seed * 1_000_003 + w)
        if w == 0 and init == "oneshot":
            shot = oneshot_plan(
                tn, target_dim, method=method, tune=tune, merge=merge,
                repeats=repeats, seed=seed, slicing_mode=slicing_mode,
                itemsize=itemsize, budget_bytes=budget_bytes,
                precision=precision, fidelity_tol=fidelity_tol,
            )
            tree, warm = shot.tree, shot.smask
            width_before = shot.width_before
        else:
            tree = boltzmann_restart_tree(tn, rng)
            warm = best.smask if best is not None else 0
            if not workers:
                width_before = tree.width()
        if budget is None and not workers:
            # the seed's certified envelope fixes the budget for the
            # whole run (equal-memory comparison vs the staged pipeline)
            seed_mask = (
                warm
                if init == "oneshot"
                else reslice(tree, target_dim, warm=warm, mode="width")
            )
            budget = max(
                peak_budget_for_width(target_dim, itemsize),
                certified_peak(tree, seed_mask, itemsize),
            )
            warm = seed_mask  # the full reslice below warm-starts here
        smask = reslice(
            tree, target_dim, warm=warm, mode=slicing_mode,
            itemsize=itemsize, budget_bytes=budget,
        )
        ev = evaluate(tree, smask)
        if w == 0 and init == "oneshot" and ev.feasible:
            # an infeasible seed (explicit budget tighter than its
            # certified peak) is no baseline: the "never worse than
            # one-shot" guarantee only makes sense at equal budget
            baseline_objective = ev.objective
        workers.append(
            _Worker(rng, tree, ev.smask, math.log2(ev.objective))
        )
        consider(tree, ev, w, "init")

    # ------------------------------------------------------------------
    # the anytime loop
    # ------------------------------------------------------------------
    while evals < max_evals:
        if wall_clock_s is not None and time.perf_counter() - t0 >= (
            wall_clock_s
        ):
            break
        w = evals % len(workers)
        worker = workers[w]
        rng = worker.rng
        temp = temperature * (cooling ** worker.steps)
        worker.steps += 1
        if worker.stall >= stall_limit:
            # Boltzmann restart out of the stalled basin
            tree = boltzmann_restart_tree(tn, rng)
            smask = reslice(
                tree, target_dim, warm=worker.smask, mode=slicing_mode,
                itemsize=itemsize, budget_bytes=budget,
            )
            ev = evaluate(tree, smask)
            worker.tree = tree
            worker.smask = ev.smask
            worker.log2_obj = math.log2(ev.objective)
            worker.stall = 0
            _metrics.inc("search.restarts")
            consider(tree, ev, w, "restart")
            continue
        res = reconfigure_subtree(
            worker.tree, rng, max_roots=max_roots,
            temperature=0.1 + 0.5 * rng.random(),
        )
        if res is None:
            worker.stall += 1
            continue
        # tight inner loop: the local move leaves the warm mask
        # near-optimal, so skip reslice's fresh slice_finder comparison
        # (seeds and restarts, whose trees are far from the warm mask,
        # keep the default compare)
        smask = reslice(
            worker.tree, target_dim, warm=worker.smask, mode=slicing_mode,
            itemsize=itemsize, budget_bytes=budget, compare_fresh=False,
        )
        ev = evaluate(worker.tree, smask)
        dlog = math.log2(ev.objective) - worker.log2_obj
        accept = ev.feasible and (
            dlog < 0.0
            or rng.random() < math.exp(-dlog / max(temp, 1e-3))
        )
        if accept:
            worker.smask = ev.smask
            worker.log2_obj = math.log2(ev.objective)
            worker.stall = 0 if dlog < 0.0 else worker.stall + 1
            _metrics.inc("search.accepted")
            consider(worker.tree, ev, w, "reconfigure")
        else:
            worker.tree.unsplice(res)
            worker.stall += 1
            _metrics.inc("search.rejected")

    assert best is not None and best_tree is not None
    # GEMM orientation swaps children, which changes the post-order
    # execution schedule and therefore step lifetimes: re-certify the
    # oriented tree so the returned peak/feasibility describe the object
    # that will execute, and keep the unoriented (certified) tree when
    # orientation would break a tight budget.
    oriented = orient_gemms(best_tree)
    part = partition_tree(oriented, best.smask) if best.smask else None
    peak = certified_peak(oriented, best.smask, itemsize, part=part)
    if budget is None or peak <= budget or peak <= best.peak_bytes:
        best_tree = oriented
        best = _Eval(
            best.smask,
            score(oriented, best.smask, part),
            peak,
            budget is None or peak <= budget,
        )
    return SearchResult(
        tree=best_tree,
        smask=best.smask,
        objective=best.objective,
        peak_bytes=best.peak_bytes,
        budget_bytes=int(budget) if budget is not None else 0,
        feasible=best.feasible,
        evaluations=evals,
        wall_s=time.perf_counter() - t0,
        trace=trace,
        baseline_objective=baseline_objective,
        num_workers=num_workers,
        seed=seed,
        objective_kind=objective,
        width_before=width_before,
    )
