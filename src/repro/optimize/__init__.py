"""Anytime path–slice–memory co-optimization (Sec. IV run *inside* the
path search).

The staged pipeline (pathfinder → slicer → refiner) plans each stage
once; :func:`plan_search` instead runs the paper's in-place slicer and
the lifetime machinery **inside** an iterated tree search, scoring every
``(tree, S)`` candidate by hoist-aware executed FLOPs under a certified
live-set peak budget.  See :mod:`repro.optimize.search`.
"""

from .search import (
    OneShot,
    SearchResult,
    TracePoint,
    oneshot_plan,
    plan_search,
)

__all__ = [
    "OneShot",
    "SearchResult",
    "TracePoint",
    "oneshot_plan",
    "plan_search",
]
