"""Losses — chunked vocabulary cross-entropy.

The full logits tensor (B·S·V) for the fleet's 100k+ vocabs at 4k sequence
would be hundreds of GB; we scan over sequence chunks, computing each
chunk's logits + logsumexp under remat so the backward pass recomputes
them (the lifetime of the logits tensor is exactly one chunk step — the
same lifetime argument the paper makes for slicing overhead)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

F32 = jnp.float32


def chunked_cross_entropy(
    hidden: jax.Array,  # (B, S, D)
    head_w: jax.Array,  # (D, V)
    labels: jax.Array,  # (B, S) int32
    chunk: int = 512,
) -> jax.Array:
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    hs = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(h_c, l_c):
        logits = jnp.einsum("bsd,dv->bsv", h_c, head_w).astype(F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, l_c[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        return jnp.sum(lse - gold)

    def body(acc, xs):
        h_c, l_c = xs
        return acc + chunk_loss(h_c, l_c), None

    total, _ = jax.lax.scan(body, jnp.zeros((), F32), (hs, ls))
    return total / (B * S)
