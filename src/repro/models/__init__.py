"""Architecture fleet — model factory."""

from __future__ import annotations

from ..configs.base import ArchConfig
from .encdec import EncDecLM
from .hybrid import ZambaLM
from .lm import DecoderLM
from .ssm_model import MambaLM


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense", "moe"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return ZambaLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


__all__ = ["build_model", "DecoderLM", "MambaLM", "ZambaLM", "EncDecLM"]
