"""Mamba-2 (SSD) language model — attention-free, O(1)-state decode."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.sharding import ParamDef
from . import layers as L

F32 = jnp.float32


def mamba_defs(cfg: ArchConfig, n: int) -> dict:
    """Split projections (no slicing of a tp-sharded fused axis — §Perf
    iteration 2) with the head axis tp-sharded end-to-end."""
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    nheads = d_inner // cfg.ssm_head_dim
    N2 = 2 * cfg.ssm_state
    return {
        "w_z": ParamDef((n, D, d_inner), (None, "fsdp", "tp")),
        "w_x": ParamDef((n, D, d_inner), (None, "fsdp", "tp")),
        "w_bc": ParamDef((n, D, N2), (None, "fsdp", "tp")),
        "w_dt": ParamDef((n, D, nheads), (None, "fsdp", "tp")),
        "conv_x": ParamDef((n, d_inner, cfg.ssm_conv), (None, "tp", None),
                           scale=0.5),
        "conv_bc": ParamDef((n, N2, cfg.ssm_conv), (None, "tp", None),
                            scale=0.5),
        "dt_bias": ParamDef((n, nheads), (None, "tp"), init="zeros"),
        "a_log": ParamDef((n, nheads), (None, "tp"), init="zeros"),
        "norm": ParamDef((n, d_inner), (None, "tp"), init="ones"),
        "w_out": ParamDef((n, d_inner, D), (None, "tp", "fsdp")),
        "ln": ParamDef((n, D), (None, None), init="ones"),
    }


class MambaLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def param_defs(self):
        cfg = self.cfg
        defs: dict[str, Any] = {
            "embed": ParamDef(
                (cfg.vocab_size, cfg.d_model), ("tp", "fsdp"), scale=0.02
            ),
            "final_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
            "layers": mamba_defs(cfg, cfg.num_layers),
        }
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef(
                (cfg.d_model, cfg.vocab_size), ("fsdp", "tp"), scale=0.02
            )
        return defs

    def _mix(self, lp, h, ssm_state=None, conv_state=None):
        cfg = self.cfg
        x = L.rms_norm(h, lp["ln"], cfg.norm_eps)
        y, (s2, c2) = L.mamba2_mix(
            x,
            lp,
            d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand,
            ssm_state=ssm_state,
            conv_state=conv_state,
        )
        return h + y, s2, c2

    def hidden_states(self, params, batch):
        h = params["embed"][batch["tokens"]]

        def body(hh, lp):
            hh, _, _ = self._mix(lp, hh)
            return hh, None

        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
        h, _ = jax.lax.scan(body, h, params["layers"])
        return L.rms_norm(h, params["final_norm"], self.cfg.norm_eps), jnp.zeros((), F32)

    def head_weights(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def loss(self, params, batch):
        from .losses import chunked_cross_entropy

        h, aux = self.hidden_states(params, batch)
        loss = chunked_cross_entropy(h, self.head_weights(params), batch["labels"])
        return loss, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------- serve
    def cache_spec(self, batch_size: int, max_len: int):
        cfg = self.cfg
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        n = cfg.num_layers
        return {
            "ssm": (
                jax.ShapeDtypeStruct(
                    (n, batch_size, nheads, cfg.ssm_state, cfg.ssm_head_dim),
                    F32,
                ),
                ("layer", "dp", "tp", None, None),
            ),
            "conv_x": (
                jax.ShapeDtypeStruct(
                    (n, batch_size, cfg.ssm_conv - 1, d_inner), jnp.bfloat16
                ),
                ("layer", "dp", None, "tp"),
            ),
            "conv_bc": (
                jax.ShapeDtypeStruct(
                    (n, batch_size, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                    jnp.bfloat16,
                ),
                ("layer", "dp", None, "tp"),
            ),
        }

    def init_cache(self, batch_size: int, max_len: int):
        return jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype),
            self.cache_spec(batch_size, max_len),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
        )

    def decode_step(self, params, cache, tokens, pos, mrope_positions=None):
        h = params["embed"][tokens]  # (B, 1, D)

        def body(hh, xs):
            lp, s, cx, cbc = xs
            hh, s2, (cx2, cbc2) = self._mix(
                lp, hh, ssm_state=s, conv_state=(cx, cbc)
            )
            return hh, (s2, cx2.astype(jnp.bfloat16),
                        cbc2.astype(jnp.bfloat16))

        h, (s_new, cx_new, cbc_new) = jax.lax.scan(
            body, h,
            (params["layers"], cache["ssm"], cache["conv_x"],
             cache["conv_bc"]),
        )
        h = L.rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, 0], self.head_weights(params))
        return logits.astype(F32), {
            "ssm": s_new, "conv_x": cx_new, "conv_bc": cbc_new,
        }

    def prefill(self, params, batch, max_len: int | None = None):
        h = params["embed"][batch["tokens"]]

        def body(hh, lp):
            hh, s2, (cx2, cbc2) = self._mix(lp, hh)
            return hh, (s2, cx2.astype(jnp.bfloat16),
                        cbc2.astype(jnp.bfloat16))

        h, (s_new, cx_new, cbc_new) = jax.lax.scan(body, h, params["layers"])
        h = L.rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self.head_weights(params))
        return {
            "ssm": s_new, "conv_x": cx_new, "conv_bc": cbc_new,
        }, logits.astype(F32)
