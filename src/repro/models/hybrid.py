"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The backbone is ``num_layers`` mamba2 mixers; after every ``attn_every``
mixers, a single shared transformer block (one weight set, zamba's
signature parameter-sharing trick) is applied — each application has its
own KV cache slot.  Sliding-window attention (``cfg.window``) keeps the
500k-context decode sub-quadratic: the cache is a ring buffer of
``window`` slots.

Layer layout for 81 layers / attn_every 6:
  13 groups of (6 mamba + shared attn)  +  3 tail mamba layers.
Groups are scanned (group params stacked on a leading 13 axis, inner
mini-scan over the 6) so HLO stays O(1) in depth.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.sharding import ParamDef
from . import layers as L
from .ssm_model import mamba_defs

F32 = jnp.float32


class ZambaLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.attn_every > 0
        self.n_groups = cfg.num_layers // cfg.attn_every
        self.n_tail = cfg.num_layers - self.n_groups * cfg.attn_every

    # -------------------------------------------------------------- params
    def param_defs(self):
        cfg = self.cfg
        D, hd = cfg.d_model, cfg.resolved_head_dim
        H, KV = cfg.num_heads, cfg.num_kv_heads
        defs: dict[str, Any] = {
            "embed": ParamDef((cfg.vocab_size, D), ("tp", "fsdp"), scale=0.02),
            "final_norm": ParamDef((D,), (None,), init="ones"),
            "head": ParamDef((D, cfg.vocab_size), ("fsdp", "tp"), scale=0.02),
            "groups": _stack_defs(
                mamba_defs(cfg, cfg.attn_every), self.n_groups
            ),
            # one shared transformer block (attn + mlp), applied 13×
            "shared": {
                "wq": ParamDef((D, H, hd), ("fsdp", "tp", None)),
                "wk": ParamDef((D, KV, hd), ("fsdp", "tp", None)),
                "wv": ParamDef((D, KV, hd), ("fsdp", "tp", None)),
                "wo": ParamDef((H, hd, D), ("tp", None, "fsdp")),
                "ln_attn": ParamDef((D,), (None,), init="ones"),
                "w_gate": ParamDef((D, cfg.d_ff), ("fsdp", "tp")),
                "w_up": ParamDef((D, cfg.d_ff), ("fsdp", "tp")),
                "w_down": ParamDef((cfg.d_ff, D), ("tp", "fsdp")),
                "ln_mlp": ParamDef((D,), (None,), init="ones"),
            },
        }
        if self.n_tail:
            defs["tail"] = mamba_defs(cfg, self.n_tail)
        return defs

    # ------------------------------------------------------------- blocks
    def _mamba(self, lp, h, ssm_state=None, conv_state=None):
        cfg = self.cfg
        x = L.rms_norm(h, lp["ln"], cfg.norm_eps)
        y, (s2, c2) = L.mamba2_mix(
            x, lp,
            d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand,
            ssm_state=ssm_state,
            conv_state=conv_state,
        )
        return h + y, s2, c2

    def _shared_attn(self, sp, h, positions, kv_cache=None, pos=None):
        cfg = self.cfg
        x = L.rms_norm(h, sp["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, sp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, sp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, sp["wv"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if kv_cache is None:
            o = L.blockwise_attention(q, k, v, causal=True, window=cfg.window)
            new_cache = (k, v)
        else:
            kc, vc = kv_cache
            eff = kc.shape[1]
            slot = pos % eff
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, 1)
            o = L.decode_attention(q, kc, vc, jnp.minimum(pos + 1, eff))
            new_cache = (kc, vc)
        h = h + jnp.einsum("bshk,hkd->bsd", o.astype(h.dtype), sp["wo"])
        x = L.rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
        h = h + L.swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])
        return h, new_cache

    # ------------------------------------------------------------ forward
    def hidden_states(self, params, batch):
        cfg = self.cfg
        h = params["embed"][batch["tokens"]]
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        shared = params["shared"]

        def group_body(hh, gp):
            def inner(hh2, lp):
                hh2, _, _ = self._mamba(lp, hh2)
                return hh2, None

            hh, _ = jax.lax.scan(inner, hh, gp)
            hh, _ = self._shared_attn(shared, hh, positions)
            return hh, None

        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
        h, _ = jax.lax.scan(group_body, h, params["groups"])
        if self.n_tail:
            def tail_body(hh, lp):
                hh, _, _ = self._mamba(lp, hh)
                return hh, None

            h, _ = jax.lax.scan(tail_body, h, params["tail"])
        return L.rms_norm(h, params["final_norm"], cfg.norm_eps), jnp.zeros(
            (), F32
        )

    def head_weights(self, params):
        return params["head"]

    def loss(self, params, batch):
        from .losses import chunked_cross_entropy

        h, aux = self.hidden_states(params, batch)
        loss = chunked_cross_entropy(h, params["head"], batch["labels"])
        return loss, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------- serve
    def cache_spec(self, batch_size: int, max_len: int):
        cfg = self.cfg
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        conv_dim = d_inner + 2 * cfg.ssm_state
        eff = min(cfg.window, max_len) if cfg.window else max_len
        hd = cfg.resolved_head_dim
        ng, ae = self.n_groups, cfg.attn_every
        spec = {
            "ssm": (
                jax.ShapeDtypeStruct(
                    (ng, ae, batch_size, nheads, cfg.ssm_state,
                     cfg.ssm_head_dim), F32,
                ),
                ("layer", None, "dp", "tp", None, None),
            ),
            "conv_x": (
                jax.ShapeDtypeStruct(
                    (ng, ae, batch_size, cfg.ssm_conv - 1, d_inner),
                    jnp.bfloat16,
                ),
                ("layer", None, "dp", None, "tp"),
            ),
            "conv_bc": (
                jax.ShapeDtypeStruct(
                    (ng, ae, batch_size, cfg.ssm_conv - 1,
                     2 * cfg.ssm_state),
                    jnp.bfloat16,
                ),
                ("layer", None, "dp", None, "tp"),
            ),
            "attn_k": (
                jax.ShapeDtypeStruct(
                    (ng, batch_size, eff, cfg.num_kv_heads, hd), jnp.bfloat16
                ),
                ("layer", "dp", "sp", None, None),
            ),
            "attn_v": (
                jax.ShapeDtypeStruct(
                    (ng, batch_size, eff, cfg.num_kv_heads, hd), jnp.bfloat16
                ),
                ("layer", "dp", "sp", None, None),
            ),
        }
        if self.n_tail:
            spec["tail_ssm"] = (
                jax.ShapeDtypeStruct(
                    (self.n_tail, batch_size, nheads, cfg.ssm_state,
                     cfg.ssm_head_dim), F32,
                ),
                ("layer", "dp", "tp", None, None),
            )
            spec["tail_conv_x"] = (
                jax.ShapeDtypeStruct(
                    (self.n_tail, batch_size, cfg.ssm_conv - 1, d_inner),
                    jnp.bfloat16,
                ),
                ("layer", "dp", None, "tp"),
            )
            spec["tail_conv_bc"] = (
                jax.ShapeDtypeStruct(
                    (self.n_tail, batch_size, cfg.ssm_conv - 1,
                     2 * cfg.ssm_state),
                    jnp.bfloat16,
                ),
                ("layer", "dp", None, "tp"),
            )
        return spec

    def init_cache(self, batch_size: int, max_len: int):
        return jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype),
            self.cache_spec(batch_size, max_len),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
        )

    def decode_step(self, params, cache, tokens, pos, mrope_positions=None):
        cfg = self.cfg
        h = params["embed"][tokens]
        B = tokens.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        shared = params["shared"]

        def group_body(hh, xs):
            gp, s, cx, cbc, kc, vc = xs

            def inner(hh2, xs2):
                lp, s_i, cx_i, cbc_i = xs2
                hh2, s2, (cx2, cbc2) = self._mamba(
                    lp, hh2, s_i, (cx_i, cbc_i)
                )
                return hh2, (s2, cx2.astype(jnp.bfloat16),
                             cbc2.astype(jnp.bfloat16))

            hh, (s_new, cx_new, cbc_new) = jax.lax.scan(
                inner, hh, (gp, s, cx, cbc)
            )
            hh, (kc2, vc2) = self._shared_attn(
                shared, hh, positions, kv_cache=(kc, vc), pos=pos
            )
            return hh, (s_new, cx_new, cbc_new, kc2, vc2)

        h, (s_new, cx_new, cbc_new, kc_new, vc_new) = jax.lax.scan(
            group_body,
            h,
            (
                params["groups"],
                cache["ssm"],
                cache["conv_x"],
                cache["conv_bc"],
                cache["attn_k"],
                cache["attn_v"],
            ),
        )
        new_cache = dict(
            cache, ssm=s_new, conv_x=cx_new, conv_bc=cbc_new,
            attn_k=kc_new, attn_v=vc_new,
        )
        if self.n_tail:
            def tail_body(hh, xs):
                lp, s, cx, cbc = xs
                hh, s2, (cx2, cbc2) = self._mamba(lp, hh, s, (cx, cbc))
                return hh, (s2, cx2.astype(jnp.bfloat16),
                            cbc2.astype(jnp.bfloat16))

            h, (ts, tcx, tcbc) = jax.lax.scan(
                tail_body, h,
                (params["tail"], cache["tail_ssm"], cache["tail_conv_x"],
                 cache["tail_conv_bc"]),
            )
            new_cache["tail_ssm"] = ts
            new_cache["tail_conv_x"] = tcx
            new_cache["tail_conv_bc"] = tcbc
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, 0], params["head"])
        return logits.astype(F32), new_cache

    def prefill(self, params, batch, max_len: int | None = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        eff = min(cfg.window, max_len) if cfg.window else max_len
        h = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        shared = params["shared"]

        def fit(k):
            k = k[:, -eff:]
            pad = eff - k.shape[1]
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
            return k.astype(jnp.bfloat16)

        def group_body(hh, gp):
            def inner(hh2, lp):
                hh2, s2, (cx2, cbc2) = self._mamba(lp, hh2)
                return hh2, (s2, cx2.astype(jnp.bfloat16),
                             cbc2.astype(jnp.bfloat16))

            hh, (s_new, cx_new, cbc_new) = jax.lax.scan(inner, hh, gp)
            hh, (k, v) = self._shared_attn(shared, hh, positions)
            return hh, (s_new, cx_new, cbc_new, fit(k), fit(v))

        h, (s_new, cx_new, cbc_new, ks, vs) = jax.lax.scan(
            group_body, h, params["groups"]
        )
        cache = {
            "ssm": s_new,
            "conv_x": cx_new,
            "conv_bc": cbc_new,
            "attn_k": ks,
            "attn_v": vs,
        }
        if self.n_tail:
            def tail_body(hh, lp):
                hh, s2, (cx2, cbc2) = self._mamba(lp, hh)
                return hh, (s2, cx2.astype(jnp.bfloat16),
                            cbc2.astype(jnp.bfloat16))

            h, (ts, tcx, tcbc) = jax.lax.scan(tail_body, h, params["tail"])
            cache["tail_ssm"] = ts
            cache["tail_conv_x"] = tcx
            cache["tail_conv_bc"] = tcbc
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"])
        return cache, logits.astype(F32)


def _stack_defs(defs: dict, n: int) -> dict:
    """Add a leading stacking axis of size n to every ParamDef in a dict."""
    out = {}
    for k, d in defs.items():
        out[k] = ParamDef(
            (n,) + d.shape,
            (None,) + d.logical,
            init=d.init,
            scale=d.scale,
            dtype=d.dtype,
        )
    return out
