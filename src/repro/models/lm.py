"""Generic decoder-only LM covering the dense / MoE / VLM families.

Layer stacks are ``lax.scan``-ed over stacked parameters (compile time and
HLO size are O(1) in depth — required for the 126-layer dry-run) with a
rematerialization policy on the layer body.  MoE models split the stack
into a dense prefix (``first_k_dense``) and a scanned MoE remainder.

Batch conventions:
  train:   {"tokens" (B,S) | "embeds" (B,S,D), "labels" (B,S),
            ["positions" (3,B,S) for M-RoPE]}
  prefill: {"tokens" | "embeds"} → (cache, last-position logits)
  decode:  (cache, tokens (B,1), pos ()) → (logits (B,V), cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..parallel.sharding import ParamDef
from . import layers as L

F32 = jnp.float32


def _attn_defs(cfg: ArchConfig, n: int) -> dict:
    D, H, KV, hd = (
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.resolved_head_dim,
    )
    d = {
        "wq": ParamDef((n, D, H, hd), (None, "fsdp", "tp", None)),
        "wk": ParamDef((n, D, KV, hd), (None, "fsdp", "tp", None)),
        "wv": ParamDef((n, D, KV, hd), (None, "fsdp", "tp", None)),
        "wo": ParamDef((n, H, hd, D), (None, "tp", None, "fsdp")),
        "ln_attn": ParamDef((n, D), (None, None), init="ones"),
    }
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((n, hd), (None, None), init="ones")
        d["k_norm"] = ParamDef((n, hd), (None, None), init="ones")
    return d


def _mlp_defs(cfg: ArchConfig, n: int, d_ff: int) -> dict:
    D = cfg.d_model
    return {
        "w_gate": ParamDef((n, D, d_ff), (None, "fsdp", "tp")),
        "w_up": ParamDef((n, D, d_ff), (None, "fsdp", "tp")),
        "w_down": ParamDef((n, d_ff, D), (None, "tp", "fsdp")),
        "ln_mlp": ParamDef((n, D), (None, None), init="ones"),
    }


def _moe_defs(cfg: ArchConfig, n: int) -> dict:
    D, E, Fm = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    d = {
        "router": ParamDef((n, D, E), (None, "fsdp", None), scale=0.02),
        "e_gate": ParamDef((n, E, D, Fm), (None, "ep", "fsdp", None)),
        "e_up": ParamDef((n, E, D, Fm), (None, "ep", "fsdp", None)),
        "e_down": ParamDef((n, E, Fm, D), (None, "ep", None, "fsdp")),
        "ln_mlp": ParamDef((n, D), (None, None), init="ones"),
    }
    if cfg.num_shared_experts:
        Fs = (cfg.moe_d_ff or cfg.d_ff) * cfg.num_shared_experts
        d["s_gate"] = ParamDef((n, D, Fs), (None, "fsdp", "tp"))
        d["s_up"] = ParamDef((n, D, Fs), (None, "fsdp", "tp"))
        d["s_down"] = ParamDef((n, Fs, D), (None, "tp", "fsdp"))
    return d


class DecoderLM:
    """Dense / MoE / VLM decoder-only transformer."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -------------------------------------------------------------- params
    def param_defs(self):
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab_size
        n_dense = (
            cfg.first_k_dense if cfg.num_experts else cfg.num_layers
        )
        n_moe = cfg.num_layers - n_dense if cfg.num_experts else 0
        defs: dict[str, Any] = {
            "embed": ParamDef((V, D), ("tp", "fsdp"), scale=0.02),
            "final_norm": ParamDef((D,), (None,), init="ones"),
        }
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((D, V), ("fsdp", "tp"), scale=0.02)
        if n_dense:
            defs["dense_layers"] = {
                **_attn_defs(cfg, n_dense),
                **_mlp_defs(cfg, n_dense, cfg.d_ff),
            }
        if n_moe:
            defs["moe_layers"] = {
                **_attn_defs(cfg, n_moe),
                **_moe_defs(cfg, n_moe),
            }
        return defs

    # ------------------------------------------------------------ blocks
    def _attention(self, p, h, positions, cache=None, pos=None,
                   mrope_positions=None):
        cfg = self.cfg
        B, S, D = h.shape
        hd = cfg.resolved_head_dim
        x = L.rms_norm(h, p["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if cfg.qk_norm:
            q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
        if cfg.mrope and mrope_positions is not None:
            q = L.apply_mrope(q, mrope_positions, cfg.rope_theta)
            k = L.apply_mrope(k, mrope_positions, cfg.rope_theta)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        if cache is None:
            o = L.blockwise_attention(q, k, v, causal=True, window=cfg.window)
            new_cache = (k, v)
        else:
            k_cache, v_cache = cache
            eff = k_cache.shape[1]
            slot = pos % eff  # ring buffer when windowed (eff < max_len)
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, 1)
            o = L.decode_attention(
                q, k_cache, v_cache, jnp.minimum(pos + 1, eff)
            )
            new_cache = (k_cache, v_cache)
        out = jnp.einsum("bshk,hkd->bsd", o.astype(h.dtype), p["wo"])
        return h + out, new_cache

    def _mlp(self, p, h, moe: bool):
        cfg = self.cfg
        x = L.rms_norm(h, p["ln_mlp"], cfg.norm_eps)
        aux = jnp.zeros((), F32)
        if not moe:
            y = L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
        else:
            y, aux = L.moe_layer(
                x, p["router"], p["e_gate"], p["e_up"], p["e_down"],
                top_k=cfg.experts_per_token,
            )
            if cfg.num_shared_experts:
                y = y + L.swiglu(x, p["s_gate"], p["s_up"], p["s_down"])
        return h + y, aux

    def _layer(self, p, h, positions, moe, cache=None, pos=None,
               mrope_positions=None):
        h, new_cache = self._attention(
            p, h, positions, cache, pos, mrope_positions
        )
        h, aux = self._mlp(p, h, moe)
        return h, aux, new_cache

    # ----------------------------------------------------------- forward
    def _embed(self, params, batch):
        cfg = self.cfg
        if "embeds" in batch:
            h = batch["embeds"].astype(jnp.bfloat16)
        else:
            h = params["embed"][batch["tokens"]]
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return h, positions

    def _stack(self, params, key, h, positions, moe, mrope_positions):
        """scan a layer stack over stacked params (training path)."""
        if key not in params:
            return h, jnp.zeros((), F32)

        def body(carry, lp):
            hh, aux = carry
            hh, a, _ = self._layer(
                lp, hh, positions, moe, mrope_positions=mrope_positions
            )
            return (hh, aux + a), None

        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
        (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), F32)), params[key])
        return h, aux

    def hidden_states(self, params, batch):
        """Final-layer hidden states (B, S, D) + moe aux loss."""
        h, positions = self._embed(params, batch)
        mrope_positions = batch.get("positions") if self.cfg.mrope else None
        h, _ = self._stack(
            params, "dense_layers", h, positions, False, mrope_positions
        )
        h, aux = self._stack(
            params, "moe_layers", h, positions, True, mrope_positions
        )
        return L.rms_norm(h, params["final_norm"], self.cfg.norm_eps), aux

    def head_weights(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def loss(self, params, batch):
        from .losses import chunked_cross_entropy

        h, aux = self.hidden_states(params, batch)
        loss = chunked_cross_entropy(h, self.head_weights(params),
                                     batch["labels"])
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------- serve
    def cache_spec(self, batch_size: int, max_len: int):
        """(shape, dtype, logical spec) tree for the KV cache."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        eff = min(cfg.window, max_len) if cfg.window else max_len
        def kv(n):
            return (
                jax.ShapeDtypeStruct(
                    (n, batch_size, eff, cfg.num_kv_heads, hd), jnp.bfloat16
                ),
                ("layer", "dp", "sp", None, None),
            )
        n_dense = (
            cfg.first_k_dense if cfg.num_experts else cfg.num_layers
        )
        n_moe = cfg.num_layers - n_dense if cfg.num_experts else 0
        spec = {}
        if n_dense:
            spec["dense"] = {"k": kv(n_dense), "v": kv(n_dense)}
        if n_moe:
            spec["moe"] = {"k": kv(n_moe), "v": kv(n_moe)}
        return spec

    def init_cache(self, batch_size: int, max_len: int):
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            jax.tree.map(
                lambda t: t[0], self.cache_spec(batch_size, max_len),
                is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                and isinstance(x[0], jax.ShapeDtypeStruct),
            ),
        )

    def _stack_decode(self, params, key, h, positions, moe, cache_k,
                      cache_v, pos, mrope_positions):
        if key not in params:
            return h, cache_k, cache_v

        def body(carry, xs):
            hh = carry
            lp, ck, cv = xs
            hh, _, (nck, ncv) = self._layer(
                lp, hh, positions, moe, cache=(ck, cv), pos=pos,
                mrope_positions=mrope_positions,
            )
            return hh, (nck, ncv)

        h, (ck, cv) = jax.lax.scan(
            body, h, (params[key], cache_k, cache_v)
        )
        return h, ck, cv

    def decode_step(self, params, cache, tokens, pos, mrope_positions=None):
        """tokens: (B, 1); pos: () int32 — returns (logits (B,V), cache)."""
        cfg = self.cfg
        h = params["embed"][tokens]
        B = tokens.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        new_cache = dict(cache)
        if "dense" in cache:
            h, ck, cv = self._stack_decode(
                params, "dense_layers", h, positions, False,
                cache["dense"]["k"], cache["dense"]["v"], pos,
                mrope_positions,
            )
            new_cache["dense"] = {"k": ck, "v": cv}
        if "moe" in cache:
            h, ck, cv = self._stack_decode(
                params, "moe_layers", h, positions, True,
                cache["moe"]["k"], cache["moe"]["v"], pos, mrope_positions,
            )
            new_cache["moe"] = {"k": ck, "v": cv}
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", h, self.head_weights(params))
        return logits[:, 0].astype(F32), new_cache

    def prefill(self, params, batch, max_len: int | None = None):
        """Run the prompt, returning (cache, last-position logits)."""
        cfg = self.cfg
        h, positions = self._embed(params, batch)
        B, S = positions.shape
        max_len = max_len or S
        eff = min(cfg.window, max_len) if cfg.window else max_len
        mrope_positions = batch.get("positions") if cfg.mrope else None
        new_cache = {}

        def fit(k):
            """Right-size a (B, S, …) cache to ``eff`` slots: keep the last
            ``eff`` (ring window) or right-pad so decode can append."""
            k = k[:, -eff:]
            pad = eff - k.shape[1]
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
            return k

        def run(key, moe, h):
            if key not in params:
                return h, None

            def body(hh, lp):
                hh, _, (k, v) = self._layer(
                    lp, hh, positions, moe, mrope_positions=mrope_positions
                )
                return hh, (fit(k), fit(v))

            h, (ks, vs) = jax.lax.scan(body, h, params[key])
            return h, {"k": ks, "v": vs}

        h, dense_cache = run("dense_layers", False, h)
        h, moe_cache = run("moe_layers", True, h)
        if dense_cache is not None:
            new_cache["dense"] = dense_cache
        if moe_cache is not None:
            new_cache["moe"] = moe_cache
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bd,dv->bv", h[:, -1], self.head_weights(params)
        )
        return new_cache, logits.astype(F32)
