"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, D).  Encoder layers are
bidirectional; decoder layers are causal self-attention + cross-attention
to the encoder memory.  Decode shapes run the decoder with a KV cache and
precomputed cross-attention K/V (encoder memory is fixed at decode time).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.sharding import ParamDef
from . import layers as L

F32 = jnp.float32


def _block_defs(cfg: ArchConfig, n: int, cross: bool) -> dict:
    D, H, KV, hd = (
        cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    )
    d = {
        "wq": ParamDef((n, D, H, hd), (None, "fsdp", "tp", None)),
        "wk": ParamDef((n, D, KV, hd), (None, "fsdp", "tp", None)),
        "wv": ParamDef((n, D, KV, hd), (None, "fsdp", "tp", None)),
        "wo": ParamDef((n, H, hd, D), (None, "tp", None, "fsdp")),
        "ln_attn": ParamDef((n, D), (None, None), init="ones"),
        "w_gate": ParamDef((n, D, cfg.d_ff), (None, "fsdp", "tp")),
        "w_up": ParamDef((n, D, cfg.d_ff), (None, "fsdp", "tp")),
        "w_down": ParamDef((n, cfg.d_ff, D), (None, "tp", "fsdp")),
        "ln_mlp": ParamDef((n, D), (None, None), init="ones"),
    }
    if cross:
        d.update(
            {
                "xq": ParamDef((n, D, H, hd), (None, "fsdp", "tp", None)),
                "xk": ParamDef((n, D, KV, hd), (None, "fsdp", "tp", None)),
                "xv": ParamDef((n, D, KV, hd), (None, "fsdp", "tp", None)),
                "xo": ParamDef((n, H, hd, D), (None, "tp", None, "fsdp")),
                "ln_x": ParamDef((n, D), (None, None), init="ones"),
            }
        )
    return d


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def param_defs(self):
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab_size
        return {
            "embed": ParamDef((V, D), ("tp", "fsdp"), scale=0.02),
            "enc_layers": _block_defs(cfg, cfg.encoder_layers, cross=False),
            "dec_layers": _block_defs(cfg, cfg.num_layers, cross=True),
            "enc_norm": ParamDef((D,), (None,), init="ones"),
            "final_norm": ParamDef((D,), (None,), init="ones"),
            "head": ParamDef((D, V), ("fsdp", "tp"), scale=0.02),
        }

    # ------------------------------------------------------------- blocks
    def _self_attn(self, p, h, positions, causal, cache=None, pos=None):
        cfg = self.cfg
        x = L.rms_norm(h, p["ln_attn"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if cache is None:
            o = L.blockwise_attention(q, k, v, causal=causal)
            new_cache = (k, v)
        else:
            kc, vc = cache
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, 1)
            o = L.decode_attention(q, kc, vc, pos + 1)
            new_cache = (kc, vc)
        return h + jnp.einsum("bshk,hkd->bsd", o.astype(h.dtype), p["wo"]), new_cache

    def _cross_attn(self, p, h, mem_k, mem_v):
        cfg = self.cfg
        x = L.rms_norm(h, p["ln_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, p["xq"])
        o = L.blockwise_attention(q, mem_k, mem_v, causal=False)
        return h + jnp.einsum("bshk,hkd->bsd", o.astype(h.dtype), p["xo"])

    def _mlp(self, p, h):
        x = L.rms_norm(h, p["ln_mlp"], self.cfg.norm_eps)
        return h + L.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])

    def encode(self, params, embeds):
        B, S, D = embeds.shape
        h = embeds.astype(jnp.bfloat16)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(hh, lp):
            hh, _ = self._self_attn(lp, hh, positions, causal=False)
            hh = self._mlp(lp, hh)
            return hh, None

        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
        h, _ = jax.lax.scan(body, h, params["enc_layers"])
        return L.rms_norm(h, params["enc_norm"], self.cfg.norm_eps)

    def _mem_kv(self, lp, mem):
        k = jnp.einsum("bsd,dhk->bshk", mem, lp["xk"])
        v = jnp.einsum("bsd,dhk->bshk", mem, lp["xv"])
        return k, v

    def hidden_states(self, params, batch):
        cfg = self.cfg
        mem = self.encode(params, batch["embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(hh, lp):
            hh, _ = self._self_attn(lp, hh, positions, causal=True)
            mk, mv = self._mem_kv(lp, mem)
            hh = self._cross_attn(lp, hh, mk, mv)
            hh = self._mlp(lp, hh)
            return hh, None

        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
        h, _ = jax.lax.scan(body, h, params["dec_layers"])
        return L.rms_norm(h, params["final_norm"], cfg.norm_eps), jnp.zeros(
            (), F32
        )

    def head_weights(self, params):
        return params["head"]

    def loss(self, params, batch):
        from .losses import chunked_cross_entropy

        h, aux = self.hidden_states(params, batch)
        loss = chunked_cross_entropy(h, params["head"], batch["labels"])
        return loss, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------- serve
    def cache_spec(self, batch_size: int, max_len: int, enc_len: int = 0):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        n = cfg.num_layers
        enc_len = enc_len or max_len
        kv = lambda s: (
            jax.ShapeDtypeStruct(
                (n, batch_size, s, cfg.num_kv_heads, hd), jnp.bfloat16
            ),
            ("layer", "dp", "sp", None, None),
        )
        return {
            "self_k": kv(max_len),
            "self_v": kv(max_len),
            "cross_k": kv(enc_len),
            "cross_v": kv(enc_len),
        }

    def init_cache(self, batch_size: int, max_len: int, enc_len: int = 0):
        return jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype),
            self.cache_spec(batch_size, max_len, enc_len),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
        )

    def decode_step(self, params, cache, tokens, pos, mrope_positions=None):
        cfg = self.cfg
        h = params["embed"][tokens]
        B = tokens.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

        def body(hh, xs):
            lp, kc, vc, xk, xv = xs
            hh, (kc2, vc2) = self._self_attn(
                lp, hh, positions, causal=True, cache=(kc, vc), pos=pos
            )
            x = L.rms_norm(hh, lp["ln_x"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", x, lp["xq"])
            o = L.decode_attention(q, xk, xv, xk.shape[1])
            hh = hh + jnp.einsum("bshk,hkd->bsd", o.astype(hh.dtype), lp["xo"])
            hh = self._mlp(lp, hh)
            return hh, (kc2, vc2)

        h, (kc, vc) = jax.lax.scan(
            body,
            h,
            (
                params["dec_layers"],
                cache["self_k"],
                cache["self_v"],
                cache["cross_k"],
                cache["cross_v"],
            ),
        )
        new_cache = dict(cache, self_k=kc, self_v=vc)
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, 0], params["head"])
        return logits.astype(F32), new_cache

    def prefill(self, params, batch, max_len: int | None = None):
        """Encode + run the decoder prompt, building all caches."""
        cfg = self.cfg
        mem = self.encode(params, batch["embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        h = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def fit(k):
            pad = max_len - k.shape[1]
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
            return k.astype(jnp.bfloat16)

        def body(hh, lp):
            hh, (k, v) = self._self_attn(lp, hh, positions, causal=True)
            mk, mv = self._mem_kv(lp, mem)
            hh = self._cross_attn(lp, hh, mk, mv)
            hh = self._mlp(lp, hh)
            return hh, (
                fit(k), fit(v),
                mk.astype(jnp.bfloat16), mv.astype(jnp.bfloat16),
            )

        h, (ks, vs, mks, mvs) = jax.lax.scan(body, h, params["dec_layers"])
        cache = {
            "self_k": ks, "self_v": vs, "cross_k": mks, "cross_v": mvs,
        }
        h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"])
        return cache, logits.astype(F32)
