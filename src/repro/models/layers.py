"""Shared neural building blocks for the architecture fleet (pure JAX).

Everything is functional: params are pytrees built from ParamDef
declarations (parallel/sharding.py).  Attention is implemented blockwise
(online softmax over key blocks, exact causal extents per query block) so
the compiled HLO never materializes an (S × S) score matrix — the same
algorithm as kernels/flash_attention.py, which replaces it on TPU.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

F32 = jnp.float32


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(F32)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim)
    )


def apply_rope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (B, S) int32
    theta: float = 1e4,
) -> jax.Array:
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    ang = positions[..., None].astype(F32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (3, B, S) int32 — temporal/height/width
    theta: float = 1e4,
    sections: tuple[int, int, int] = (2, 1, 1),  # D/2 split ratio t:h:w
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the D/2 frequency bands are split into
    three sections rotated by the temporal / height / width positions."""
    D = x.shape[-1]
    half = D // 2
    tot = sum(sections)
    bounds = [half * sum(sections[: i + 1]) // tot for i in range(3)]
    freqs = rope_freqs(D, theta)  # (half,)
    parts = []
    lo = 0
    for i, hi in enumerate(bounds):
        ang = positions[i][..., None].astype(F32) * freqs[lo:hi]
        parts.append(ang)
        lo = hi
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# blockwise attention (flash-style, jnp; exact causal extents)
# ----------------------------------------------------------------------
def _attn_block(qi, k_ctx, v_ctx, q_pos0: int, k_pos0: int, *,
                causal: bool, sm_scale: float, bk: int, window: int = 0):
    """One query block vs its full (static) key context, streamed in key
    blocks of ``bk`` with an online softmax.  All fp32."""
    B, bq, H, Dh = qi.shape
    Sk = k_ctx.shape[1]
    nk = Sk // bk
    q = qi.astype(F32) * sm_scale
    kb = k_ctx.reshape(B, nk, bk, H, Dh)
    vb = v_ctx.reshape(B, nk, bk, H, Dh)

    def step(carry, inp):
        acc, m_i, l_i = carry
        kj, vj, j = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj.astype(F32))
        if causal or window:
            qp = q_pos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kp = k_pos0 + j * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1
            )
            ok = (qp >= kp) if causal else (qp == qp)
            if window:
                ok &= kp > qp - window
            s = jnp.where(ok[None, None], s, -1e30)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vj.astype(F32)
        )
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, H, bq, Dh), F32)
    m0 = jnp.full((B, H, bq), -1e30, F32)
    l0 = jnp.zeros((B, H, bq), F32)
    (acc, _, l_i), _ = jax.lax.scan(
        step,
        (acc0, m0, l0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            jnp.arange(nk),
        ),
    )
    out = acc / jnp.maximum(l_i, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3)  # (B, bq, H, Dh)


def blockwise_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Sk, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: int = 0,
    bq: int = 256,
    bk: int = 512,
) -> jax.Array:
    """Flash-style attention in plain jnp.  Query blocks are a Python loop
    (static shapes, exact causal key extents — no masked-block waste);
    key blocks stream through a scan (O(B·H·bq·bk) live memory)."""
    B, Sq, H, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    group = H // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    sm_scale = 1.0 / math.sqrt(Dh)
    bq = min(bq, Sq)
    outs = []
    nq = -(-Sq // bq)
    for i in range(nq):
        q0 = i * bq
        qi = q[:, q0 : q0 + bq]
        q_abs0 = q_offset + q0
        hi = min(Sk, q_abs0 + qi.shape[1]) if causal else Sk
        # earliest key any query in this block may see
        lo = max(0, q_abs0 + 1 - window) if window else 0
        lo = min(lo, hi - 1) if hi > 0 else 0
        # align to bk
        lo_a = (lo // bk) * bk
        hi_a = -(-hi // bk) * bk
        hi_a = min(hi_a, ((Sk + bk - 1) // bk) * bk)
        if hi_a > Sk:  # pad keys once if needed
            pad = hi_a - Sk
            k_ctx = jnp.pad(k[:, lo_a:Sk], ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_ctx = jnp.pad(v[:, lo_a:Sk], ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            k_ctx = k[:, lo_a:hi_a]
            v_ctx = v[:, lo_a:hi_a]
        outs.append(
            _attn_block(
                qi, k_ctx, v_ctx, q_abs0, lo_a,
                causal=causal, sm_scale=sm_scale, bk=min(bk, k_ctx.shape[1]),
                window=window,
            ).astype(q.dtype)
        )
    return jnp.concatenate(outs, axis=1)


def decode_attention(
    q: jax.Array,  # (B, 1, H, Dh)
    k_cache: jax.Array,  # (B, S, Hkv, Dh)
    v_cache: jax.Array,
    num_valid: jax.Array,  # () int32 — number of valid cache slots
) -> jax.Array:
    """Single-token decode attention over a KV cache with dynamic validity
    masking.  Works for both linear caches (num_valid = pos + 1) and ring
    buffers (num_valid = min(pos + 1, window); slot order is irrelevant to
    the softmax, and keys carry their RoPE phase from write time)."""
    B, S, Hkv, Dh = k_cache.shape
    H = q.shape[2]
    group = H // Hkv
    sm_scale = 1.0 / math.sqrt(Dh)
    qf = q.astype(F32) * sm_scale  # (B, 1, H, D)
    kf = k_cache.astype(F32)
    if group > 1:
        qg = qf.reshape(B, 1, Hkv, group, Dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)  # (B,Hkv,g,1,S)
        s = s.reshape(B, H, 1, S)
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    valid = jnp.arange(S) < num_valid
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if group > 1:
        pg = p.reshape(B, Hkv, group, 1, S)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v_cache.astype(F32))
        o = o.reshape(B, 1, H, Dh)
    else:
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache.astype(F32))
    return o.astype(q.dtype)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------
def swiglu(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


# ----------------------------------------------------------------------
# Mixture of Experts (capacity routing, EP-friendly scatter/gather)
# ----------------------------------------------------------------------
def moe_layer(
    x: jax.Array,  # (B, S, D)
    router_w: jax.Array,  # (D, E)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    dispatch: str = "sort",
) -> tuple[jax.Array, jax.Array]:
    """Top-k token-choice routing with per-expert capacity (tokens over
    capacity are dropped, standard Switch/GShard semantics).

    Dispatch is a scatter into (E·C, D) expert buffers and the expert FFN
    is one stacked einsum — sharding E over the "model"/ep axis gives
    expert parallelism with XLA inserting the all-to-alls.

    ``dispatch``: how position-in-expert is computed.
      "sort"   — argsort + searchsorted, O(T·k log) and no (T·k, E)
                 intermediate (§Perf iteration 3: the dry-run exposed the
                 one-hot cumsum as a reduce-window FLOPs bomb).
      "cumsum" — classic GShard one-hot cumsum (kept for comparison).

    Returns (y (B,S,D), aux_loss ()).
    """
    B, S, D = x.shape
    E = router_w.shape[1]
    T = B * S
    xf = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xf.astype(F32), router_w.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate, ids = jax.lax.top_k(probs, top_k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * Σ_e f_e · p_e  (scatter-based —
    # no (T, E) one-hot needed)
    f_e = jnp.zeros((E,), F32).at[ids[:, 0]].add(1.0) / T
    aux = E * jnp.mean(f_e * jnp.mean(probs, axis=0))

    cap = int(capacity_factor * T * top_k / E)
    cap = max(8, -(-cap // 8) * 8)  # align
    flat_ids = ids.reshape(-1)  # (T·k,)
    if dispatch == "sort":
        sort_idx = jnp.argsort(flat_ids, stable=True)
        sorted_ids = flat_ids[sort_idx]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(E))  # (E,)
        pos_sorted = jnp.arange(T * top_k) - starts[sorted_ids]
        mypos = jnp.zeros((T * top_k,), jnp.int32).at[sort_idx].set(
            pos_sorted.astype(jnp.int32)
        )
    else:  # cumsum (GShard classic)
        onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (T·k, E)
        pos_all = jnp.cumsum(onehot, axis=0) - 1
        mypos = jnp.take_along_axis(pos_all, flat_ids[:, None], axis=1)[:, 0]
    keep = mypos < cap
    dest = jnp.where(keep, flat_ids * cap + mypos, E * cap)  # E*cap = trash
    tok = jnp.repeat(jnp.arange(T), top_k)
    xin = xf[tok]  # (T·k, D)
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[dest].add(
        jnp.where(keep[:, None], xin, 0)
    )[: E * cap]
    h = buf.reshape(E, cap, D)
    gates = jnp.einsum("ecd,edf->ecf", h, w_gate)
    ups = jnp.einsum("ecd,edf->ecf", h, w_up)
    act = jax.nn.silu(gates.astype(F32)).astype(x.dtype) * ups
    out = jnp.einsum("ecf,efd->ecd", act, w_down).reshape(E * cap, D)
    out = jnp.concatenate([out, jnp.zeros((1, D), out.dtype)], 0)
    y_slots = out[dest] * (keep * gate.reshape(-1))[:, None].astype(x.dtype)
    y = y_slots.reshape(T, top_k, D).sum(axis=1)
    return y.reshape(B, S, D), aux


# ----------------------------------------------------------------------
# Mamba-2 (SSD) block
# ----------------------------------------------------------------------
def causal_conv1d(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv.  x: (B, S, C); w: (C, K).
    With ``state`` (B, K-1, C): decode mode (S small), returns new state."""
    B, S, C = x.shape
    K = w.shape[1]
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)  # (B, K-1+S, C)
        new_state = xin[:, -(K - 1):, :]
    else:
        xin = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = xin[:, -(K - 1):, :]
    out = jax.lax.conv_general_dilated(
        xin.astype(F32),
        w.T[:, None, :].astype(F32),  # (K, 1, C) -> spec below
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return out.astype(x.dtype), new_state


def mamba2_mix(
    x: jax.Array,  # (B, S, D)
    p: dict,
    *,
    d_state: int,
    head_dim: int,
    expand: int,
    chunk: int = 64,
    ssm_state=None,  # (B, nheads, d_state, head_dim) decode carry
    conv_state=None,  # ((B,K-1,d_inner), (B,K-1,2N)) decode carry
):
    """Mamba-2 mixer (SSD).  Returns (y, (ssm_state, conv_state)).

    Sharding-aware layout (§Perf iteration 2): the head axis stays
    explicit end-to-end (never merged with batch — merged dims with mixed
    shardings force SPMD full-reshards), projections are separate params
    (no slicing of a tp-sharded axis), and B/C are computed once per
    (batch, chunk) — they are head-free in the ngroups=1 SSD."""
    B, S, D = x.shape
    d_inner = expand * D
    nheads = d_inner // head_dim
    z = jnp.einsum("bsd,dp->bsp", x, p["w_z"])  # (B,S,d_inner) [tp]
    xs = jnp.einsum("bsd,dp->bsp", x, p["w_x"])  # (B,S,d_inner) [tp]
    bc = jnp.einsum("bsd,dn->bsn", x, p["w_bc"])  # (B,S,2N)
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])  # (B,S,H) [tp on H]

    cs_x = conv_state[0] if conv_state is not None else None
    cs_bc = conv_state[1] if conv_state is not None else None
    xs, new_cs_x = causal_conv1d(xs, p["conv_x"], cs_x)
    bc, new_cs_bc = causal_conv1d(bc, p["conv_bc"], cs_bc)
    xs = jax.nn.silu(xs.astype(F32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(F32))
    b_mat = bc[..., :d_state]  # (B,S,N) head-free
    c_mat = bc[..., d_state:]  # (B,S,N)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))
    a = -jnp.exp(p["a_log"].astype(F32))  # (H,)
    log_decay = dt * a[None, None, :]  # (B,S,H)

    xh = xs.reshape(B, S, nheads, head_dim)  # head axis explicit [tp]
    if ssm_state is not None and S == 1:
        # decode fast path: one recurrence step, pure einsums
        h = ssm_state.astype(F32)  # (B,H,N,D)
        decay = jnp.exp(log_decay[:, 0])  # (B,H)
        xdt = xh[:, 0].astype(F32) * dt[:, 0][..., None]  # (B,H,Dh)
        h = decay[..., None, None] * h + jnp.einsum(
            "bn,bhd->bhnd", b_mat[:, 0], xdt
        )
        y = jnp.einsum("bn,bhnd->bhd", c_mat[:, 0], h)  # (B,H,Dh)
        y = y[:, None].reshape(B, 1, nheads, head_dim)
        new_state = h
    elif S % chunk == 0:
        y, new_state = _ssd_chunked_jnp(
            xh, dt, log_decay, b_mat, c_mat, chunk, ssm_state
        )
    else:
        y, new_state = _ssd_seq_jnp(
            xh, dt, log_decay, b_mat, c_mat, ssm_state
        )
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"])
    out = jnp.einsum("bsp,pd->bsd", y, p["w_out"])
    return out, (new_state, (new_cs_x, new_cs_bc))


def _ssd_chunked_jnp(xh, dt, a, b, c, chunk: int, state0=None):
    """Chunked SSD in plain jnp with an explicit head axis — same algorithm
    as the Pallas kernel (kernels/mamba2_ssd.py), which replaces the
    intra-chunk part on TPU.

    xh: (B,S,H,Dh); dt/a: (B,S,H); b/c: (B,S,N) head-free (ngroups=1).
    Returns (y (B,S,H,Dh) f32, state (B,H,N,Dh) f32).
    """
    B, T, H, Dh = xh.shape
    N = b.shape[-1]
    C = T // chunk
    xr = xh.reshape(B, C, chunk, H, Dh).astype(F32)
    dtr = dt.reshape(B, C, chunk, H).astype(F32)
    ar = a.reshape(B, C, chunk, H).astype(F32)
    br = b.reshape(B, C, chunk, N).astype(F32)
    cr = c.reshape(B, C, chunk, N).astype(F32)
    cum_a = jnp.cumsum(ar, axis=2)  # (B,C,L,H)
    ii = jnp.arange(chunk)
    li = (ii[:, None] >= ii[None, :]).astype(F32)  # (L,M)
    lmat = jnp.exp(
        cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]
    ) * li[None, None, :, :, None]  # (B,C,L,M,H)
    scores = jnp.einsum("bcls,bcms->bclm", cr, br)  # head-free (B,C,L,M)
    xdt = xr * dtr[..., None]  # (B,C,L,H,Dh)
    y_intra = jnp.einsum(
        "bclm,bclmh,bcmhd->bclhd", scores, lmat, xdt
    )
    decay_end = jnp.exp(cum_a[:, :, -1:, :] - cum_a)  # (B,C,L,H)
    states = jnp.einsum("bcls,bclh,bclhd->bchsd", br, decay_end, xdt)
    chunk_decay = jnp.exp(cum_a[:, :, -1])  # (B,C,H)
    h0 = (
        jnp.zeros((B, H, N, Dh), F32) if state0 is None
        else state0.astype(F32)
    )

    def step(h, inp):
        st_c, dec_c = inp  # (B,H,N,Dh), (B,H)
        return dec_c[..., None, None] * h + st_c, h

    h_final, h_ins = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_ins = jnp.moveaxis(h_ins, 0, 1)  # (B,C,H,N,Dh) state entering chunk
    y_cross = jnp.einsum(
        "bcls,bchsd,bclh->bclhd", cr, h_ins, jnp.exp(cum_a)
    )
    y = (y_intra + y_cross).reshape(B, T, H, Dh)
    return y, h_final


def _ssd_seq_jnp(xh, dt, a, b, c, state0=None):
    """Sequential (exact) SSD with explicit head axis, for ragged lengths."""
    B, T, H, Dh = xh.shape
    N = b.shape[-1]
    h0 = (
        jnp.zeros((B, H, N, Dh), F32) if state0 is None
        else state0.astype(F32)
    )

    def step(h, inp):
        x_t, dt_t, a_t, b_t, c_t = inp
        xdt = x_t.astype(F32) * dt_t[..., None]  # (B,H,Dh)
        h = jnp.exp(a_t)[..., None, None] * h + jnp.einsum(
            "bn,bhd->bhnd", b_t, xdt
        )
        y = jnp.einsum("bn,bhnd->bhd", c_t, h)
        return h, y

    xs = (
        jnp.moveaxis(xh.astype(F32), 1, 0),
        jnp.moveaxis(dt.astype(F32), 1, 0),
        jnp.moveaxis(a.astype(F32), 1, 0),
        jnp.moveaxis(b.astype(F32), 1, 0),
        jnp.moveaxis(c.astype(F32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
