"""Adaptive tile refiner — the paper's Sec. V-B path refiner mapped to TPU.

On Sunway the refiner permutes/splits contraction indices until every
stem GEMM matches the SWTT fused-kernel tile requirements (8×8 kernels,
DMA-bandwidth roofline).  The TPU analogue implemented here makes three
per-node decisions over the normalized :class:`~repro.lowering.gemm_form.
GemmForm` of every contraction step:

  1. **backend** — Pallas ``tiled_matmul`` for MXU-sized GEMMs,
     ``jnp.dot`` (XLA batched dot_general) for sub-tile shapes where
     kernel padding would dominate, plain ``jnp.einsum`` for tiny or
     degenerate nodes where even the transpose/reshape plumbing costs
     more than the contraction;
  2. **block shapes** — (bm, bn, bk) snapped to multiples of the 128-wide
     MXU tile, chosen per node from a candidate ladder under the VMEM
     residency budget;
  3. **pad-vs-split** — for each candidate the model charges the padded
     FLOPs ``ceil(M/bm)·ceil(N/bn)·ceil(K/bk)`` tiles actually execute;
     picking a smaller block *splits* the GEMM into more, fuller tiles
     while a larger block *pads* — the candidate with the lower modeled
     time wins (the Sunway refiner's permute-or-pad choice).

The same per-node cost model (tile quantization capped by the HBM
roofline, complex traffic counted as Karatsuba's 3 real GEMMs) is summed
into ``LoweredSchedule.modeled_time_s``, which the API layer feeds back
into ``PlanReport.modeled_time_s`` so planner metrics reflect the
schedule that will actually execute.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable, Hashable, Sequence

import jax.numpy as jnp

from ..core.merging import TPU_HBM_BW, TPU_MXU, TPU_PEAK_FLOPS
from ..kernels.contract_gemm import suffix_tile_split
from .gemm_form import GemmForm, lower_step, real_component_bytes

# candidate Pallas block edges (multiples of the MXU tile)
BLOCK_CANDIDATES = (128, 256, 512)
# VMEM residency budget for one (bm×bk + bk×bn + bm×bn) working set, fp32
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
# below this many real FLOPs a node stays on einsum — the reshape/
# transpose plumbing would cost more than the contraction itself
EINSUM_FLOPS_FLOOR = 2.0 ** 16
# effective peak for non-MXU lowerings (XLA dot_general / einsum on
# sub-tile shapes): mostly VPU + permute work, modeled at peak/8
NON_MXU_PEAK_FRACTION = 0.125


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """Refined, executable lowering of one contraction step.

    For ``backend="pallas_fused"`` the block shapes are the *effective*
    axis-suffix tiles (see ``kernels.contract_gemm.suffix_tile_split``),
    which divide (B, M, N, K) exactly — no padding FLOPs, no materialized
    operand transpose.  ``transpose_bytes`` is the HBM permute traffic
    this spec pays (0 for fused/einsum — the fused saving is what
    ``LoweredSchedule.transpose_bytes_eliminated`` totals up).
    """

    form: GemmForm
    backend: str  # "pallas" | "pallas_fused" | "dot" | "einsum"
    bm: int
    bn: int
    bk: int
    modeled_time_s: float
    pad_waste: float  # fraction of executed MXU FLOPs that are padding
    transpose_bytes: float = 0.0  # HBM bytes moved permuting the operands
    precision: str = "fp32"  # "fp32" | "bf16" (bf16-input/fp32-accumulate)


def default_fused() -> bool:
    """Whether the refiner may choose the fused transpose-GEMM backend:
    the ``REPRO_FUSED_GEMM`` environment variable (CI runs the tier-1
    gate under both values), defaulting to on.  ``REPRO_FUSED_GEMM=0``
    is the off-switch back to the materialized permute + ``tiled_matmul``
    reference path."""
    v = os.environ.get("REPRO_FUSED_GEMM", "1")
    if v not in ("0", "1"):
        raise ValueError(f"REPRO_FUSED_GEMM={v!r} not in ('0', '1')")
    return v == "1"


def default_megakernel() -> bool:
    """Whether the executor may fuse adjacent GEMMs into VMEM-resident
    chains (the epilogue megakernel): the ``REPRO_MEGAKERNEL``
    environment variable (CI runs the tier-1 gate under both values),
    defaulting to on.  ``REPRO_MEGAKERNEL=0`` is the off-switch back to
    one kernel dispatch per tree step."""
    v = os.environ.get("REPRO_MEGAKERNEL", "1")
    if v not in ("0", "1"):
        raise ValueError(f"REPRO_MEGAKERNEL={v!r} not in ('0', '1')")
    return v == "1"


def precision_itemsize(dtype, precision: str = "fp32") -> int:
    """Storage bytes per element at ``precision``: half the native width
    when the element's real components are held as bf16 (complex64 → a
    bf16 pair = 4 bytes, float32 → 2 bytes), the native width for fp32."""
    itemsize = int(jnp.dtype(dtype).itemsize)
    return max(1, itemsize // 2) if precision == "bf16" else itemsize


def operand_transpose_bytes(
    form: GemmForm, dtype, precision: str = "fp32"
) -> float:
    """HBM traffic of materializing the operand permutations: one read +
    one write per operand whose native layout is not already in GEMM
    order — the ``2*(|A|+|B|)*bytes`` the fused kernel eliminates.
    Operands consumed at bf16 are permuted at their (halved) storage
    width."""
    itemsize = precision_itemsize(dtype, precision)
    t = 0.0
    if form.perm_a != tuple(range(len(form.perm_a))):
        t += 2.0 * itemsize * form.B * form.M * form.K
    if form.perm_b != tuple(range(len(form.perm_b))):
        t += 2.0 * itemsize * form.B * form.K * form.N
    return t


def _ceil_to(x: float, t: int) -> float:
    return max(t, math.ceil(x / t) * t)


def _real_gemm_count(dtype, backend: str) -> int:
    """Real GEMMs per logical GEMM: Karatsuba runs 3, a naive complex
    product runs 4, real dtypes run 1."""
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        return 1
    return 3 if backend == "pallas" else 4


def step_traffic_bytes(
    form: GemmForm, dtype, precision: str = "fp32"
) -> float:
    """Modeled HBM operand + output bytes for one execution of the step
    (excluding any transpose round-trip): inputs at their storage
    precision, output always at the full fp32-component width (the MXU
    accumulates in fp32 and the result is written back as such)."""
    itemsize = int(jnp.dtype(dtype).itemsize)
    in_item = precision_itemsize(dtype, precision)
    return float(form.B) * (
        in_item * (form.M * form.K + form.K * form.N)
        + itemsize * form.M * form.N
    )


def modeled_step_time(
    form: GemmForm,
    dtype,
    backend: str,
    bm: int,
    bn: int,
    bk: int,
    precision: str = "fp32",
) -> tuple[float, float]:
    """(seconds, pad_waste) for one execution of this step.

    Pallas is charged padded-tile FLOPs at full MXU peak; the fused
    transpose-GEMM executes exact FLOPs (axis-suffix tiles never pad);
    dot/einsum are charged exact FLOPs at the non-MXU effective peak.
    All are capped by the HBM roofline on the operand + output traffic —
    and the backends that materialize permuted operand copies
    (``pallas``, ``dot``) additionally pay the ``2*(|A|+|B|)*bytes``
    transpose bandwidth that the fused kernel (and XLA's fused einsum)
    eliminates: a separate, non-overlappable HBM round-trip before the
    GEMM proper.

    ``precision="bf16"`` (MXU backends only) doubles the systolic-array
    rate and halves the operand-side traffic — bf16 inputs, fp32
    accumulation, fp32 output writeback.
    """
    n_real = _real_gemm_count(dtype, backend)
    flops = form.flops * n_real
    traffic = step_traffic_bytes(form, dtype, precision)
    t_mem = traffic / TPU_HBM_BW
    mxu_peak = TPU_PEAK_FLOPS * (2.0 if precision == "bf16" else 1.0)
    if backend == "pallas":
        padded = (
            2.0
            * form.B
            * _ceil_to(form.M, bm)
            * _ceil_to(form.N, bn)
            * _ceil_to(form.K, bk)
            * n_real
        )
        t_compute = padded / mxu_peak
        waste = 1.0 - flops / padded
    elif backend == "pallas_fused":
        t_compute = flops / mxu_peak
        waste = 0.0
    else:
        t_compute = flops / (TPU_PEAK_FLOPS * NON_MXU_PEAK_FRACTION)
        waste = 0.0
    t = max(t_compute, t_mem)
    if backend in ("pallas", "dot"):
        t += operand_transpose_bytes(form, dtype, precision) / TPU_HBM_BW
    return t, waste


def refine_step(
    form: GemmForm,
    dtype,
    *,
    min_kernel_dim: int = TPU_MXU,
    fused: bool | None = None,
    precision: str = "fp32",
) -> GemmSpec:
    """Pick backend + block shapes for one normalized contraction step.

    ``fused`` gates the fused transpose-GEMM candidates (default:
    :func:`default_fused`, i.e. ``REPRO_FUSED_GEMM``).  A fused candidate
    is admissible when its effective axis-suffix tiles are still
    MXU-sized — its cost model pays no padding FLOPs and no operand
    transpose bandwidth, so it wins whenever admissible.

    ``precision="bf16"`` refines the step under the bf16-input/
    fp32-accumulate model: the VMEM working-set check counts 2-byte
    operand components (the fp32 accumulator tile stays 4-byte), so
    larger blocks become admissible, and the cost model prices 2× MXU
    rate / half operand traffic.  Only MXU backends carry the precision —
    dot/einsum fallbacks always execute fp32.
    """
    if fused is None:
        fused = default_fused()
    real_bytes = real_component_bytes(dtype)
    if form.flops < EINSUM_FLOPS_FLOOR:
        t, w = modeled_step_time(form, dtype, "einsum", 1, 1, 1)
        return GemmSpec(form, "einsum", 0, 0, 0, t, w)
    # 64-bit components (float64 / complex128) would be silently
    # truncated by the fp32 Pallas accumulator — keep them on XLA's dot.
    if min(form.M, form.N, form.K) < min_kernel_dim or real_bytes > 4:
        t, w = modeled_step_time(form, dtype, "dot", 1, 1, 1)
        return GemmSpec(
            form, "dot", 0, 0, 0, t, w, operand_transpose_bytes(form, dtype)
        )
    # per-component operand bytes at the requested precision; the fp32
    # accumulator/output tile is always 4-byte
    ob = 2 if precision == "bf16" else real_bytes
    best: GemmSpec | None = None
    tbytes = operand_transpose_bytes(form, dtype, precision)
    for bm in BLOCK_CANDIDATES:
        for bn in BLOCK_CANDIDATES:
            for bk in BLOCK_CANDIDATES:
                if ob * (bm * bk + bk * bn) + 4 * bm * bn > (
                    VMEM_BUDGET_BYTES
                ):
                    continue  # working set must stay VMEM-resident
                t, w = modeled_step_time(
                    form, dtype, "pallas", bm, bn, bk, precision
                )
                if best is None or t < best.modeled_time_s:
                    best = GemmSpec(
                        form, "pallas", bm, bn, bk, t, w, tbytes, precision
                    )
                if not fused:
                    continue
                # fused candidate at the same targets: effective tiles are
                # the axis-suffix products, admissible while MXU-sized
                _, _, tm = suffix_tile_split(form.m_shape, bm)
                _, _, tn = suffix_tile_split(form.n_shape, bn)
                _, _, tk = suffix_tile_split(form.k_shape, bk)
                if min(tm, tn, tk) < min_kernel_dim:
                    continue
                if ob * (tm * tk + tk * tn) + 4 * tm * tn > (
                    VMEM_BUDGET_BYTES
                ):
                    continue
                tf, wf = modeled_step_time(
                    form, dtype, "pallas_fused", tm, tn, tk, precision
                )
                if tf < best.modeled_time_s:
                    best = GemmSpec(
                        form, "pallas_fused", tm, tn, tk, tf, wf, 0.0,
                        precision,
                    )
    return best


@dataclasses.dataclass
class LoweredSchedule:
    """Refined kernel schedule for every step of a ContractionPlan.

    ``precision_mode``/``fidelity_tol``/``predicted_amp_error`` record
    the mixed-precision assignment (see :mod:`repro.lowering.precision`):
    the mode the plan was built under, the XEB-fidelity budget it was
    certified against, and the forward error model's accumulated relative
    amplitude error over the bf16 nodes.  All default to the pure-fp32
    schedule."""

    specs: list[GemmSpec]
    dtype: str
    precision_mode: str = "fp32"
    fidelity_tol: float = 0.0
    predicted_amp_error: float = 0.0

    @property
    def modeled_time_s(self) -> float:
        """Modeled seconds for one slice (sum over steps)."""
        return sum(s.modeled_time_s for s in self.specs)

    def backend_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for s in self.specs:
            counts[s.backend] = counts.get(s.backend, 0) + 1
        return counts

    def precision_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for s in self.specs:
            counts[s.precision] = counts.get(s.precision, 0) + 1
        return counts

    def hbm_traffic_bytes(self) -> float:
        """Modeled HBM operand/output traffic for one slice, at each
        step's storage precision, including the materialized-transpose
        round trips (complex elements count both components via the
        native itemsize)."""
        return sum(
            step_traffic_bytes(s.form, self.dtype, s.precision)
            + s.transpose_bytes
            for s in self.specs
        )

    def pad_waste(self) -> float:
        """FLOPs-weighted padding fraction across the Pallas nodes."""
        useful = padded = 0.0
        for s in self.specs:
            if s.backend != "pallas":
                continue
            f = s.form.flops
            useful += f
            padded += f / (1.0 - s.pad_waste) if s.pad_waste < 1.0 else f
        return 0.0 if padded == 0.0 else 1.0 - useful / padded

    def transpose_bytes(self) -> float:
        """HBM bytes this schedule spends materializing operand
        permutations (per slice) — zero on fused/einsum nodes."""
        return sum(s.transpose_bytes for s in self.specs)

    def transpose_bytes_eliminated(self) -> float:
        """HBM bytes of operand-transpose traffic the fused nodes avoid
        (per slice): what the reference permute + ``tiled_matmul`` path
        would have moved for every ``pallas_fused`` node."""
        return sum(
            operand_transpose_bytes(s.form, self.dtype)
            for s in self.specs
            if s.backend == "pallas_fused"
        )

    def summary(self) -> dict:
        return {
            "nodes": len(self.specs),
            "backends": self.backend_counts(),
            "pad_waste": self.pad_waste(),
            "modeled_time_s": self.modeled_time_s,
            "transpose_bytes": self.transpose_bytes(),
            "transpose_bytes_eliminated": self.transpose_bytes_eliminated(),
            "dtype": self.dtype,
            "precision_mode": self.precision_mode,
            "precision_counts": self.precision_counts(),
            "predicted_amp_error": self.predicted_amp_error,
            "fidelity_tol": self.fidelity_tol,
        }

    def summary_row(self) -> str:
        c = self.backend_counts()
        per = " ".join(
            f"{k}={c[k]}"
            for k in ("pallas_fused", "pallas", "dot", "einsum")
            if k in c
        )
        pc = self.precision_counts()
        prec = (
            f" bf16={pc['bf16']}/{len(self.specs)}"
            f" amp_err={self.predicted_amp_error:.2e}"
            if pc.get("bf16")
            else ""
        )
        return (
            f"lowered[{self.dtype}]: {len(self.specs)} nodes ({per}) "
            f"pad_waste={self.pad_waste()*100:.1f}% "
            f"t_model={self.modeled_time_s:.3e}s/slice{prec}"
        )


def refine_schedule(
    steps: Sequence[tuple[Sequence, Sequence, Sequence]],
    size_of: Callable[[Hashable], int],
    dtype=jnp.complex64,
    *,
    min_kernel_dim: int = TPU_MXU,
    fused: bool | None = None,
) -> LoweredSchedule:
    """Lower + refine every ``(inds_a, inds_b, inds_out)`` step."""
    if fused is None:
        fused = default_fused()
    specs = [
        refine_step(
            lower_step(ia, ib, io, size_of), dtype,
            min_kernel_dim=min_kernel_dim, fused=fused,
        )
        for ia, ib, io in steps
    ]
    return LoweredSchedule(specs, str(jnp.dtype(dtype)))


def refine_tree_schedule(
    tree,
    smask: int = 0,
    dtype=jnp.complex64,
    *,
    min_kernel_dim: int = TPU_MXU,
    fused: bool | None = None,
) -> LoweredSchedule:
    """Refine the kernel schedule for every step of ``(tree, S)``
    directly from the contraction tree — planner-side usage (modeled
    benchmarks, cost projections) on instances too large to instantiate
    an executor plan for.  Mirrors the executor's step construction:
    sliced indices are fixed before lowering, the output index order
    follows ``pair_contract_inds``."""
    from ..core.executor import pair_contract_inds  # lazy: avoid cycle
    from ..core.tensor_network import bits

    space = tree.tn.space
    sliced_labels = {space.labels[b] for b in bits(smask)}
    open_set = frozenset(tree.tn.open_inds)
    node_inds = {
        i: tuple(ix for ix in tree.tn.inputs[i] if ix not in sliced_labels)
        for i in range(tree.tn.num_tensors)
    }
    steps = []
    for v in tree.contract_order():
        l, r = tree.children[v]
        _, out = pair_contract_inds(node_inds[l], node_inds[r], open_set)
        steps.append((node_inds[l], node_inds[r], out))
        node_inds[v] = out
    return refine_schedule(
        steps, tree.tn.size_of, dtype=dtype,
        min_kernel_dim=min_kernel_dim, fused=fused,
    )


# ----------------------------------------------------------------------
# fusion-boundary pass: greedy VMEM-resident chain growth along the
# schedule (the epilogue megakernel's planning half)
# ----------------------------------------------------------------------

# live-set ceiling for one fused chain: whole operands + scratch slots +
# output must be simultaneously VMEM-resident (vs ~16 MB/core), leaving
# headroom for the final output's store buffering.  Deliberately larger
# than the per-GEMM tile budget (VMEM_BUDGET_BYTES) — a chain replaces
# several kernels' working sets with one residency certified by the
# lifetime planner's linear scan.
CHAIN_VMEM_BUDGET_BYTES = 12 * 1024 * 1024
# batch cells are unrolled into per-cell MXU dots inside the megakernel;
# cap the unroll so open-batch sampling networks keep sane trace sizes
CHAIN_MAX_BATCH = 256


@dataclasses.dataclass(frozen=True)
class FusedChainSpec:
    """One planned VMEM-resident GEMM chain.

    ``positions`` are consecutive entries of one execution segment's step
    sequence (never crossing the prologue/epilogue boundary — chains are
    planned per segment); step ``t``'s carry operand is step ``t-1``'s
    output (``carry_side[t]`` ∈ {"l", "r"}, ``""`` at the head).
    ``external_nodes`` are the env keys the executor gathers as kernel
    operands (step 0's pair, then one non-carry operand per step);
    ``slot_ids``/``slot_elems`` are the scratch-slot assignment of the
    interior intermediates from the chain-local linear scan
    (:func:`repro.lowering.memory.chain_segment_plan`), and
    ``live_bytes`` is that scan's certified VMEM peak.

    The saved-traffic accounting keeps the two eliminations disjoint so
    nothing is double-charged: ``roundtrip_bytes_saved`` is the plain
    HBM write+read of each interior intermediate, while
    ``transpose_bytes_saved`` is only the *extra* permute-copy traffic
    the unfused backends would have paid (``GemmSpec.transpose_bytes``,
    already zero on fused/einsum steps) — a carry operand's transpose
    bandwidth is therefore counted once, not once per elimination.
    """

    segment: str
    positions: tuple[int, ...]
    nodes: tuple[tuple[int, int, int], ...]  # (lhs, rhs, out) env keys
    carry_side: tuple[str, ...]
    external_nodes: tuple[int, ...]
    out_node: int
    live_bytes: int
    slot_ids: tuple[int, ...]
    slot_elems: tuple[int, ...]
    roundtrip_bytes_saved: float
    transpose_bytes_saved: float
    # per-scratch-slot storage precision: "bf16" when every interior
    # intermediate assigned to the slot is consumed at bf16 (the slot is
    # then a bf16 VMEM buffer at half the bytes), "fp32" otherwise.
    # Empty (the default) means all-fp32 — pre-precision plans.
    slot_prec: tuple[str, ...] = ()

    @property
    def n_steps(self) -> int:
        return len(self.positions)

    @property
    def hbm_bytes_saved(self) -> float:
        """Modeled HBM bytes one execution of this chain avoids."""
        return self.roundtrip_bytes_saved + self.transpose_bytes_saved


@dataclasses.dataclass
class ChainPlan:
    """All fused chains planned for one ``(tree, S)`` schedule."""

    chains: tuple[FusedChainSpec, ...]
    vmem_budget: int

    def by_segment(self, name: str) -> dict[int, FusedChainSpec]:
        """start position → chain, for one segment's dispatch loop."""
        return {
            c.positions[0]: c for c in self.chains if c.segment == name
        }

    def segment_chains(self, name: str) -> list[FusedChainSpec]:
        return [c for c in self.chains if c.segment == name]

    @property
    def num_chains(self) -> int:
        return len(self.chains)

    @property
    def num_multi(self) -> int:
        """Chains fusing ≥ 2 steps (all of them, per the planner's
        ``min_len`` — kept explicit for reporting/regression gates)."""
        return sum(1 for c in self.chains if c.n_steps >= 2)

    def max_live_bytes(self) -> int:
        return max((c.live_bytes for c in self.chains), default=0)

    def hbm_bytes_saved(self, segment: str = "naive") -> float:
        """Modeled HBM bytes saved per execution of ``segment`` (for the
        epilogue that is once per slice)."""
        return sum(
            c.hbm_bytes_saved for c in self.chains if c.segment == segment
        )

    def modeled_time_saved_s(self, segment: str = "naive") -> float:
        """Per-execution seconds of HBM traffic the chains eliminate —
        the refiner cost-model correction for fused steps (their
        round-trip and transpose charges no longer apply)."""
        return self.hbm_bytes_saved(segment) / TPU_HBM_BW

    def summary(self) -> dict:
        return {
            "chains": self.num_chains,
            "multi_step_chains": self.num_multi,
            "max_chain_len": max(
                (c.n_steps for c in self.chains), default=0
            ),
            "max_live_bytes": self.max_live_bytes(),
            "vmem_budget": self.vmem_budget,
            "hbm_bytes_saved": {
                seg: self.hbm_bytes_saved(seg)
                for seg in sorted({c.segment for c in self.chains})
            },
        }


def _chainable(spec: GemmSpec, real_bytes: int) -> bool:
    """Whether one step may participate in a fused chain: fp32-component
    dtypes only (the kernel accumulates in fp32), at least one axis per
    operand/output (Pallas wants a real block; the refiner's degenerate
    scalar nodes stay unfused), bounded batch unroll."""
    f = spec.form
    return (
        real_bytes <= 4
        and len(f.inds_a) >= 1
        and len(f.inds_b) >= 1
        and len(f.inds_out) >= 1
        and f.B <= CHAIN_MAX_BATCH
    )


def _build_chain(
    segment: str,
    run: list[int],
    step_nodes,
    specs,
    nbytes: dict[int, int],
    itemsize: int,
    itemsize_of: dict[int, int] | None = None,
):
    """Assemble the FusedChainSpec (or its certification plan) for one
    candidate run of schedule positions.  Returns ``(spec, live_bytes)``.

    ``itemsize_of`` maps env keys to their *storage* itemsize when the
    precision planner stores some nodes as bf16 component pairs —
    ``nbytes`` is then precision-aware, and the scratch-slot element
    counts must divide by each node's own itemsize, not the schedule
    dtype's."""
    from .memory import chain_segment_plan  # lazy: avoid cycle

    def isz(v: int) -> int:
        return itemsize_of.get(v, itemsize) if itemsize_of else itemsize

    nodes = tuple(step_nodes[p] for p in run)
    carry_side = [""]
    externals = [nodes[0][0], nodes[0][1]]
    for t in range(1, len(nodes)):
        prev_out = nodes[t - 1][2]
        l, r, _ = nodes[t]
        if l == prev_out:
            carry_side.append("l")
            externals.append(r)
        else:
            carry_side.append("r")
            externals.append(l)
    out_node = nodes[-1][2]
    seg = chain_segment_plan(
        f"chain:{segment}:{run[0]}", tuple(externals), nodes, (out_node,),
        nbytes,
    )
    interior = [nodes[t][2] for t in range(len(nodes) - 1)]
    used = sorted({seg.slot_of[v] for v in interior})
    remap = {s: d for d, s in enumerate(used)}
    slot_ids = tuple(remap[seg.slot_of[v]] for v in interior)
    slot_bytes = [0] * len(used)
    slot_elems = [0] * len(used)
    slot_wide = [False] * len(used)
    for t, v in enumerate(interior):
        d = remap[seg.slot_of[v]]
        slot_bytes[d] = max(slot_bytes[d], nbytes[v])
        slot_elems[d] = max(slot_elems[d], nbytes[v] // isz(v))
        # the consuming step (t+1 within the run) fixes the interior's
        # storage precision; a slot is bf16 only if no occupant needs f32
        if specs[run[t + 1]].precision != "bf16":
            slot_wide[d] = True
    roundtrip = sum(2.0 * nbytes[v] for v in interior)
    transpose = sum(specs[p].transpose_bytes for p in run)
    spec = FusedChainSpec(
        segment=segment,
        positions=tuple(run),
        nodes=nodes,
        carry_side=tuple(carry_side),
        external_nodes=tuple(externals),
        out_node=out_node,
        live_bytes=seg.peak_bytes,
        slot_ids=slot_ids,
        slot_elems=tuple(slot_elems),
        roundtrip_bytes_saved=roundtrip,
        transpose_bytes_saved=transpose,
        slot_prec=tuple(
            "fp32" if wide else "bf16" for wide in slot_wide
        ),
    )
    return spec, seg.peak_bytes


def plan_chains(
    schedule: LoweredSchedule,
    step_nodes: Sequence[tuple[int, int, int]],
    segments: dict[str, tuple[int, ...]],
    nbytes: dict[int, int],
    *,
    vmem_budget: int = CHAIN_VMEM_BUDGET_BYTES,
    min_len: int = 2,
    itemsize_of: dict[int, int] | None = None,
) -> ChainPlan:
    """The fusion-boundary pass: greedily grow runs of adjacent steps
    along each segment's execution order while the certified live set —
    whole operands pinned, intermediates slot-assigned by the chain-local
    linear scan — fits the VMEM budget.

    ``step_nodes[p]`` are the ``(lhs, rhs, out)`` env keys of schedule
    position ``p``; ``segments`` maps each execution segment to its
    ordered positions, so a chain can never cross the prologue/epilogue
    boundary, and a segment *output* (the root, or a hoisted frontier
    buffer) can never be chain-interior — its consumer is outside the
    segment, so adjacency fails there by construction.  ``nbytes`` is the
    per-node buffer size from the memory plan (same dict for every
    segment); under a mixed-precision plan it is dtype-true (bf16-stored
    nodes at half bytes) and ``itemsize_of`` supplies each node's storage
    itemsize so scratch slots are sized in elements correctly — the
    CHAIN_VMEM_BUDGET_BYTES residency check thereby admits longer chains
    when interiors are bf16."""
    itemsize = int(jnp.dtype(schedule.dtype).itemsize)
    real_bytes = real_component_bytes(schedule.dtype)
    chains: list[FusedChainSpec] = []
    for name, positions in segments.items():
        i = 0
        while i < len(positions):
            p = positions[i]
            if not _chainable(schedule.specs[p], real_bytes):
                i += 1
                continue
            run = [p]
            j = i
            while j + 1 < len(positions):
                q = positions[j + 1]
                prev_out = step_nodes[run[-1]][2]
                if (
                    step_nodes[q][0] != prev_out
                    and step_nodes[q][1] != prev_out
                ):
                    break
                if not _chainable(schedule.specs[q], real_bytes):
                    break
                _, live = _build_chain(
                    name, run + [q], step_nodes, schedule.specs, nbytes,
                    itemsize, itemsize_of,
                )
                if live > vmem_budget:
                    break
                run.append(q)
                j += 1
            if len(run) >= min_len:
                spec, _ = _build_chain(
                    name, run, step_nodes, schedule.specs, nbytes,
                    itemsize, itemsize_of,
                )
                chains.append(spec)
            i = j + 1
    return ChainPlan(chains=tuple(chains), vmem_budget=vmem_budget)


def plan_tree_chains(
    tree,
    smask: int = 0,
    dtype=jnp.complex64,
    *,
    hoist: bool = True,
    fused: bool | None = None,
    vmem_budget: int = CHAIN_VMEM_BUDGET_BYTES,
) -> ChainPlan:
    """Planner-side chain plan for ``(tree, S)`` — the same pass the
    executor runs at plan construction, built directly from the tree
    (pinned regressions, modeled benchmarks; no ContractionPlan
    needed)."""
    from .memory import node_nbytes  # lazy: avoid cycle

    sched = refine_tree_schedule(tree, smask, dtype=dtype, fused=fused)
    order = tree.contract_order()
    step_nodes = tuple((*tree.children[v], v) for v in order)
    itemsize = jnp.dtype(dtype).itemsize
    nbytes = {
        v: node_nbytes(tree, v, smask, itemsize) for v in tree.emask
    }
    segments: dict[str, tuple[int, ...]] = {
        "naive": tuple(range(len(step_nodes)))
    }
    if hoist and smask and step_nodes:
        from .partition import partition_tree  # lazy: avoid cycle

        part = partition_tree(tree, smask)
        pos = {v: k for k, v in enumerate(order)}
        segments["prologue"] = tuple(pos[v] for v in part.invariant_nodes)
        segments["epilogue"] = tuple(pos[v] for v in part.epilogue_nodes)
    return plan_chains(
        sched, step_nodes, segments, nbytes, vmem_budget=vmem_budget
    )


def modeled_plan_time(
    tree,
    smask: int = 0,
    dtype=jnp.complex64,
    *,
    part=None,
    fused: bool | None = None,
    precision: str = "fp32",
    fidelity_tol: float | None = None,
) -> float:
    """Modeled wall seconds of *two-phase* execution for ``(tree, S)``:
    the refined prologue runs once, the refined epilogue ``2^|S|`` times.

    Objective evaluation without full plan compilation — no
    ``ContractionPlan`` (and no jit trace) is built, so the anytime
    co-optimizer can score candidates with ``objective="modeled_time"``
    directly from planner state.  ``part`` reuses a caller-held
    :class:`~repro.lowering.partition.TreePartition`.  ``precision``/
    ``fidelity_tol`` score with the mixed-precision assignment the plan
    would actually run under (see :mod:`repro.lowering.precision`)."""
    from ..core.tensor_network import popcount  # lazy: avoid cycle

    sched = refine_tree_schedule(tree, smask, dtype=dtype, fused=fused)
    if not smask:
        if precision != "fp32":
            from .precision import assign_precision  # lazy: avoid cycle

            sched = assign_precision(
                sched, mode=precision, fidelity_tol=fidelity_tol,
                fused=fused,
            )
        return sched.modeled_time_s
    if part is None:
        from .partition import partition_tree  # lazy: avoid cycle

        part = partition_tree(tree, smask)
    invariant = set(part.invariant_nodes)
    order = tree.contract_order()
    n_slices = 1 << popcount(smask)
    if precision != "fp32":
        from .precision import assign_precision  # lazy: avoid cycle

        epilogue = tuple(
            i for i, v in enumerate(order) if v not in invariant
        )
        sched = assign_precision(
            sched, mode=precision, fidelity_tol=fidelity_tol,
            epilogue_positions=epilogue, n_slices=n_slices, fused=fused,
        )
    prologue_t = sum(
        spec.modeled_time_s
        for v, spec in zip(order, sched.specs)
        if v in invariant
    )
    return prologue_t + (sched.modeled_time_s - prologue_t) * n_slices
