"""Adaptive tile refiner — the paper's Sec. V-B path refiner mapped to TPU.

On Sunway the refiner permutes/splits contraction indices until every
stem GEMM matches the SWTT fused-kernel tile requirements (8×8 kernels,
DMA-bandwidth roofline).  The TPU analogue implemented here makes three
per-node decisions over the normalized :class:`~repro.lowering.gemm_form.
GemmForm` of every contraction step:

  1. **backend** — Pallas ``tiled_matmul`` for MXU-sized GEMMs,
     ``jnp.dot`` (XLA batched dot_general) for sub-tile shapes where
     kernel padding would dominate, plain ``jnp.einsum`` for tiny or
     degenerate nodes where even the transpose/reshape plumbing costs
     more than the contraction;
  2. **block shapes** — (bm, bn, bk) snapped to multiples of the 128-wide
     MXU tile, chosen per node from a candidate ladder under the VMEM
     residency budget;
  3. **pad-vs-split** — for each candidate the model charges the padded
     FLOPs ``ceil(M/bm)·ceil(N/bn)·ceil(K/bk)`` tiles actually execute;
     picking a smaller block *splits* the GEMM into more, fuller tiles
     while a larger block *pads* — the candidate with the lower modeled
     time wins (the Sunway refiner's permute-or-pad choice).

The same per-node cost model (tile quantization capped by the HBM
roofline, complex traffic counted as Karatsuba's 3 real GEMMs) is summed
into ``LoweredSchedule.modeled_time_s``, which the API layer feeds back
into ``PlanReport.modeled_time_s`` so planner metrics reflect the
schedule that will actually execute.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Hashable, Sequence

import jax.numpy as jnp

from ..core.merging import TPU_HBM_BW, TPU_MXU, TPU_PEAK_FLOPS
from .gemm_form import GemmForm, lower_step, real_component_bytes

# candidate Pallas block edges (multiples of the MXU tile)
BLOCK_CANDIDATES = (128, 256, 512)
# VMEM residency budget for one (bm×bk + bk×bn + bm×bn) working set, fp32
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
# below this many real FLOPs a node stays on einsum — the reshape/
# transpose plumbing would cost more than the contraction itself
EINSUM_FLOPS_FLOOR = 2.0 ** 16
# effective peak for non-MXU lowerings (XLA dot_general / einsum on
# sub-tile shapes): mostly VPU + permute work, modeled at peak/8
NON_MXU_PEAK_FRACTION = 0.125


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """Refined, executable lowering of one contraction step."""

    form: GemmForm
    backend: str  # "pallas" | "dot" | "einsum"
    bm: int
    bn: int
    bk: int
    modeled_time_s: float
    pad_waste: float  # fraction of executed MXU FLOPs that are padding


def _ceil_to(x: float, t: int) -> float:
    return max(t, math.ceil(x / t) * t)


def _real_gemm_count(dtype, backend: str) -> int:
    """Real GEMMs per logical GEMM: Karatsuba runs 3, a naive complex
    product runs 4, real dtypes run 1."""
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        return 1
    return 3 if backend == "pallas" else 4


def modeled_step_time(
    form: GemmForm, dtype, backend: str, bm: int, bn: int, bk: int
) -> tuple[float, float]:
    """(seconds, pad_waste) for one execution of this step.

    Pallas is charged padded-tile FLOPs at full MXU peak; dot/einsum are
    charged exact FLOPs at the non-MXU effective peak.  Both are capped
    by the HBM roofline on the operand + output traffic.
    """
    n_real = _real_gemm_count(dtype, backend)
    flops = form.flops * n_real
    itemsize = jnp.dtype(dtype).itemsize
    traffic = itemsize * form.B * (
        form.M * form.K + form.K * form.N + form.M * form.N
    )
    t_mem = traffic / TPU_HBM_BW
    if backend == "pallas":
        padded = (
            2.0
            * form.B
            * _ceil_to(form.M, bm)
            * _ceil_to(form.N, bn)
            * _ceil_to(form.K, bk)
            * n_real
        )
        t_compute = padded / TPU_PEAK_FLOPS
        waste = 1.0 - flops / padded
    else:
        t_compute = flops / (TPU_PEAK_FLOPS * NON_MXU_PEAK_FRACTION)
        waste = 0.0
    return max(t_compute, t_mem), waste


def refine_step(
    form: GemmForm,
    dtype,
    *,
    min_kernel_dim: int = TPU_MXU,
) -> GemmSpec:
    """Pick backend + block shapes for one normalized contraction step."""
    real_bytes = real_component_bytes(dtype)
    if form.flops < EINSUM_FLOPS_FLOOR:
        t, w = modeled_step_time(form, dtype, "einsum", 1, 1, 1)
        return GemmSpec(form, "einsum", 0, 0, 0, t, w)
    # 64-bit components (float64 / complex128) would be silently
    # truncated by the fp32 Pallas accumulator — keep them on XLA's dot.
    if min(form.M, form.N, form.K) < min_kernel_dim or real_bytes > 4:
        t, w = modeled_step_time(form, dtype, "dot", 1, 1, 1)
        return GemmSpec(form, "dot", 0, 0, 0, t, w)
    best: GemmSpec | None = None
    for bm in BLOCK_CANDIDATES:
        for bn in BLOCK_CANDIDATES:
            for bk in BLOCK_CANDIDATES:
                if 4 * (bm * bk + bk * bn + bm * bn) > VMEM_BUDGET_BYTES:
                    continue  # working set must stay VMEM-resident
                t, w = modeled_step_time(form, dtype, "pallas", bm, bn, bk)
                if best is None or t < best.modeled_time_s:
                    best = GemmSpec(form, "pallas", bm, bn, bk, t, w)
    return best


@dataclasses.dataclass
class LoweredSchedule:
    """Refined kernel schedule for every step of a ContractionPlan."""

    specs: list[GemmSpec]
    dtype: str

    @property
    def modeled_time_s(self) -> float:
        """Modeled seconds for one slice (sum over steps)."""
        return sum(s.modeled_time_s for s in self.specs)

    def backend_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for s in self.specs:
            counts[s.backend] = counts.get(s.backend, 0) + 1
        return counts

    def pad_waste(self) -> float:
        """FLOPs-weighted padding fraction across the Pallas nodes."""
        useful = padded = 0.0
        for s in self.specs:
            if s.backend != "pallas":
                continue
            f = s.form.flops
            useful += f
            padded += f / (1.0 - s.pad_waste) if s.pad_waste < 1.0 else f
        return 0.0 if padded == 0.0 else 1.0 - useful / padded

    def summary(self) -> dict:
        return {
            "nodes": len(self.specs),
            "backends": self.backend_counts(),
            "pad_waste": self.pad_waste(),
            "modeled_time_s": self.modeled_time_s,
            "dtype": self.dtype,
        }

    def summary_row(self) -> str:
        c = self.backend_counts()
        per = " ".join(f"{k}={c[k]}" for k in ("pallas", "dot", "einsum") if k in c)
        return (
            f"lowered[{self.dtype}]: {len(self.specs)} nodes ({per}) "
            f"pad_waste={self.pad_waste()*100:.1f}% "
            f"t_model={self.modeled_time_s:.3e}s/slice"
        )


def refine_schedule(
    steps: Sequence[tuple[Sequence, Sequence, Sequence]],
    size_of: Callable[[Hashable], int],
    dtype=jnp.complex64,
    *,
    min_kernel_dim: int = TPU_MXU,
) -> LoweredSchedule:
    """Lower + refine every ``(inds_a, inds_b, inds_out)`` step."""
    specs = [
        refine_step(
            lower_step(ia, ib, io, size_of), dtype,
            min_kernel_dim=min_kernel_dim,
        )
        for ia, ib, io in steps
    ]
    return LoweredSchedule(specs, str(jnp.dtype(dtype)))
