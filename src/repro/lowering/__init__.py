"""GEMM lowering subsystem: contraction trees → executable kernel schedules.

The paper's Sec. V pipeline on Sunway is  *contraction → fused GEMM →
adaptive path refiner → kernel schedule*; this package is the TPU/Pallas
port of that bridge between the planner and the kernels:

  gemm_form — normalize each pairwise contraction into
              transpose→reshape→GEMM→reshape form (batch/M/N/K index
              classification; open sampling indices ride as batch axes,
              sliced indices are fixed before lowering)
  refiner   — the Sec. V-B adaptive refiner for TPU: per-node backend
              choice (Pallas tiled_matmul / jnp.dot / jnp.einsum),
              MXU-128-snapped block shapes, pad-vs-split decisions, and
              the per-node cost model fed back into PlanReport
  cache     — compiled-plan LRU keyed by a canonical network
              fingerprint (structure + dtype + open indices + planner
              params), so repeated requests for the same circuit family
              skip planning and retracing; plus the hoisted-prologue LRU
              keyed by leaf-array fingerprint
  partition — lifetime-based two-phase split (Sec. III interpretation):
              slice-invariant prologue vs slice-dependent epilogue, the
              hoisted buffer frontier between them, and the executed-FLOPs
              accounting that turns Eq. 4 into a runtime win
  memory    — lifetime-based buffer planner: linear-scan slot assignment
              over step lifetimes, exact live-set peaks per execution
              segment (naive / prologue / epilogue), deterministic free
              schedules and donation hints; feeds PlanReport and the
              peak-aware slicer mode
  precision — mixed-precision planner: per-node bf16-input/fp32-
              accumulate demotion under a forward amplitude-error model
              certified against a Linear-XEB fidelity tolerance
              (REPRO_PRECISION / fidelity_tol), plus the per-node
              storage-itemsize maps that make the memory planner and
              peak-aware slicer dtype-true

Sunway→TPU mapping of the refiner, for the record: SWTT 8×8 fused-GEMM
kernel quantization → MXU 128×128 tile quantization; LDM residency →
VMEM residency budget; DMA-bandwidth roofline → HBM roofline;
fp16-compute/fp32-accumulate → bf16/fp32 ``preferred_element_type``;
the permute-or-pad index rewrite → per-node pad-vs-split block choice.
"""

from .cache import (  # noqa: F401
    PLAN_CACHE,
    HoistCache,
    PlanCache,
    PlanEntry,
    leaf_fingerprint,
    leaf_key,
    network_fingerprint,
)
from .gemm_form import GemmForm, apply, apply_chain, lower_step  # noqa: F401
from .memory import (  # noqa: F401
    MemoryPlan,
    SegmentPlan,
    chain_segment_plan,
    node_nbytes,
    peak_bytes,
    plan_memory,
)
from .partition import TreePartition, partition_tree  # noqa: F401
from .precision import (  # noqa: F401
    DEFAULT_FIDELITY_TOL,
    PRECISION_MODES,
    assign_precision,
    default_precision,
    node_amp_error,
    storage_itemsizes,
    tree_storage_itemsizes,
)
from .refiner import (  # noqa: F401
    CHAIN_VMEM_BUDGET_BYTES,
    ChainPlan,
    FusedChainSpec,
    GemmSpec,
    LoweredSchedule,
    default_fused,
    default_megakernel,
    modeled_step_time,
    operand_transpose_bytes,
    plan_chains,
    plan_tree_chains,
    refine_schedule,
    refine_step,
    refine_tree_schedule,
)
