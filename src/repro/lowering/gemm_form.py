"""GEMM normalization: pairwise contraction → transpose/reshape/GEMM form.

The paper's Sec. V-A observation is that every stem contraction *is* a
GEMM once its indices are classified; the Sunway runtime rewrites each
pairwise contraction into a fused transpose→GEMM so the hot loop never
executes a generic einsum.  This module is the TPU analogue of that
rewrite: given the (ordered) index tuples of one contraction step it
classifies every index into one of four GEMM roles,

  batch  — shared by both operands AND kept in the output (open sampling
           indices that ride through both children; lowered as the
           leading batch axis of a batched GEMM),
  M      — kept indices exclusive to the left operand,
  N      — kept indices exclusive to the right operand,
  K      — contracted indices (shared, absent from the output),

and emits a static :class:`GemmForm`: two input permutations, the
(B, M, K) / (B, K, N) collapse shapes, and the output permutation that
restores the executor's index-order convention.  Sliced indices never
reach this layer — the executor fixes them on the leaf arrays before any
step runs — so a slicing mask ``S`` only shrinks the shapes seen here
(the "sliced indices as fixed axes" half of the paper's rewrite).

:func:`apply` executes a refined step (:class:`~repro.lowering.refiner.
GemmSpec`) inside the jitted slice program.  Complex operands on the
Pallas backend route through the 3-real-GEMM Karatsuba in
:mod:`repro.kernels.ops`; tiny/degenerate nodes keep the original einsum
string so lowering is total over arbitrary trees.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Hashable, Sequence

import jax
import jax.numpy as jnp


def real_component_bytes(dtype) -> int:
    """Byte width of one real component (complex64 → 4, complex128 → 8).

    The single source of the Pallas-safety policy: components wider than
    4 bytes must not run through the fp32-accumulating kernel — the
    refiner routes them off Pallas at plan time and :func:`apply`
    re-checks the concrete arrays at trace time.
    """
    dt = jnp.dtype(dtype)
    return (
        dt.itemsize // 2 if jnp.issubdtype(dt, jnp.complexfloating)
        else dt.itemsize
    )


@dataclasses.dataclass(frozen=True)
class GemmForm:
    """Static lowering of one pairwise contraction to batched-GEMM form."""

    inds_a: tuple
    inds_b: tuple
    inds_out: tuple
    batch_inds: tuple
    m_inds: tuple
    n_inds: tuple
    k_inds: tuple
    perm_a: tuple[int, ...]  # a axes → (batch..., m..., k...)
    perm_b: tuple[int, ...]  # b axes → (batch..., k..., n...)
    out_perm: tuple[int, ...]  # (batch..., m..., n...) → inds_out order
    batch_shape: tuple[int, ...]
    m_shape: tuple[int, ...]
    n_shape: tuple[int, ...]
    k_shape: tuple[int, ...]
    expr: str  # einsum fallback for the same step

    @property
    def B(self) -> int:
        return math.prod(self.batch_shape)

    @property
    def M(self) -> int:
        return math.prod(self.m_shape)

    @property
    def N(self) -> int:
        return math.prod(self.n_shape)

    @property
    def K(self) -> int:
        return math.prod(self.k_shape)

    @property
    def flops(self) -> float:
        """Real-valued multiply-add count of the un-padded GEMM."""
        return 2.0 * self.B * self.M * self.N * self.K


def lower_step(
    inds_a: Sequence[Hashable],
    inds_b: Sequence[Hashable],
    inds_out: Sequence[Hashable],
    size_of: Callable[[Hashable], int],
) -> GemmForm:
    """Classify one pairwise contraction into GEMM roles.

    ``inds_out`` must follow the executor's convention (kept indices of
    ``a`` in order, then kept indices of ``b`` not already present), i.e.
    the output of :func:`repro.core.executor.pair_contract_inds`.
    """
    set_a, set_b = set(inds_a), set(inds_b)
    out_set = set(inds_out)
    batch = tuple(ix for ix in inds_a if ix in set_b and ix in out_set)
    k_inds = tuple(ix for ix in inds_a if ix in set_b and ix not in out_set)
    m_inds = tuple(ix for ix in inds_a if ix not in set_b)
    n_inds = tuple(ix for ix in inds_b if ix not in set_a)

    pos_a = {ix: i for i, ix in enumerate(inds_a)}
    pos_b = {ix: i for i, ix in enumerate(inds_b)}
    perm_a = tuple(pos_a[ix] for ix in batch + m_inds + k_inds)
    perm_b = tuple(pos_b[ix] for ix in batch + k_inds + n_inds)

    natural = batch + m_inds + n_inds
    if set(natural) != out_set or len(natural) != len(inds_out):
        raise ValueError(
            f"output {inds_out!r} is not a permutation of batch+M+N "
            f"{natural!r}"
        )
    nat_pos = {ix: i for i, ix in enumerate(natural)}
    out_perm = tuple(nat_pos[ix] for ix in inds_out)

    from ..core.executor import einsum_expr  # shared labeling convention

    try:
        expr = einsum_expr(inds_a, inds_b, inds_out)
    except IndexError:
        # more distinct indices than einsum subscript letters — only
        # possible on paper-scale planning-only nodes (>= 2^52 FLOPs),
        # which the refiner always routes to GEMM backends; the einsum
        # fallback string is never consulted for them.
        expr = ""
    return GemmForm(
        inds_a=tuple(inds_a),
        inds_b=tuple(inds_b),
        inds_out=tuple(inds_out),
        batch_inds=batch,
        m_inds=m_inds,
        n_inds=n_inds,
        k_inds=k_inds,
        perm_a=perm_a,
        perm_b=perm_b,
        out_perm=out_perm,
        batch_shape=tuple(size_of(ix) for ix in batch),
        m_shape=tuple(size_of(ix) for ix in m_inds),
        n_shape=tuple(size_of(ix) for ix in n_inds),
        k_shape=tuple(size_of(ix) for ix in k_inds),
        expr=expr,
    )


def apply(spec, a: jax.Array, b: jax.Array, *, interpret: bool | None = None):
    """Execute one refined step (``spec`` is a refiner ``GemmSpec``).

    Trace-safe: shapes and the backend choice are static, so this runs
    unchanged under ``jit``, the executor's slice-batch ``vmap``, and
    ``shard_map``.
    """
    form: GemmForm = spec.form
    if spec.backend == "einsum":
        return jnp.einsum(form.expr, a, b)
    real_bytes = real_component_bytes(jnp.result_type(a.dtype, b.dtype))
    if spec.backend == "pallas_fused" and real_bytes <= 4:
        from ..kernels import ops

        # operands stay in their tree-native layouts: the kernel's
        # index_maps apply perm_a/perm_b during tile loads, so the a2/b2
        # HBM copies below never exist on this path.
        out = ops.fused_matmul(
            a, b,
            perm_a=form.perm_a, perm_b=form.perm_b,
            nb=len(form.batch_inds), nm=len(form.m_inds),
            nn=len(form.n_inds), nk=len(form.k_inds),
            bm=spec.bm, bn=spec.bn, bk=spec.bk,
            interpret=interpret,
            precision=getattr(spec, "precision", "fp32"),
        )
    else:
        a2 = jnp.transpose(a, form.perm_a).reshape(form.B, form.M, form.K)
        b2 = jnp.transpose(b, form.perm_b).reshape(form.B, form.K, form.N)
        if spec.backend == "dot" or real_bytes > 4:
            # 64-bit components handed to a schedule refined for a
            # narrower dtype would be silently truncated by the fp32
            # Pallas accumulator — keep them on XLA's full-precision dot
            # (this also catches a pallas_fused spec handed 64-bit
            # arrays at trace time).
            out = jnp.matmul(a2, b2)
        elif spec.backend == "pallas":
            from ..kernels import ops

            mm = functools.partial(
                ops.matmul,
                bm=spec.bm,
                bn=spec.bn,
                bk=spec.bk,
                interpret=interpret,
                min_kernel_dim=1,  # the refiner already gated tiny shapes
                precision=getattr(spec, "precision", "fp32"),
            )
            if form.B > 1:
                out = jax.vmap(mm)(a2, b2)
            else:
                out = mm(a2[0], b2[0])[None]
        else:
            raise ValueError(f"unknown lowering backend {spec.backend!r}")
    out = out.reshape(form.batch_shape + form.m_shape + form.n_shape)
    if form.out_perm != tuple(range(out.ndim)):
        out = jnp.transpose(out, form.out_perm)
    return out


def apply_chain(
    chain, specs, operands, *, interpret: bool | None = None,
    use_kernel: bool | None = None,
):
    """Execute one fused chain (``chain`` is a refiner
    :class:`~repro.lowering.refiner.FusedChainSpec`, ``specs`` the
    GemmSpecs of its steps, ``operands`` the external buffers in
    ``chain.external_nodes`` order) as a single megakernel call.

    Trace-safe like :func:`apply` — the chain metadata is static — so the
    same dispatch serves the vmapped slice scan, ``shard_map``, and the
    resumable per-slice path.  64-bit components handed to a schedule
    refined for a narrower dtype fall back to the sequential per-step
    :func:`apply` (same trace-time guard as the single-step path: the
    fp32 chain kernel would silently truncate them)."""
    dt = jnp.result_type(*[o.dtype for o in operands])
    if real_component_bytes(dt) > 4:
        carry = apply(
            specs[0], operands[0], operands[1], interpret=interpret
        )
        for t in range(1, len(specs)):
            ext = operands[t + 1]
            a, b = (
                (carry, ext) if chain.carry_side[t] == "l" else (ext, carry)
            )
            carry = apply(specs[t], a, b, interpret=interpret)
        return carry
    from ..kernels import ops

    return ops.fused_chain(
        operands,
        forms=tuple(s.form for s in specs),
        carry_side=chain.carry_side,
        slot_ids=chain.slot_ids,
        slot_elems=chain.slot_elems,
        interpret=interpret,
        use_kernel=use_kernel,
        precisions=tuple(getattr(s, "precision", "fp32") for s in specs),
        slot_prec=getattr(chain, "slot_prec", None) or None,
    )
