"""Compiled-plan cache — the first serving-scale primitive.

Planning an RQC contraction (pathfinding, slicing, tuning, merging,
lowering) costs seconds while executing one slice costs milliseconds, so
a serving deployment that re-plans per request wastes almost all of its
wall time.  Production circuit families are *structurally* repetitive:
two amplitude requests for the same circuit with different bitstrings
produce tensor networks that differ only in leaf values, never in
structure.  This module keys a cache on that structure:

  * :func:`network_fingerprint` canonicalizes a
    :class:`~repro.core.tensor_network.TensorNetwork` by renaming every
    index to its first-appearance ordinal (so arbitrary user labels hash
    identically), then SHA-256s the structure + per-index sizes + open
    indices + array dtype;
  * a :class:`PlanCache` (thread-safe LRU) maps
    ``(fingerprint, planner/lowering parameters)`` to the fully planned
    artifact: the tree, the slicing mask ``S``, the refined
    :class:`~repro.lowering.refiner.LoweredSchedule`, and the live
    ``ContractionPlan`` object — whose memoized jitted executables ride
    along, so a cache hit skips planning *and* retracing.

The slicing mask is part of the cached value rather than the key because
``S`` is a deterministic function of (structure, planner parameters);
including the planner parameters in the key therefore pins ``S`` exactly
as the schedule was refined for.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from collections import OrderedDict
from typing import Any, Hashable, Sequence

from ..obs import metrics as _metrics


def network_fingerprint(tn, dtype=None, extra: tuple = ()) -> str:
    """Canonical SHA-256 fingerprint of a tensor network's structure.

    Invariant under index relabeling: labels are replaced by their
    first-appearance ordinal scanning ``tn.inputs`` in order.  ``extra``
    lets callers fold planner parameters into the digest.
    """
    rename: dict[Hashable, int] = {}

    def rid(ix) -> int:
        if ix not in rename:
            rename[ix] = len(rename)
        return rename[ix]

    structure = tuple(tuple(rid(ix) for ix in t) for t in tn.inputs)
    open_ids = tuple(rid(ix) for ix in tn.open_inds)
    sizes = tuple(tn.size_of(ix) for ix in rename)
    payload = repr((structure, open_ids, sizes, str(dtype), extra))
    return hashlib.sha256(payload.encode()).hexdigest()


def leaf_fingerprint(arrays: Sequence, indices: Sequence[int] | None = None) -> str:
    """SHA-256 over the *values* of selected leaf arrays.

    Two-phase execution materializes the slice-invariant prologue once
    and reuses it for every slice; this fingerprint is what makes that
    reuse safe across *calls*: the hoisted tensors are a pure function of
    the prologue's leaf arrays, so they can be served from an LRU keyed
    by this digest (e.g. repeated sampler calls on the same open-qubit
    batch network reuse the hoisted stem).  ``indices`` restricts the
    digest to the leaves the prologue actually consumes, so epilogue-only
    value changes (different sliced-leaf projections) still hit.

    Value hashing forces a device→host transfer for device-resident
    arrays — callers on the hot path should use :func:`leaf_key`, which
    keys device arrays by buffer identity instead."""
    import numpy as np

    h = hashlib.sha256()
    for i in range(len(arrays)) if indices is None else indices:
        a = np.asarray(arrays[i])
        h.update(repr((int(i), a.shape, str(a.dtype))).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def leaf_key(
    arrays: Sequence, indices: Sequence[int] | None = None
) -> tuple[str, tuple]:
    """Cache key over leaf arrays that never forces a host transfer.

    Device-resident ``jax.Array`` leaves are keyed by shape/dtype plus
    the *committed buffer's identity* (``id`` of the immutable array
    object): the same array object always holds the same values, so
    identity subsumes value equality without touching the bytes.  Host
    arrays (numpy and anything else) fall back to
    :func:`leaf_fingerprint`-style value hashing — they are cheap to
    hash and have no stable buffer identity.

    Returns ``(digest, keepalive)``.  **The caller must store
    ``keepalive`` alongside the cache entry**: it pins the identity-keyed
    arrays so their ``id`` cannot be recycled by the allocator while the
    entry is alive (a recycled id would alias a different buffer onto a
    stale cache hit).  Equal-valued but distinct device arrays therefore
    miss — the safe direction; a miss only costs one prologue
    re-materialization."""
    import jax
    import numpy as np

    h = hashlib.sha256()
    keepalive = []
    for i in range(len(arrays)) if indices is None else indices:
        a = arrays[i]
        if isinstance(a, jax.Array) and not isinstance(a, jax.core.Tracer):
            h.update(
                repr(
                    ("dev", int(i), a.shape, str(a.dtype), id(a))
                ).encode()
            )
            keepalive.append(a)
        else:
            a = np.asarray(a)
            h.update(repr(("host", int(i), a.shape, str(a.dtype))).encode())
            h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest(), tuple(keepalive)


@dataclasses.dataclass
class PlanEntry:
    """Cached planning artifact for one (network family, params) key."""

    plan: Any  # ContractionPlan (carries tree, smask, schedule, jit cache)
    report: Any  # PlanReport template from the original planning run


class PlanCache:
    """Thread-safe LRU cache of compiled contraction plans."""

    #: prefix for the obs counters this cache bumps (``<prefix>.hits`` /
    #: ``<prefix>.misses``); subclasses override so their traffic is
    #: attributable separately in a metrics snapshot.
    _metric = "plan_cache"

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._entries: OrderedDict[str, PlanEntry] = OrderedDict()
        self._lock = threading.Lock()
        self._inflight: dict[str, threading.Event] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> PlanEntry | None:
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.misses += 1
                _metrics.inc(f"{self._metric}.misses")
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            _metrics.inc(f"{self._metric}.hits")
            return ent

    def put(self, key: str, entry: PlanEntry) -> None:
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)

    def single_flight(self, key: str, factory):
        """Return the entry for ``key``, computing it at most once across
        concurrent threads.

        The first thread to miss becomes the *leader*: it runs
        ``factory()`` outside the lock (planning takes seconds — holding
        the lock would serialize unrelated families) and publishes the
        result with :meth:`put`.  Threads that miss while the key is in
        flight wait on the leader's event instead of replanning — under
        threaded serving dispatch, N concurrent requests for a new
        circuit family cost ONE planning run, not N.  A leader whose
        factory raises wakes the waiters and clears the in-flight mark;
        the next waiter retries as the new leader, so a transient
        planning failure never wedges the key.  Waiters count as hits
        (they were served from cached work), the leader as the one miss.
        """
        while True:
            with self._lock:
                ent = self._entries.get(key)
                if ent is not None:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    _metrics.inc(f"{self._metric}.hits")
                    return ent
                ev = self._inflight.get(key)
                leader = ev is None
                if leader:
                    ev = self._inflight[key] = threading.Event()
                    self.misses += 1
                    _metrics.inc(f"{self._metric}.misses")
            if leader:
                try:
                    value = factory()
                    self.put(key, value)
                    return value
                finally:
                    with self._lock:
                        self._inflight.pop(key, None)
                    ev.set()
            else:
                ev.wait()
                # loop: entry present on leader success; leader failure
                # promotes this waiter to leader on the next pass

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._entries),
                "maxsize": self.maxsize,
                "hits": self.hits,
                "misses": self.misses,
            }


class HoistCache(PlanCache):
    """LRU of materialized slice-invariant prologue tensors, keyed by
    :func:`leaf_key` of the prologue's leaf arrays (device buffers by
    identity — no host transfer; host arrays by value).

    One instance lives on each :class:`~repro.core.executor.
    ContractionPlan` (the hoisted buffers are only meaningful for that
    plan's partition); the stored value is ``(outputs, keepalive,
    replicated)`` — the hoisted device arrays in
    ``partition.hoisted_nodes`` order, the key's keep-alive references
    (which must live exactly as long as the entry so identity keys can
    never alias recycled buffers), and a per-``Mesh`` dict of the
    replicated device-put copies ``contract_sharded`` broadcasts, so a
    plan-cache hit reuses the already-placed buffers instead of
    re-broadcasting them every invocation.

    Entries hold keep-alive references to *device buffers*, so eviction
    is what releases device memory: dropping the ``(outputs, keepalive)``
    tuple drops the only cache-held references (verified against
    ``jax.live_arrays`` in tests).  Beyond the entry-count ``maxsize``,
    an optional ``max_bytes`` bounds the summed ``outputs`` bytes —
    oldest entries are evicted until the total fits (the newest entry is
    always kept, even when it alone exceeds the bound: a best-effort LRU
    bound, not an admission policy)."""

    _metric = "hoist_cache"

    def __init__(self, maxsize: int = 8, max_bytes: int | None = None):
        super().__init__(maxsize=maxsize)
        self.max_bytes = max_bytes
        self._entry_bytes: OrderedDict[str, int] = OrderedDict()
        self.total_bytes = 0
        self.evictions = 0
        self.evicted_bytes = 0

    @staticmethod
    def entry_nbytes(value) -> int:
        outputs = value[0]
        n = sum(int(getattr(a, "nbytes", 0)) for a in outputs)
        if len(value) > 2:  # replicated per-mesh copies count too
            for placed in value[2].values():
                n += sum(int(getattr(a, "nbytes", 0)) for a in placed)
        return n

    def put(self, key: str, value) -> None:
        nbytes = self.entry_nbytes(value)
        with self._lock:
            old = self._entry_bytes.pop(key, 0)
            self.total_bytes -= old
            self._entries[key] = value
            self._entries.move_to_end(key)
            self._entry_bytes[key] = nbytes
            self.total_bytes += nbytes
            while len(self._entries) > 1 and (
                len(self._entries) > self.maxsize
                or (
                    self.max_bytes is not None
                    and self.total_bytes > self.max_bytes
                )
            ):
                evicted, _ = self._entries.popitem(last=False)
                freed = self._entry_bytes.pop(evicted)
                self.total_bytes -= freed
                self.evictions += 1
                self.evicted_bytes += freed
                _metrics.inc(f"{self._metric}.evictions")
                _metrics.inc(f"{self._metric}.evicted_bytes", freed)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._entry_bytes.clear()
            self.total_bytes = 0
            self.hits = 0
            self.misses = 0
            self.evictions = 0
            self.evicted_bytes = 0

    def stats(self) -> dict:
        out = super().stats()
        with self._lock:
            out.update(
                total_bytes=self.total_bytes,
                max_bytes=self.max_bytes,
                evictions=self.evictions,
                evicted_bytes=self.evicted_bytes,
            )
        return out


#: process-global cache used by :mod:`repro.core.api`
PLAN_CACHE = PlanCache(
    maxsize=int(os.environ.get("REPRO_PLAN_CACHE_SIZE", "64"))
)
