"""Lifetime-based two-phase partition of a sliced contraction tree.

The paper's central interpretability claim (Sec. III, Eq. 4) is that
slicing overhead is *localized*: only the contractions whose
lifetime-closure touches a sliced index change across the ``2^|S|``
subtasks.  Everything else — branch subtrees and stem segments untouched
by ``S`` — computes the exact same tensors in every subtask, so a naive
executor recomputes them ``2^|S|`` times for nothing.

This module turns that observation into an executable split.  Given a
:class:`~repro.core.contraction_tree.ContractionTree` and a slicing mask,
:func:`partition_tree` classifies every node via
:func:`repro.core.lifetime.lifetime_closure` and emits a
:class:`TreePartition`:

  * the **prologue** — slice-invariant internal nodes, executed once per
    plan with the full (unsliced) leaf arrays;
  * the **epilogue** — slice-dependent nodes, the only contractions run
    (and vmapped) inside the slice loop;
  * the **hoisted frontier** — maximal invariant subtree roots whose
    parent is slice-dependent: their materialized tensors are the buffer
    interface handed from the prologue to every epilogue invocation.

The partition also carries the executed-FLOPs accounting that makes the
runtime win measurable: ``hoisted_overhead() <= slicing_overhead`` (Eq.
4) always, with equality only when no node is invariant.
"""

from __future__ import annotations

import dataclasses

from ..core.contraction_tree import ContractionTree
from ..core.lifetime import lifetime_closure
from ..core.tensor_network import popcount


@dataclasses.dataclass(frozen=True)
class TreePartition:
    """Two-phase (prologue/epilogue) split of one ``(tree, S)`` pair.

    Node lists are in contraction (post-)order, so executing
    ``invariant_nodes`` then, per slice, ``epilogue_nodes`` respects every
    data dependency; ``hoisted_nodes ⊆ invariant_nodes`` is the cross-phase
    buffer interface (each one's parent is slice-dependent)."""

    smask: int
    num_sliced: int
    dependent: frozenset[int]  # lifetime-closure of S (leaves + internal)
    invariant_nodes: tuple[int, ...]  # prologue, contract order
    epilogue_nodes: tuple[int, ...]  # per-slice, contract order
    hoisted_nodes: tuple[int, ...]  # prologue outputs consumed per slice
    prologue_leaves: tuple[int, ...]  # leaves consumed by the prologue
    epilogue_leaves: tuple[int, ...]  # leaves consumed inside the slice loop
    invariant_cost: float  # sum of 2^|s_node| over invariant nodes
    per_slice_cost: float  # dependent cost of ONE subtask (Eq. 6 / 2^|S|)
    total_cost: float  # dense C(B) (Eq. 3)

    @property
    def n_slices(self) -> int:
        return 1 << self.num_sliced

    @property
    def invariant_fraction(self) -> float:
        """Fraction of the dense tree cost C(B) that is slice-invariant,
        i.e. hoistable out of the slice loop."""
        return self.invariant_cost / self.total_cost if self.total_cost else 0.0

    def hoisted_cost(self) -> float:
        """Executed FLOPs (in the paper's 2^|s| cost units) of two-phase
        execution: one prologue plus 2^|S| epilogues."""
        return self.invariant_cost + self.n_slices * self.per_slice_cost

    def naive_cost(self) -> float:
        """Eq. 6: what a naive executor runs — the full tree per slice."""
        return self.invariant_cost * self.n_slices + (
            self.n_slices * self.per_slice_cost
        )

    def hoisted_overhead(self) -> float:
        """Executed-FLOPs overhead of two-phase execution over the dense
        C(B) — the runtime counterpart of Eq. 4, always <= the naive
        ``tree.slicing_overhead(S)``."""
        return self.hoisted_cost() / self.total_cost if self.total_cost else 1.0

    def summary(self) -> dict:
        return {
            "num_sliced": self.num_sliced,
            "invariant_nodes": len(self.invariant_nodes),
            "epilogue_nodes": len(self.epilogue_nodes),
            "hoisted_buffers": len(self.hoisted_nodes),
            "invariant_fraction": self.invariant_fraction,
            "hoisted_overhead": self.hoisted_overhead(),
        }


def partition_tree(tree: ContractionTree, smask: int) -> TreePartition:
    """Classify every tree node as slice-invariant or slice-dependent and
    build the two-phase execution partition for ``(tree, smask)``."""
    dependent = lifetime_closure(tree, smask)
    order = tree.contract_order()
    invariant_nodes = tuple(v for v in order if v not in dependent)
    epilogue_nodes = tuple(v for v in order if v in dependent)

    # maximal invariant subtree roots: invariant internal nodes whose
    # parent runs in the slice loop (the root only qualifies when S is
    # empty, in which case the "prologue" is the whole tree).
    hoisted = tuple(
        v
        for v in invariant_nodes
        if tree.parent.get(v) is None or tree.parent[v] in dependent
    )
    prologue_leaves: list[int] = []
    epilogue_leaves: list[int] = []
    for i in range(tree.tn.num_tensors):
        p = tree.parent.get(i)
        if p is not None and p not in dependent:
            prologue_leaves.append(i)
        else:
            # sliced leaves (dependent themselves) and invariant leaves
            # feeding a dependent contraction both enter the slice loop;
            # the latter pass through unsliced (their slice spec is empty).
            epilogue_leaves.append(i)

    invariant_cost = per_slice = total = 0.0
    for v in tree.children:
        nm = tree.node_mask(v)
        c = 2.0 ** popcount(nm)
        total += c
        if v in dependent:
            per_slice += 2.0 ** (popcount(nm) - popcount(nm & smask))
        else:
            invariant_cost += c
    return TreePartition(
        smask=smask,
        num_sliced=popcount(smask),
        dependent=frozenset(dependent),
        invariant_nodes=invariant_nodes,
        epilogue_nodes=epilogue_nodes,
        hoisted_nodes=hoisted,
        prologue_leaves=tuple(prologue_leaves),
        epilogue_leaves=tuple(epilogue_leaves),
        invariant_cost=invariant_cost,
        per_slice_cost=per_slice,
        total_cost=total,
    )
