"""Mixed-precision planning under an XEB error budget — Sec. VI's
single-precision leg mapped to TPU bf16.

The paper's 308.6 Pflops headline is single-precision: the Sunway
kernels compute in reduced precision and accumulate wide, and Huang et
al. (arXiv 2005.06787) show such "frugal" precision is admissible for
supremacy-circuit simulation whenever the induced amplitude error stays
within the XEB fidelity the experiment already sacrifices.  The TPU
analogue here demotes individual contraction steps to
bf16-input/fp32-accumulate ("bf16" on :class:`~repro.lowering.refiner.
GemmSpec`) under a forward error model, certified against a user-set
Linear-XEB fidelity tolerance:

**Error model.**  Rounding a GEMM's operands to bf16 perturbs every
product by at most ``2u`` relative (``u = 2^-9``, 8-bit mantissa,
round-to-nearest).  For random-circuit tensors the component phases are
Porter-Thomas-random, so the K-term accumulation grows like ``sqrt(K)``
against perturbations that also add in quadrature — the *relative*
per-node error stays ~``2u``, with a slowly growing guard for the
correlated tail (``log2 K``) and for the contractions the error still
passes through on the way to the root (``depth``).  Node errors are
independent roundings, so the plan-level relative amplitude error is
their quadrature sum, and the induced Linear-XEB fidelity loss is
``≈ 2×`` that (XEB is quadratic in the amplitudes).

**Assignment.**  Candidates (MXU-backed steps) are ranked by modeled
time saved — epilogue steps weighted by the ``2^|S|`` slice count — per
unit of error, then admitted as a strict prefix while the accumulated
fidelity loss stays within ``fidelity_tol``.  The prefix rule (stop at
the first failure, never skip) makes the assignment monotone in the
tolerance: a smaller ``fidelity_tol`` always selects a subset, and
``fidelity_tol=0`` selects nothing — reproducing the fp32 plan
bitwise.
"""

from __future__ import annotations

import math
import os

import jax.numpy as jnp

from ..core.merging import TPU_MXU
from .gemm_form import GemmForm
from .refiner import GemmSpec, LoweredSchedule, refine_step

PRECISION_MODES = ("fp32", "bf16", "auto")
# bf16 unit roundoff: 8 mantissa bits, round-to-nearest
BF16_UNIT_ROUNDOFF = 2.0 ** -9
# realistic budget: supremacy experiments run at XEB fidelity ~2e-3, so
# a few percent of *relative* fidelity loss disappears into the noise
# floor (Huang et al., arXiv 2005.06787)
DEFAULT_FIDELITY_TOL = 0.05
# backends that execute on the MXU with an fp32 accumulator — the only
# ones that can take bf16 operands
MXU_BACKENDS = ("pallas", "pallas_fused")


def default_precision() -> str:
    """Plan-wide precision mode: the ``REPRO_PRECISION`` environment
    variable (CI runs the tier-1 gate under fp32 and auto), defaulting
    to fp32.  ``auto`` demotes steps to bf16 under the XEB error budget;
    ``bf16`` forces every eligible step down regardless of tolerance."""
    v = os.environ.get("REPRO_PRECISION", "fp32")
    if v not in PRECISION_MODES:
        raise ValueError(
            f"REPRO_PRECISION={v!r} not in {PRECISION_MODES}"
        )
    return v


def node_amp_error(form: GemmForm, depth: int = 0) -> float:
    """Relative amplitude error contributed by running one GEMM with
    bf16 inputs (fp32 accumulation): ``2u`` input quantization with a
    guard for the correlated tail of the K-term sum and for the
    ``depth`` contractions the rounded values still pass through."""
    K = max(int(form.K), 1)
    guard = math.sqrt(1.0 + math.log2(K) / 8.0 + depth / 64.0)
    return 2.0 * BF16_UNIT_ROUNDOFF * guard


def predicted_fidelity_loss(amp_error: float) -> float:
    """Linear-XEB fidelity loss induced by a relative amplitude error:
    XEB averages ``|a|^2``, so first order in the perturbation is 2×."""
    return 2.0 * amp_error


def assign_precision(
    schedule: LoweredSchedule,
    *,
    mode: str | None = None,
    fidelity_tol: float | None = None,
    epilogue_positions=None,
    n_slices: int = 1,
    min_kernel_dim: int = TPU_MXU,
    fused: bool | None = None,
) -> LoweredSchedule:
    """Demote schedule steps to bf16 under the XEB error budget.

    Returns a new :class:`LoweredSchedule` whose selected specs were
    re-refined at ``precision="bf16"`` (block shapes re-chosen under the
    halved operand bytes) and whose ``precision_mode``/``fidelity_tol``/
    ``predicted_amp_error`` record the certification.  ``mode="fp32"``
    — or ``"auto"`` with a zero tolerance — returns the input specs
    untouched, so the fp32 plan is reproduced bitwise.

    ``epilogue_positions``/``n_slices`` weight each step's modeled
    saving by how often it executes (the epilogue runs once per slice),
    which orders the greedy admission; membership is then the longest
    prefix whose accumulated fidelity loss stays within tolerance."""
    mode = default_precision() if mode is None else mode
    if mode not in PRECISION_MODES:
        raise ValueError(f"precision={mode!r} not in {PRECISION_MODES}")
    tol = (
        DEFAULT_FIDELITY_TOL if fidelity_tol is None else float(fidelity_tol)
    )
    specs = list(schedule.specs)
    out = lambda sel, err: LoweredSchedule(  # noqa: E731
        sel, schedule.dtype, precision_mode=mode, fidelity_tol=tol,
        predicted_amp_error=err,
    )
    if mode == "fp32" or (mode == "auto" and tol <= 0.0):
        return out(specs, 0.0)
    epi = set(epilogue_positions) if epilogue_positions is not None else None
    n_steps = len(specs)
    candidates = []
    for p, spec in enumerate(specs):
        if spec.backend not in MXU_BACKENDS or spec.precision == "bf16":
            continue
        spec16 = refine_step(
            spec.form, schedule.dtype, min_kernel_dim=min_kernel_dim,
            fused=fused, precision="bf16",
        )
        if spec16.backend not in MXU_BACKENDS:
            continue
        weight = n_slices if (epi is None or p in epi) else 1
        benefit = (spec.modeled_time_s - spec16.modeled_time_s) * weight
        err = node_amp_error(spec.form, depth=n_steps - 1 - p)
        if mode == "auto" and benefit <= 0.0:
            continue
        candidates.append((benefit / err, p, spec16, err))
    err_sq = 0.0
    if mode == "bf16":
        for _, p, spec16, err in candidates:
            specs[p] = spec16
            err_sq += err * err
        return out(specs, math.sqrt(err_sq))
    # auto: benefit-per-error order, strict-prefix admission — stop at
    # the first candidate the budget rejects (monotone in tol)
    candidates.sort(key=lambda c: (-c[0], c[1]))
    for _, p, spec16, err in candidates:
        trial = err_sq + err * err
        if predicted_fidelity_loss(math.sqrt(trial)) > tol:
            break
        specs[p] = spec16
        err_sq = trial
    return out(specs, math.sqrt(err_sq))


def storage_itemsizes(
    step_nodes, specs, dtype, node_ids
) -> dict[int, int]:
    """Per-node *storage* itemsize under a mixed-precision schedule: a
    node is held as bf16 component pairs (half the native width) exactly
    when every GEMM that consumes it reads bf16 operands — rounding at
    the store is then identical to rounding at every consumption, so
    storage precision never changes the numerics.  Unconsumed nodes (the
    root / hoisted frontier outputs) stay full width."""
    full = int(jnp.dtype(dtype).itemsize)
    half = max(1, full // 2)
    consumers: dict[int, list[str]] = {}
    for (lhs, rhs, _out), spec in zip(step_nodes, specs):
        consumers.setdefault(lhs, []).append(spec.precision)
        consumers.setdefault(rhs, []).append(spec.precision)
    return {
        v: half
        if consumers.get(v) and all(p == "bf16" for p in consumers[v])
        else full
        for v in node_ids
    }


def tree_storage_itemsizes(
    tree,
    smask: int = 0,
    *,
    itemsize: int = 8,
    mode: str | None = None,
    fidelity_tol: float | None = None,
    fused: bool | None = None,
) -> dict[int, int] | None:
    """Planner-side storage-itemsize map for ``(tree, S)`` — what
    :func:`~repro.core.slicing.refine_slices_for_peak` needs to certify
    dtype-true peaks before any executor plan exists.  Returns ``None``
    when the assignment selects no bf16 nodes (including fp32 mode and
    itemsizes with no bf16 mapping)."""
    from ..core.tensor_network import popcount  # lazy: avoid cycle
    from .refiner import refine_tree_schedule

    dtype = {8: "complex64", 4: "float32"}.get(int(itemsize))
    if dtype is None:
        return None
    mode = default_precision() if mode is None else mode
    if mode == "fp32":
        return None
    sched = refine_tree_schedule(tree, smask, dtype=dtype, fused=fused)
    order = tree.contract_order()
    epilogue = None
    n_slices = 1
    if smask:
        from .partition import partition_tree  # lazy: avoid cycle

        invariant = set(partition_tree(tree, smask).invariant_nodes)
        epilogue = tuple(
            i for i, v in enumerate(order) if v not in invariant
        )
        n_slices = 1 << popcount(smask)
    sched = assign_precision(
        sched, mode=mode, fidelity_tol=fidelity_tol,
        epilogue_positions=epilogue, n_slices=n_slices, fused=fused,
    )
    if not sched.precision_counts().get("bf16"):
        return None
    step_nodes = tuple((*tree.children[v], v) for v in order)
    return storage_itemsizes(step_nodes, sched.specs, dtype, tree.emask)
