"""Lifetime-based buffer planning (Sec. III, Thm. 1 → a static memory plan).

The planner's width proxy (Eq. 2: ``max_e |s_e|``) bounds the *largest
single tensor*, but the executor's real footprint is the **live set** —
every buffer born and not yet consumed at some step.  The paper's central
observation is that tensor lifetimes are what determine both quantities,
and its Sunway runtime allocates buffers from a static lifetime-derived
plan instead of a dynamic heap.  This module is that plan for the JAX
executor:

  * :func:`plan_memory` runs the same lifetime machinery that drives
    two-phase hoisting (``lifetime_closure`` via
    :func:`~repro.lowering.partition.partition_tree`, interval algebra
    via :func:`repro.core.lifetime.step_lifetimes`) over a ``(tree, S)``
    pair and emits a :class:`MemoryPlan` with one :class:`SegmentPlan`
    per execution segment — the naive full-tree-per-slice program and,
    when ``S`` is non-empty, the hoisted prologue/epilogue pair;
  * each segment gets a **linear-scan slot assignment** (buffers with
    disjoint lifetimes share a slot — the classic register-allocation
    sweep over birth order) plus the **exact live-set peak** in bytes,
    per-step deterministic free lists, and slot-inheritance donation
    hints;
  * consumers: the executor drives its env frees from the plan (each
    tracer dropped at its planned last use is what lets XLA's allocator
    reuse the slot), ``PlanReport`` gains
    ``peak_bytes`` / ``peak_bytes_hoisted`` / ``buffer_slots``, and
    :mod:`repro.core.slicing` uses the planned peak to *stop slicing
    early* — the width proxy must assume several width-sized tensors are
    live at once, so bounding the true peak admits strictly smaller
    slicing sets (fewer ``2^|S|`` subtasks, Eq. 4) at the same byte
    budget.

On TPU the XLA allocator performs the actual reuse; the plan's role is
to *prove the bound at planning time* (and to schedule frees/donations
deterministically) so the slicer can trust it before anything executes.
"""

from __future__ import annotations

import dataclasses

from ..core.contraction_tree import ContractionTree
from ..core.lifetime import step_lifetimes
from ..core.tensor_network import bits
from .partition import partition_tree


def node_nbytes(
    tree: ContractionTree, v: int, smask: int, itemsize: int
) -> int:
    """Bytes of the buffer node ``v`` materializes under slicing mask
    ``S`` (sliced indices are fixed before execution, so they contribute
    no extent)."""
    size = 1
    labels = tree.tn.space.labels
    for b in bits(tree.emask[v] & ~smask):
        size *= tree.tn.size_of(labels[b])
    return size * itemsize


def _nbytes_map(
    tree: ContractionTree,
    smask: int,
    itemsize: int,
    itemsize_of: dict[int, int] | None,
) -> dict[int, int]:
    """Per-node buffer bytes, dtype-true under mixed precision:
    ``itemsize_of`` (from :func:`repro.lowering.precision.
    storage_itemsizes`) overrides the uniform ``itemsize`` for nodes the
    precision planner stores as bf16 component pairs."""
    return {
        v: node_nbytes(
            tree, v, smask,
            itemsize_of.get(v, itemsize) if itemsize_of else itemsize,
        )
        for v in tree.emask
    }


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """Static buffer plan for one execution segment.

    ``steps`` are ``(lhs, rhs, out)`` node ids in execution order.
    ``entry`` buffers are resident from the start (leaf arrays / hoisted
    frontier); ``pinned`` entries additionally survive the whole segment
    (the hoisted buffers are captured constants reused by every slice, so
    their bytes count at every step and their storage is never
    reusable).  ``slot_of`` maps every non-pinned buffer to a slot id;
    buffers sharing a slot have disjoint lifetimes, so
    ``sum(slot_bytes) + pinned`` is an executable upper bound on
    ``peak_bytes`` (the exact live-set maximum)."""

    name: str
    entry: tuple[int, ...]
    pinned: tuple[int, ...]
    steps: tuple[tuple[int, int, int], ...]
    outputs: tuple[int, ...]
    nbytes: dict[int, int]
    peak_bytes: int
    peak_step: int  # step index of the peak (-1: the entry state)
    slot_of: dict[int, int]
    slot_bytes: tuple[int, ...]
    frees: dict[int, tuple[int, ...]]  # out node -> env keys dead after it
    donations: dict[int, int]  # out node -> slot id inherited from a freed buffer

    @property
    def n_slots(self) -> int:
        return len(self.slot_bytes)

    @property
    def pinned_bytes(self) -> int:
        return sum(self.nbytes[v] for v in self.pinned)

    def slot_total_bytes(self) -> int:
        return sum(self.slot_bytes) + self.pinned_bytes


def _plan_segment(
    name: str,
    entry: tuple[int, ...],
    pinned: tuple[int, ...],
    steps: tuple[tuple[int, int, int], ...],
    outputs: tuple[int, ...],
    nbytes: dict[int, int],
) -> SegmentPlan:
    """One sweep over the segment: exact live-set peak, linear-scan slot
    assignment, free schedule, donation hints."""
    birth, death = step_lifetimes(list(steps), entry, outputs)
    pinned_set = set(pinned)
    end = len(steps)

    slots: list[int] = []  # slot id -> slot bytes (max over occupants)
    free_slots: list[int] = []
    slot_of: dict[int, int] = {}

    def take_slot(need: int) -> tuple[int, bool]:
        if free_slots:
            # best fit: the free slot that already holds `need` with the
            # least waste, else the one needing the least growth
            sid = min(
                free_slots,
                key=lambda s: (slots[s] < need, abs(slots[s] - need)),
            )
            free_slots.remove(sid)
            slots[sid] = max(slots[sid], need)
            return sid, True
        slots.append(need)
        return len(slots) - 1, False

    for v in entry:
        if v not in pinned_set:
            slot_of[v], _ = take_slot(nbytes[v])

    cur = sum(nbytes[v] for v in entry)
    peak, peak_step = cur, -1
    frees: dict[int, tuple[int, ...]] = {}
    donations: dict[int, int] = {}
    for t, (lhs, rhs, out) in enumerate(steps):
        # the output is allocated while both inputs are still resident
        # (no in-place GEMM), so it may only inherit a slot freed at a
        # *strictly earlier* step — exactly what free_slots holds here.
        sid, reused = take_slot(nbytes[out])
        slot_of[out] = sid
        if reused:
            donations[out] = sid
        cur += nbytes[out]
        if cur > peak:
            peak, peak_step = cur, t
        dead = []
        for u in (lhs, rhs):
            if death.get(u) == t and u not in pinned_set:
                cur -= nbytes[u]
                dead.append(u)
                free_slots.append(slot_of[u])
        frees[out] = tuple(dead)

    # sanity: what remains live is exactly the outputs + pinned + any
    # never-consumed entry
    expect = sum(
        nbytes[v] for v in birth if death[v] >= end and v not in pinned_set
    ) + sum(nbytes[v] for v in pinned_set)
    assert cur == expect, (name, cur, expect)
    return SegmentPlan(
        name=name,
        entry=tuple(entry),
        pinned=tuple(pinned),
        steps=tuple(steps),
        outputs=tuple(outputs),
        nbytes=dict(nbytes),
        peak_bytes=peak,
        peak_step=peak_step,
        slot_of=slot_of,
        slot_bytes=tuple(slots),
        frees=frees,
        donations=donations,
    )


def chain_segment_plan(
    name: str,
    entry,
    steps,
    outputs,
    nbytes: dict[int, int],
) -> SegmentPlan:
    """Chain-local buffer plan for a fused-GEMM run (the epilogue
    megakernel, :func:`repro.kernels.contract_gemm.fused_chain_matmul`).

    Runs the same linear-scan allocator as :func:`plan_memory`'s
    segments over just the chained steps, with every ``entry`` buffer
    *pinned*: the megakernel DMAs whole operands into VMEM up front and
    they stay resident for the duration of the chain, so only the
    chain-interior intermediates compete for scratch slots.  The
    returned :class:`SegmentPlan`'s ``peak_bytes`` is therefore the
    certified VMEM live set of one chain execution (operands +
    intermediates + output), and ``slot_of``/``slot_bytes`` are the
    scratch-slot assignment the kernel allocates verbatim."""
    return _plan_segment(
        name, tuple(entry), tuple(entry), tuple(steps), tuple(outputs),
        dict(nbytes),
    )


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """Lifetime-derived buffer plan for one ``(tree, S)`` pair.

    ``naive`` covers the full-tree-per-slice program; ``prologue`` /
    ``epilogue`` cover the two-phase split (``None`` when ``S`` is empty
    or the tree has no steps).  All byte figures are per *subtask* —
    the executor's slice-batch ``vmap`` multiplies every non-pinned
    epilogue term by the batch size (see :meth:`epilogue_peak`)."""

    itemsize: int
    smask: int
    naive: SegmentPlan
    prologue: SegmentPlan | None
    epilogue: SegmentPlan | None

    @property
    def peak_bytes(self) -> int:
        """Exact live-set peak of the naive full-tree subtask."""
        return self.naive.peak_bytes

    @property
    def peak_bytes_hoisted(self) -> int:
        """Peak footprint of two-phase execution: the prologue runs
        first (full, unsliced invariant tensors), then every epilogue
        subtask runs with the hoisted frontier pinned."""
        if self.prologue is None or self.epilogue is None:
            return self.naive.peak_bytes
        return max(self.prologue.peak_bytes, self.epilogue.peak_bytes)

    @property
    def buffer_slots(self) -> int:
        """Linear-scan slot count of the naive segment — how many
        physical buffers a static allocator needs for the whole subtask
        (vs one per tree node for a no-reuse executor)."""
        return self.naive.n_slots

    def epilogue_peak(self, slice_batch: int = 1) -> int:
        """Per-scan-step peak of the vmapped epilogue: pinned hoisted
        buffers are shared across the batch, everything else scales."""
        seg = self.epilogue if self.epilogue is not None else self.naive
        pinned = seg.pinned_bytes
        return pinned + slice_batch * (seg.peak_bytes - pinned)

    def segment_for(self, name: str) -> SegmentPlan | None:
        return {
            "naive": self.naive,
            "prologue": self.prologue,
            "epilogue": self.epilogue,
        }[name]

    def summary(self) -> dict:
        return {
            "itemsize": self.itemsize,
            "peak_bytes": self.peak_bytes,
            "peak_bytes_hoisted": self.peak_bytes_hoisted,
            "buffer_slots": self.buffer_slots,
            "naive_slot_bytes": self.naive.slot_total_bytes(),
            "prologue_peak_bytes": (
                self.prologue.peak_bytes if self.prologue else 0
            ),
            "epilogue_peak_bytes": (
                self.epilogue.peak_bytes if self.epilogue else 0
            ),
        }


def plan_memory(
    tree: ContractionTree,
    smask: int = 0,
    itemsize: int = 8,
    hoist: bool = True,
    part=None,
    itemsize_of: dict[int, int] | None = None,
) -> MemoryPlan:
    """Build the lifetime-based :class:`MemoryPlan` for ``(tree, S)``.

    Pure planner algebra — no arrays are touched, so the slicer can call
    this inside its search loop.  ``itemsize`` is the execution dtype's
    width (8 for complex64); ``itemsize_of`` overrides it per node under
    a mixed-precision plan (bf16-stored nodes at half width), making the
    certified peaks dtype-true.  ``hoist=False`` skips the prologue/
    epilogue segments; ``part`` reuses a caller-held
    :class:`~repro.lowering.partition.TreePartition` for the same
    ``(tree, smask)`` instead of recomputing it."""
    order = tree.contract_order()
    steps = tuple((*tree.children[v], v) for v in order)
    n_leaves = tree.tn.num_tensors
    nbytes = _nbytes_map(tree, smask, itemsize, itemsize_of)
    root = (tree.root,)
    naive = _plan_segment(
        "naive", tuple(range(n_leaves)), (), steps, root, nbytes
    )
    prologue = epilogue = None
    if hoist and smask and steps:
        if part is None:
            part = partition_tree(tree, smask)
        assert part.smask == smask
        # prologue consumes the full (unsliced) leaf arrays — but every
        # invariant node's mask is disjoint from S by construction, so
        # the sliced byte formula is already exact for them.
        pro_steps = tuple(
            (*tree.children[v], v) for v in part.invariant_nodes
        )
        if pro_steps:
            prologue = _plan_segment(
                "prologue", part.prologue_leaves, (), pro_steps,
                part.hoisted_nodes, nbytes,
            )
        epi_steps = tuple(
            (*tree.children[v], v) for v in part.epilogue_nodes
        )
        epilogue = _plan_segment(
            "epilogue",
            part.epilogue_leaves + part.hoisted_nodes,
            part.hoisted_nodes,
            epi_steps,
            root,
            nbytes,
        )
    return MemoryPlan(
        itemsize=itemsize,
        smask=smask,
        naive=naive,
        prologue=prologue,
        epilogue=epilogue,
    )


def certified_peak(
    tree: ContractionTree,
    smask: int = 0,
    itemsize: int = 8,
    part=None,
    itemsize_of: dict[int, int] | None = None,
) -> int:
    """The certified live-set peak for ``(tree, S)``: the worst case over
    the naive full-tree subtask and the hoisted prologue/epilogue pair —
    i.e. ``max(MemoryPlan.peak_bytes, MemoryPlan.peak_bytes_hoisted)`` —
    computed *without* slot assignment or free schedules.

    This is the byte-budget objective of the peak-aware slicer and the
    anytime co-optimizer (:mod:`repro.optimize`), which call it once per
    candidate inside their search loops; skipping the allocator sweep
    keeps that evaluation cheap while matching :func:`plan_memory`'s
    peaks exactly (property-tested).  ``part`` reuses a caller-held
    partition for the same ``(tree, smask)``; ``itemsize_of`` makes the
    peak dtype-true under a mixed-precision plan."""
    order = tree.contract_order()
    steps = [(*tree.children[v], v) for v in order]
    nbytes = _nbytes_map(tree, smask, itemsize, itemsize_of)

    def seg_peak(entry, seg_steps, outputs, pinned=()):
        birth, death = step_lifetimes(list(seg_steps), entry, outputs)
        pinned_set = set(pinned)
        cur = sum(nbytes[v] for v in entry)
        peak = cur
        for t, (lhs, rhs, out) in enumerate(seg_steps):
            cur += nbytes[out]
            if cur > peak:
                peak = cur
            for u in (lhs, rhs):
                if death.get(u) == t and u not in pinned_set:
                    cur -= nbytes[u]
        return peak

    root = (tree.root,)
    peak = seg_peak(tuple(range(tree.tn.num_tensors)), steps, root)
    if not smask or not steps:
        return peak
    if part is None:
        part = partition_tree(tree, smask)
    pro_steps = [(*tree.children[v], v) for v in part.invariant_nodes]
    if pro_steps:
        peak = max(
            peak,
            seg_peak(part.prologue_leaves, pro_steps, part.hoisted_nodes),
        )
    epi_steps = [(*tree.children[v], v) for v in part.epilogue_nodes]
    peak = max(
        peak,
        seg_peak(
            part.epilogue_leaves + part.hoisted_nodes,
            epi_steps,
            root,
            pinned=part.hoisted_nodes,
        ),
    )
    return peak


def peak_bytes(
    tree: ContractionTree,
    smask: int,
    itemsize: int = 8,
    hoist: bool = False,
) -> int:
    """Planned live-set peak for ``(tree, S)`` — the quantity the
    peak-aware slicer bounds.  Defaults to the naive segment's peak: it
    is monotone in ``S`` (removing a sliced index only grows tensors on
    its lifetime), which is what makes the slicer's prune loop sound."""
    plan = plan_memory(tree, smask, itemsize, hoist=hoist)
    return plan.peak_bytes_hoisted if hoist else plan.peak_bytes
