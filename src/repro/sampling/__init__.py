"""Batched correlated-amplitude sampling — the paper's flagship workload.

The headline experiment (Sec. VI: one million correlated samples of the
Sycamore RQC in 96.1 s) never computes amplitudes one bitstring at a
time.  Instead, a small subset of output qubits is held *open* through
the final stem of the contraction, so every sliced contraction produces
a tensor of ``2^k`` amplitudes sharing the projected prefix — a batch of
*correlated* amplitudes from one plan execution.  Bitstrings are then
drawn from that batch (frequency / rejection / top-k sampling) and
scored with Linear XEB.  The same trick is the winning move in
"Closing the Quantum Supremacy Gap" (arXiv:2110.14502) and "Classical
Simulation of Quantum Supremacy Circuits" (arXiv:2005.06787).

Layering:

  batch.py    — open-batch network construction + (sharded) contraction
  samplers.py — frequency / rejection / top-k samplers + SamplingResult

The public entry point is :func:`repro.core.api.sample_bitstrings`.
"""

from .batch import (  # noqa: F401
    AmplitudeBatch,
    contract_amplitude_batch,
    open_batch_network,
)
from .samplers import (  # noqa: F401
    SamplingResult,
    frequency_sample,
    rejection_sample,
    top_k_indices,
)
