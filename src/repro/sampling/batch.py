"""Open-batch contraction: one sliced contraction → 2^k correlated amplitudes.

``open_batch_network`` lowers a circuit with ``k`` chosen output qubits held
open (everything else projected onto a base bitstring); contracting the
result yields the full amplitude tensor over those qubits.  The open axes
ride through the planner untouched — open indices are never sliced and never
contracted, so the slice-sum structure (and the single all-reduce) is
exactly the scalar-amplitude pipeline's, just with a tensor accumulator.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def open_batch_network(circuit, base_bitstring: str, open_qubits):
    """(TensorNetwork, arrays) with ``open_qubits`` output wires held open.

    Non-open qubits are projected onto their ``base_bitstring`` value; the
    open wires become output axes in ascending qubit order.  The network is
    pre-simplified (gate fusion) like the scalar-amplitude path.
    """
    from ..core.executor import simplify_network
    from ..quantum.circuits import circuit_to_network

    tn, arrays = circuit_to_network(
        circuit, bitstring=base_bitstring, open_qubits=tuple(open_qubits)
    )
    return simplify_network(tn, arrays)


def contract_amplitude_batch(
    plan,
    arrays,
    slice_batch: int = 4,
    mesh=None,
    axis_names: tuple[str, ...] = ("data",),
    hoist: bool | None = None,
) -> np.ndarray:
    """Run a compiled :class:`~repro.core.executor.ContractionPlan` and
    return the amplitude tensor (one axis per open qubit).

    ``mesh=None`` uses the single-host vmapped executor; with a mesh the
    slice ids are sharded over ``axis_names`` (shard_map + one psum) and the
    open-batch axes ride inside each device's accumulator unchanged.

    Backend-agnostic: a plan built with ``backend="gemm"`` carries its
    lowered kernel schedule (open indices lowered as GEMM batch axes, see
    :mod:`repro.lowering`) and executes it on both paths.

    Under two-phase execution (``hoist``, default ``REPRO_HOIST``) the
    slice-invariant stem prologue is materialized once and LRU-cached by
    leaf fingerprint on the plan, so *repeated* sampler calls against the
    same open-qubit batch network (same base bitstring) skip it entirely
    and pay only the per-slice epilogue.
    """
    from ..core.executor import auto_slice_batch
    from ..obs import trace as _trace

    sb = auto_slice_batch(slice_batch, 1 << plan.num_sliced)
    with _trace.span(
        "sampling.contract", cat="sampling", batch=plan.batch_size,
        sharded=mesh is not None,
    ):
        if mesh is None:
            value = plan.contract_all(arrays, slice_batch=sb, hoist=hoist)
        else:
            from ..core.distributed import contract_sharded

            value = contract_sharded(
                plan, arrays, mesh, axis_names=axis_names, slice_batch=sb,
                hoist=hoist,
            )
        value = _trace.sync(value)
    return np.asarray(value)


@dataclasses.dataclass
class AmplitudeBatch:
    """All 2^k correlated amplitudes from one open-batch contraction.

    ``amplitudes`` has one axis per open qubit (ascending qubit order), so
    flat index ``i`` encodes the open-qubit bits MSB-first: bit ``j`` of the
    batch entry is ``(i >> (k-1-j)) & 1`` and belongs to ``open_qubits[j]``.
    """

    amplitudes: np.ndarray
    open_qubits: tuple[int, ...]
    base_bitstring: str
    num_qubits: int

    def __post_init__(self):
        self.open_qubits = tuple(self.open_qubits)
        if self.amplitudes.ndim != len(self.open_qubits):
            raise ValueError(
                f"batch has {self.amplitudes.ndim} axes for "
                f"{len(self.open_qubits)} open qubits"
            )

    @property
    def k(self) -> int:
        return len(self.open_qubits)

    @property
    def size(self) -> int:
        return int(self.amplitudes.size)

    def flat(self) -> np.ndarray:
        """Amplitudes as a 1-D batch of length 2^k (C order = MSB first)."""
        return np.ravel(self.amplitudes)

    def probs(self, normalize: bool = False) -> np.ndarray:
        """|amplitude|^2 per batch entry.

        Unnormalized values are the *true* circuit probabilities p_C(s) of
        the full n-qubit bitstrings (what XEB needs); ``normalize=True``
        gives the conditional distribution over the open qubits (what the
        frequency sampler draws from).
        """
        p = np.abs(self.flat()) ** 2
        if normalize:
            s = p.sum()
            if s <= 0:
                raise ValueError("all batch amplitudes are zero")
            p = p / s
        return p

    def bitstring_for(self, index: int) -> str:
        """Full n-qubit bitstring for flat batch entry ``index``: the base
        bitstring with the open positions filled from ``index``'s bits."""
        out = list(self.base_bitstring)
        kk = self.k
        for j, q in enumerate(self.open_qubits):
            out[q] = str((index >> (kk - 1 - j)) & 1)
        return "".join(out)

    def bitstrings_for(self, indices) -> list[str]:
        return [self.bitstring_for(int(i)) for i in np.asarray(indices)]
