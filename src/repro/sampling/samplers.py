"""Bitstring samplers over a correlated-amplitude batch.

Three strategies from the supremacy-simulation literature:

  * ``frequency_sample`` — draw from the exact conditional distribution
    |a_i|^2 / Σ|a|^2 over the open qubits (multinomial).  This is the
    paper's correlated sampling: many bitstrings per contraction, with
    frequencies faithful to the circuit distribution.
  * ``rejection_sample`` — Markov-free accept/reject against a uniform
    proposal (arXiv:2005.06787's frugal rejection sampling): accept
    candidate ``i`` with probability p_i / M where M ≥ max p.  Produces
    unbiased samples without normalizing over unseen amplitudes.
  * ``top_k_indices`` — the k heaviest outcomes, for spoofing-style
    heavy-output workloads.

All samplers return *flat batch indices*; :class:`AmplitudeBatch` maps
those to full n-qubit bitstrings.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .batch import AmplitudeBatch


@dataclasses.dataclass
class SamplingResult:
    """Output of :func:`repro.core.api.sample_bitstrings`.

    bitstrings  — sampled full n-qubit bitstrings
    amplitudes  — the sampled entries' amplitudes (len == num samples)
    probs       — true probabilities |amplitude|^2 of the samples
    xeb         — Linear XEB estimate of the sample set (Eq. 1)
    batch       — the underlying 2^k correlated-amplitude batch
    sampler     — which sampling strategy produced the set
    report      — planner metrics for the one contraction that was run
    """

    bitstrings: list[str]
    amplitudes: np.ndarray
    probs: np.ndarray
    xeb: float
    batch: AmplitudeBatch
    sampler: str
    report: object | None = None

    @property
    def num_samples(self) -> int:
        return len(self.bitstrings)


def frequency_sample(
    batch: AmplitudeBatch, num_samples: int, seed: int = 0
) -> np.ndarray:
    """Multinomial draw of flat batch indices ∝ |amplitude|^2 (delegates
    to the XEB module's sampler so there is one multinomial in the repo)."""
    from ..quantum import xeb

    # normalize=True keeps the all-zero-batch guard in one place
    return xeb.sample_bitstrings(
        batch.probs(normalize=True), num_samples, seed=seed
    )


def rejection_sample(
    batch: AmplitudeBatch,
    num_samples: int,
    seed: int = 0,
    ceiling: float | None = None,
    max_rounds: int = 10_000,
) -> np.ndarray:
    """Accept/reject with a uniform proposal over the batch.

    ``ceiling`` bounds max_i p_i; default is the exact batch maximum (known
    here since the whole batch is in hand — frugal variants use a
    Porter-Thomas multiple of the mean instead).
    """
    rng = np.random.default_rng(seed)
    p = batch.probs(normalize=False)
    m = float(p.max()) if ceiling is None else float(ceiling)
    if m <= 0:
        raise ValueError("cannot rejection-sample an all-zero batch")
    out: list[np.ndarray] = []
    need = num_samples
    for _ in range(max_rounds):
        if need <= 0:
            break
        # propose in blocks sized by the expected acceptance rate
        rate = max(p.mean() / m, 1e-6)
        block = int(min(4 * need / rate, 4e6)) + 1
        cand = rng.integers(0, batch.size, size=block)
        keep = cand[rng.random(block) * m < p[cand]]
        out.append(keep[:need])
        need -= len(keep[:need])
    if need > 0:
        raise RuntimeError("rejection sampling did not converge")
    return np.concatenate(out)


def top_k_indices(batch: AmplitudeBatch, k: int) -> np.ndarray:
    """Flat indices of the k largest |amplitude|^2, heaviest first.

    Unlike the random samplers, top-k draws *without* replacement, so it
    cannot return more samples than the batch holds — asking for more is
    an error rather than a silent truncation.
    """
    if k > batch.size:
        raise ValueError(
            f"topk asked for {k} samples from a batch of {batch.size}; "
            "open more qubits or lower num_samples"
        )
    p = batch.probs(normalize=False)
    idx = np.argpartition(p, -k)[-k:]
    return idx[np.argsort(p[idx])[::-1]]


def draw(
    batch: AmplitudeBatch,
    num_samples: int,
    sampler: str = "frequency",
    seed: int = 0,
) -> np.ndarray:
    """Dispatch on sampler name ('frequency' | 'rejection' | 'topk')."""
    from ..obs import metrics as _metrics, trace as _trace

    with _trace.span(
        "sampling.draw", cat="sampling", sampler=sampler, n=num_samples
    ):
        if sampler == "frequency":
            idx = frequency_sample(batch, num_samples, seed=seed)
        elif sampler == "rejection":
            idx = rejection_sample(batch, num_samples, seed=seed)
        elif sampler == "topk":
            idx = top_k_indices(batch, num_samples)
        else:
            raise ValueError(f"unknown sampler {sampler!r}")
    _metrics.inc("sampling.samples_drawn", len(idx))
    return idx
