"""GPipe-style pipeline parallelism over a mesh axis.

The layer stack is split into ``n_stages`` contiguous stages (stage s
holds layers [s·L/P, (s+1)·L/P)); microbatches stream through the
pipeline with ``collective_permute`` (ppermute) stage hand-offs.  The
schedule is the classic GPipe fill-run-drain: ``n_micro + P - 1`` ticks,
bubble fraction (P-1)/(n_micro+P-1).

Forward-only scheduling is written here; jax autodiff through ppermute
yields the GPipe backward (all-forward-then-all-backward) automatically,
so the same function trains.

This is offered as the alternative use of the "pod" axis (DP across pods
is the default recipe); the dry-run exercises it via
``examples``/tests on a small mesh and it composes with in-stage
FSDP/TP shardings on the remaining axes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    layer_apply: Callable,  # (layer_params, x) -> x
    stacked_params,  # pytree, leaves (L, ...)
    x: jax.Array,  # (n_micro, mb, ...) microbatched input
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the layer stack as a pipeline over ``axis``.

    Returns the full (n_micro, mb, ...) output (valid on every device —
    the last stage's results are broadcast with a psum at the end).
    """
    from jax.experimental.shard_map import shard_map

    n_stages = mesh.shape[axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    per_stage = L // n_stages
    n_micro = x.shape[0]

    # stage-shard the stacked params along the layer axis
    param_specs = jax.tree.map(lambda _: P(axis), stacked_params)
    x_spec = P()  # microbatches replicated into the pipe

    def stage_fn(params_stage, x_all):
        sid = jax.lax.axis_index(axis)

        def apply_stage(h):
            def body(hh, lp):
                return layer_apply(lp, hh), None

            h2, _ = jax.lax.scan(body, h, params_stage)
            return h2

        perm_fwd = [(i, i + 1) for i in range(n_stages - 1)]
        buf = jnp.zeros_like(x_all[0])
        out = jnp.zeros_like(x_all)
        T = n_micro + n_stages - 1
        for t in range(T):
            feed = x_all[min(t, n_micro - 1)]
            inp = jnp.where(sid == 0, feed, buf)
            act = apply_stage(inp)
            if t >= n_stages - 1:
                mb = t - (n_stages - 1)
                last = jnp.where(sid == n_stages - 1, act, jnp.zeros_like(act))
                out = out.at[mb].set(last)
            if n_stages > 1:
                buf = jax.lax.ppermute(act, axis, perm_fwd)
        # broadcast the last stage's outputs to every pipeline rank
        return jax.lax.psum(out, axis)

    return shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=P(),
        check_rep=False,
    )(stacked_params, x)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
