"""Parameter definitions with first-class sharding.

Every model declares its parameters once as a pytree of :class:`ParamDef`
(shape + logical PartitionSpec + initializer).  From that single
declaration we derive:

  * real initialization (``init_params``) for training/smoke tests,
  * ``jax.ShapeDtypeStruct`` trees for the dry-run (no allocation),
  * ``NamedSharding`` trees for pjit in/out shardings,
  * mesh-agnostic checkpointing (logical specs re-bound to any mesh —
    this is the elastic-restart story).

Logical axes used by the fleet (resolved against the active mesh):
  "fsdp"   → "data"                (ZeRO-3 sharding of params/opt state)
  "tp"     → "model"               (Megatron tensor parallelism)
  "ep"     → "model"               (expert parallelism)
  "dp"     → ("pod", "data")       (batch)
  "sp"     → "model"               (long-context sequence sharding)
Axes not present on the mesh resolve to None (elastic down-scaling).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_TO_PHYSICAL = {
    "fsdp": ("data",),
    "tp": ("model",),
    "ep": ("model",),
    "dp": ("pod", "data"),
    "sp": ("model",),
    None: (),
}

# Sharding recipes: per-architecture overrides of the logical→physical
# map.  The §Perf hillclimbs showed one size does not fit all:
#   default — FSDP + TP (MaxText-style), right for multi-B dense models.
#   dp_only — pure data parallelism, params replicated.  Right for small
#             models (mamba2-130m): sharding 130M params over 256 chips
#             costs more in per-layer all-gathers than it saves.
#   fsdp_only — ZeRO-3 without tensor parallelism.  Right for the hybrid
#             SSM (zamba2): TP over d_inner forces resharding of every
#             conv/SSD intermediate; FSDP keeps memory bounded with one
#             gather per parameter per pass.
RECIPES: dict[str, dict] = {
    "default": LOGICAL_TO_PHYSICAL,
    "dp_only": {
        **LOGICAL_TO_PHYSICAL,
        "fsdp": (),
        "tp": (),
        "ep": (),
        "sp": (),
        "dp": ("pod", "data", "model"),
    },
    "fsdp_only": {
        **LOGICAL_TO_PHYSICAL,
        "tp": (),
        "ep": (),
        "dp": ("pod", "data", "model"),
    },
}


def resolve_spec(
    logical: tuple,
    mesh: Mesh,
    shape: tuple[int, ...] | None = None,
    recipe: str = "default",
) -> P:
    """Map logical axis names to mesh axes, dropping absent ones.

    With ``shape``, axes that do not evenly divide their dimension are
    dropped (rightmost first for multi-axis dims) — this is what makes the
    same model config land on any mesh: GQA kv-heads smaller than the TP
    axis fall back to replication, a batch of 1 falls back off DP, a vocab
    not divisible by 16 keeps the embedding unsharded, etc.
    """
    table = RECIPES[recipe]
    out = []
    used: set[str] = set()
    for i, ax in enumerate(logical):
        if ax is None:
            out.append(None)
            continue
        phys = [
            a
            for a in table.get(ax, (ax,))
            if a in mesh.axis_names and a not in used
        ]
        if shape is not None:
            dim = shape[i] if i < len(shape) else 0
            while phys:
                prod = 1
                for a in phys:
                    prod *= mesh.shape[a]
                if dim % prod == 0:
                    break
                phys = phys[:-1]  # drop rightmost axis, retry
        used.update(phys)
        if not phys:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(tuple(phys))
    return P(*out)


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple  # logical axis per dim (or None)
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; default 1/sqrt(fan_in-ish)
    dtype: jnp.dtype = jnp.bfloat16

    def initializer(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (
            jax.random.normal(key, self.shape, jnp.float32) * scale
        ).astype(self.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [d.initializer(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_specs(defs) -> object:
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=is_def)


def param_shardings(defs, mesh: Mesh, recipe: str = "default"):
    return jax.tree.map(
        lambda d: NamedSharding(
            mesh, resolve_spec(d.logical, mesh, d.shape, recipe)
        ),
        defs,
        is_leaf=is_def,
    )


def _is_logical(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )


def logical_shardings(abstract_tree, logical_tree, mesh: Mesh,
                      recipe: str = "default"):
    """Shape-aware shardings for non-param trees (batches, caches, opt
    state) declared as parallel pytrees of ShapeDtypeStructs and
    logical-axis tuples."""

    flat_log, treedef = jax.tree.flatten(logical_tree, is_leaf=_is_logical)
    flat_abs = jax.tree.leaves(abstract_tree)
    assert len(flat_log) == len(flat_abs), (
        f"{len(flat_log)} logical vs {len(flat_abs)} abstract leaves"
    )
    out = [
        NamedSharding(mesh, resolve_spec(log, mesh, ab.shape, recipe))
        for log, ab in zip(flat_log, flat_abs)
    ]
    return jax.tree.unflatten(treedef, out)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
