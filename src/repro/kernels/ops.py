"""Jit'd public wrappers around the Pallas kernels.

Handles the unglamorous production parts: padding to tile multiples,
complex GEMMs for the quantum executor (3-real-GEMM Karatsuba — a
beyond-paper trick: 25% fewer MXU FLOPs than the naive 4-GEMM form), GQA
head broadcast for flash attention, and the SSD inter-chunk combine.

``interpret`` defaults to True off-TPU so the same call sites run the
kernel bodies on CPU (correctness) and the compiled kernels on TPU
(performance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..obs import trace as _trace
from . import ref
from .contract_gemm import (
    chain_reference,
    fused_chain_matmul,
    fused_transpose_matmul,
    tiled_matmul,
)
from .flash_attention import flash_attention
from .mamba2_ssd import ssd_intra_chunk


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    min_kernel_dim: int = 128,
    precision: str = "fp32",
) -> jax.Array:
    """GEMM via the Pallas kernel, with padding and complex support.

    Falls back to jnp.dot for tiny shapes where tile padding would dominate
    (the paper's Sec. V-A pathology — better to merge branches than to run
    a 128×4 GEMM on the MXU).

    ``precision="bf16"`` rounds the (real-component) operands to bf16
    before the kernel; the MXU accumulates in fp32 and the output stays
    fp32.  Complex Karatsuba sums its component pairs in fp32 *before*
    the rounding, so the fused/chained paths can match bitwise.
    """
    if interpret is None:
        interpret = default_interpret()
    if jnp.iscomplexobj(a) or jnp.iscomplexobj(b):
        return _complex_matmul(
            a, b, bm=bm, bn=bn, bk=bk, interpret=interpret,
            min_kernel_dim=min_kernel_dim, precision=precision,
        )
    m, k = a.shape
    _, n = b.shape
    if min(m, n, k) < min_kernel_dim:
        return ref.matmul_ref(a, b)
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    if precision == "bf16":
        ap = ap.astype(jnp.bfloat16)
        bp = bp.astype(jnp.bfloat16)
    # host-side XLA-profile annotation only (repro.obs.trace.annotate is
    # a no-op unless REPRO_TRACE=1, and never touches the traced graph)
    with _trace.annotate("ops.matmul"):
        out = tiled_matmul(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def _complex_matmul(
    a: jax.Array, b: jax.Array, **kw
) -> jax.Array:
    """Karatsuba: 3 real GEMMs instead of 4.

    P1 = Ar·Br, P2 = Ai·Bi, P3 = (Ar+Ai)·(Br+Bi)
    C  = (P1 − P2) + i·(P3 − P1 − P2)
    """
    ar, ai = jnp.real(a).astype(jnp.float32), jnp.imag(a).astype(jnp.float32)
    br, bi = jnp.real(b).astype(jnp.float32), jnp.imag(b).astype(jnp.float32)
    p1 = matmul(ar, br, **kw)
    p2 = matmul(ai, bi, **kw)
    p3 = matmul(ar + ai, br + bi, **kw)
    return (p1 - p2) + 1j * (p3 - p1 - p2)


def fused_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    perm_a: tuple[int, ...],
    perm_b: tuple[int, ...],
    nb: int,
    nm: int,
    nn: int,
    nk: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
    precision: str = "fp32",
) -> jax.Array:
    """Fused transpose-GEMM over tree-native operand layouts, with complex
    support (the same 3-real-GEMM Karatsuba as :func:`matmul` — real/imag
    component extraction commutes with the in-kernel permutation, so the
    components also stay in native layout; no transposed copy ever lands
    in HBM).  Returns the natural (batch..., m..., n...) output, one axis
    per role index.

    ``precision="bf16"`` rounds each real component to bf16 before the
    kernel (the in-kernel permutation commutes with the elementwise
    cast); accumulation and output stay fp32.

    Rank-0 operands / scalar outputs fall back to the materialized
    permute + ``jnp.matmul`` reference — Pallas wants at least one output
    axis, and the refiner never routes such nodes here anyway.
    """
    if interpret is None:
        interpret = default_interpret()
    if jnp.iscomplexobj(a) or jnp.iscomplexobj(b):
        ar = jnp.real(a).astype(jnp.float32)
        ai = jnp.imag(a).astype(jnp.float32)
        br = jnp.real(b).astype(jnp.float32)
        bi = jnp.imag(b).astype(jnp.float32)
        kw = dict(perm_a=perm_a, perm_b=perm_b, nb=nb, nm=nm, nn=nn, nk=nk,
                  bm=bm, bn=bn, bk=bk, interpret=interpret,
                  precision=precision)
        p1 = fused_matmul(ar, br, **kw)
        p2 = fused_matmul(ai, bi, **kw)
        p3 = fused_matmul(ar + ai, br + bi, **kw)
        return (p1 - p2) + 1j * (p3 - p1 - p2)
    if a.ndim == 0 or b.ndim == 0 or nb + nm + nn == 0:
        import math

        batch_shape = tuple(a.shape[p] for p in perm_a[:nb])
        m_shape = tuple(a.shape[p] for p in perm_a[nb:nb + nm])
        k_shape = tuple(a.shape[p] for p in perm_a[nb + nm:])
        n_shape = tuple(b.shape[p] for p in perm_b[nb + nk:])
        B, M = math.prod(batch_shape), math.prod(m_shape)
        K, N = math.prod(k_shape), math.prod(n_shape)
        a2 = jnp.transpose(a, perm_a).reshape(B, M, K)
        b2 = jnp.transpose(b, perm_b).reshape(B, K, N)
        return jnp.matmul(a2, b2).reshape(batch_shape + m_shape + n_shape)
    if precision == "bf16":
        a = a.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    with _trace.annotate("ops.fused_matmul"):
        return fused_transpose_matmul(
            a, b, perm_a=perm_a, perm_b=perm_b, nb=nb, nm=nm, nn=nn, nk=nk,
            bm=bm, bn=bn, bk=bk, interpret=interpret,
        )


def fused_chain(
    operands,
    *,
    forms: tuple,
    carry_side: tuple[str, ...],
    slot_ids: tuple[int, ...],
    slot_elems: tuple[int, ...],
    interpret: bool | None = None,
    use_kernel: bool | None = None,
    precisions: tuple[str, ...] | None = None,
    slot_prec: tuple[str, ...] | None = None,
):
    """Execute a fused GEMM chain (see :class:`repro.lowering.refiner.
    FusedChainSpec`): a run of adjacent tree contractions as one call,
    intermediates VMEM-resident, with complex support.

    Complex operands are split into fp32 ``(re, im)`` components *here*,
    once, at the chain boundary — the carry stays component-split through
    every step (per-step Karatsuba), so no complex intermediate is ever
    materialized between chained steps.  On TPU the chain runs as the
    persistent Pallas megakernel
    (:func:`repro.kernels.contract_gemm.fused_chain_matmul`); off-TPU it
    runs the same dataflow as one fused XLA program
    (:func:`~repro.kernels.contract_gemm.chain_reference`) — interpret-
    mode Pallas emulates kernels in Python per step, which would defeat
    the fusion this path exists to measure.  ``use_kernel`` forces the
    choice (the conformance suite exercises the kernel body explicitly
    with ``use_kernel=True, interpret=True``).

    ``precisions[t]`` is step ``t``'s GEMM input precision; interior
    carries are rounded to their consumer's precision and held in VMEM
    at the planned slot dtype (``slot_prec``) — kernel and reference
    apply identical rounding, so they remain bitwise-comparable.
    """
    if interpret is None:
        interpret = default_interpret()
    if use_kernel is None:
        use_kernel = not interpret
    complex_mode = any(jnp.iscomplexobj(o) for o in operands)
    comps = []
    for o in operands:
        o = jnp.asarray(o)
        if complex_mode:
            comps.append(jnp.real(o).astype(jnp.float32))
            comps.append(jnp.imag(o).astype(jnp.float32))
        else:
            comps.append(o.astype(jnp.float32))
    kw = dict(
        forms=tuple(forms), carry_side=tuple(carry_side),
        complex_mode=complex_mode,
        precisions=tuple(precisions) if precisions is not None else None,
    )
    with _trace.annotate("ops.fused_chain"):
        if use_kernel:
            out = fused_chain_matmul(
                *comps, slot_ids=tuple(slot_ids),
                slot_elems=tuple(slot_elems), interpret=interpret,
                slot_prec=tuple(slot_prec) if slot_prec is not None
                else None,
                **kw,
            )
        else:
            out = chain_reference(comps, **kw)
    if complex_mode:
        re, im = out
        return re + 1j * im
    return out[0]


def attention(
    q: jax.Array,  # (batch, seq_q, n_heads, d)
    k: jax.Array,  # (batch, seq_k, n_kv, d)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Multi-head attention with GQA, (b, s, h, d) layout.

    The kernel path broadcasts KV heads to Q heads and flattens (b, h);
    decode paths (seq_q below tile size) use the reference (they are
    bandwidth-, not compute-bound)."""
    if interpret is None:
        interpret = default_interpret()
    batch, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    if (
        not use_kernel
        or sq % bq
        or sk % bk
        or q_offset % bq
        or d % 8
    ):
        # reference path (decode steps, ragged shapes)
        qf = q.transpose(0, 2, 1, 3).reshape(batch * hq, sq, d)
        kf = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1).reshape(
            batch * hq, sk, d
        )
        vf = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1).reshape(
            batch * hq, sk, d
        )
        o = ref.attention_ref(qf, kf, vf, causal=causal, q_offset=q_offset)
        return o.reshape(batch, hq, sq, d).transpose(0, 2, 1, 3)
    qf = q.transpose(0, 2, 1, 3).reshape(batch * hq, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), group, axis=1).reshape(
        batch * hq, sk, d
    )
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), group, axis=1).reshape(
        batch * hq, sk, d
    )
    o = flash_attention(
        qf, kf, vf, bq=bq, bk=bk, causal=causal, q_offset=q_offset,
        interpret=interpret,
    )
    return o.reshape(batch, hq, sq, d).transpose(0, 2, 1, 3)


def ssd_scan(
    x: jax.Array,  # (BH, T, D)
    dt: jax.Array,  # (BH, T)
    a: jax.Array,  # (BH, T) per-step log decay
    b: jax.Array,  # (BH, T, S)
    c: jax.Array,  # (BH, T, S)
    *,
    chunk: int = 64,
    state0: jax.Array | None = None,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: Pallas intra-chunk + lax.scan inter-chunk combine.

    Returns (y (BH,T,D) fp32, final_state (BH,S,D) fp32).
    """
    if interpret is None:
        interpret = default_interpret()
    BH, T, D = x.shape
    S = b.shape[-1]
    if not use_kernel or T % chunk:
        return ref.ssd_scan_ref(x, dt, a, b, c, state0)
    C = T // chunk
    xr = x.reshape(BH, C, chunk, D)
    dtr = dt.reshape(BH, C, chunk)
    ar = a.reshape(BH, C, chunk).astype(jnp.float32)
    br = b.reshape(BH, C, chunk, S)
    cr = c.reshape(BH, C, chunk, S)
    y_intra, chunk_states = ssd_intra_chunk(
        xr, dtr, ar, br, cr, interpret=interpret
    )
    # inter-chunk recurrence over C steps
    cum_a = jnp.cumsum(ar, axis=2)  # (BH, C, L)
    chunk_decay = jnp.exp(cum_a[:, :, -1])  # (BH, C) total decay of chunk
    h0 = (
        jnp.zeros((BH, S, D), jnp.float32)
        if state0 is None
        else state0.astype(jnp.float32)
    )

    def step(h, inp):
        st_c, decay_c = inp  # (BH,S,D), (BH,)
        h_in = h  # state entering this chunk
        h_out = decay_c[:, None, None] * h + st_c
        return h_out, h_in

    states_seq = (
        jnp.moveaxis(chunk_states, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
    )
    h_final, h_ins = jax.lax.scan(step, h0, states_seq)
    h_ins = jnp.moveaxis(h_ins, 0, 1)  # (BH, C, S, D) state entering chunk
    # cross-chunk contribution: y_t += c_t · (decay_to_t · h_in)
    decay_to_t = jnp.exp(cum_a)  # (BH, C, L) decay from chunk start to t
    y_cross = jnp.einsum(
        "bcls,bcsd,bcl->bcld", cr.astype(jnp.float32), h_ins, decay_to_t
    )
    y = (y_intra + y_cross).reshape(BH, T, D)
    return y, h_final
