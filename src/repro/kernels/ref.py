"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """q: (bh, sq, d), k/v: (bh, sk, d) — naive softmax attention."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    if causal:
        qp = q_offset + jnp.arange(sq)[:, None]
        kp = jnp.arange(sk)[None, :]
        s = jnp.where(qp >= kp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # (BH, T, D)
    dt: jax.Array,  # (BH, T)
    a: jax.Array,  # (BH, T) per-step log decay
    b: jax.Array,  # (BH, T, S)
    c: jax.Array,  # (BH, T, S)
    state0: jax.Array | None = None,  # (BH, S, D)
) -> tuple[jax.Array, jax.Array]:
    """Sequential (exact) selective-scan reference:

        h_t = exp(a_t) h_{t-1} + b_t (dt_t x_t)ᵀ ;  y_t = c_t h_t
    """
    BH, T, D = x.shape
    S = b.shape[-1]
    h0 = (
        jnp.zeros((BH, S, D), jnp.float32)
        if state0 is None
        else state0.astype(jnp.float32)
    )

    def step(h, inp):
        x_t, dt_t, a_t, b_t, c_t = inp
        h = jnp.exp(a_t)[:, None, None] * h + jnp.einsum(
            "bs,bd->bsd", b_t, x_t * dt_t[:, None]
        )
        y = jnp.einsum("bs,bsd->bd", c_t, h)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(a.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
