"""Tiled stem-contraction GEMM — the paper's compute hot-spot, TPU-native.

The contraction of two stem tensors is a (2^m × 2^k) @ (2^k × 2^n) GEMM
(Sec. V-A).  On Sunway the paper fights SWTT's 8×8 kernel quantization and
DMA bandwidth; the TPU analogue is MXU 128×128 tile quantization and
HBM→VMEM bandwidth.  This kernel:

  * tiles (bm × bk) @ (bk × bn) blocks into VMEM via BlockSpec — block
    shapes are chosen 128-aligned so the MXU sees full tiles,
  * walks K as the innermost (sequential) grid axis, accumulating into the
    revisited output block in fp32 (``preferred_element_type``) — the
    bf16-compute/fp32-accumulate mixed precision the paper uses on Sunway
    (fp16/fp32) mapped to the TPU-native pair,
  * leaves M as the outermost axis so slice-batched stems (executor vmap)
    stream through without re-fetching B.

Validated against ref.matmul_ref in interpret mode (this container is
CPU-only; TPU is the target).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_tiles: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with fp32 accumulation.  Dims must divide the block shape
    (ops.matmul pads); returns fp32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n),
        (bm, bk, bn),
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


# ----------------------------------------------------------------------
# fused transpose-GEMM (Sec. V): the layout permutation rides inside the
# kernel instead of materializing transposed operand copies in HBM
# ----------------------------------------------------------------------
def suffix_tile_split(shape: tuple[int, ...], target: int) -> tuple[int, int, int]:
    """Split a role group's dims into (grid prefix, tile suffix).

    Returns ``(n_prefix, grid, tile)``: the longest suffix of ``shape``
    whose product stays ``<= target`` becomes the in-kernel tile
    (``tile`` = its product); the remaining prefix axes are enumerated by
    the grid (``grid`` = their product).  Because the boundary sits on an
    axis boundary, every tile is an exact rectangular block of the
    operand's *native* layout — the fused kernel never pads.
    """
    tile = 1
    j = len(shape)
    while j > 0 and tile * shape[j - 1] <= target:
        j -= 1
        tile *= shape[j]
    grid = 1
    for d in shape[:j]:
        grid *= d
    return j, grid, tile


def _coords(idx, dims: tuple[int, ...]) -> list:
    """Row-major multi-index of flat ``idx`` over ``dims`` (traced-safe)."""
    out = []
    rem = idx
    for d in reversed(dims):
        out.append(rem % d)
        rem = rem // d
    out.reverse()
    return out


def _operand_index_map(role_of, bshape, pre_shape_1, pre_shape_2, which):
    """index_map factory for one operand in its native layout.

    ``role_of[p] = (kind, pos)`` classifies native axis ``p``; prefix
    positions take their grid coordinate, suffix positions are covered by
    a full-size block (block index 0).  ``which`` selects which two grid
    axes this operand consumes (a: (m, k); b: (k, n); out: (m, n))."""

    def index_map(b, i, j, kk):
        g1 = {"a": i, "b": kk, "o": i}[which]
        g2 = {"a": kk, "b": j, "o": j}[which]
        bc = _coords(b, bshape)
        c1 = _coords(g1, pre_shape_1)
        c2 = _coords(g2, pre_shape_2)
        out = []
        for kind, pos in role_of:
            if kind == "batch":
                out.append(bc[pos])
            elif kind == "first":
                out.append(c1[pos] if pos < len(pre_shape_1) else 0)
            else:  # "second"
                out.append(c2[pos] if pos < len(pre_shape_2) else 0)
        return tuple(out)

    return index_map


def _fused_kernel(
    a_ref, b_ref, o_ref, *, perm_a, perm_b, tile_m, tile_n, tile_k, out_block
):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # the permutation happens here, on the VMEM-resident tile: the loaded
    # blocks keep the operands' native axis order, so the HBM copies of
    # a2/b2 that the reference path materializes never exist.
    at = jnp.transpose(a_ref[...], perm_a).reshape(tile_m, tile_k)
    bt = jnp.transpose(b_ref[...], perm_b).reshape(tile_k, tile_n)
    o_ref[...] += jnp.dot(
        at, bt, preferred_element_type=jnp.float32
    ).reshape(out_block)


@functools.partial(
    jax.jit,
    static_argnames=(
        "perm_a", "perm_b", "nb", "nm", "nn", "nk", "bm", "bn", "bk",
        "interpret",
    ),
)
def fused_transpose_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    perm_a: tuple[int, ...],
    perm_b: tuple[int, ...],
    nb: int,
    nm: int,
    nn: int,
    nk: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Batched GEMM over operands in their *native* (contraction-tree)
    layouts — the paper's Sec. V fused permute-GEMM, TPU-native.

    ``perm_a`` orders ``a``'s native axes as (batch..., m..., k...) and
    ``perm_b`` orders ``b``'s as (batch..., k..., n...) — exactly the
    permutations the reference path materializes via
    ``jnp.transpose(...).reshape(...)``.  Here they stay *virtual*: the
    ``index_map`` of each BlockSpec walks the native layout so every grid
    cell DMAs an axis-aligned native block into VMEM, and the kernel
    permutes that tile in-register before the MXU dot.  Tiles are exact
    axis-suffix blocks (see :func:`suffix_tile_split`), so — unlike the
    pad-or-split reference — the fused kernel executes zero padding
    FLOPs and moves ``2*(|A|+|B|)`` fewer bytes of HBM traffic.

    ``bm/bn/bk`` are tile-size *targets*; the effective tile is the
    largest axis-suffix product per role group that fits the target.
    Returns the un-permuted natural output (batch..., m..., n...) with
    one axis per role index, accumulated in fp32 (the kernel family's
    bf16-compute / fp32-accumulate convention).
    """
    assert len(perm_a) == nb + nm + nk == a.ndim, (perm_a, nb, nm, nk, a.shape)
    assert len(perm_b) == nb + nk + nn == b.ndim, (perm_b, nb, nk, nn, b.shape)
    ax_ab, ax_am, ax_ak = perm_a[:nb], perm_a[nb:nb + nm], perm_a[nb + nm:]
    ax_bb, ax_bk, ax_bn = perm_b[:nb], perm_b[nb:nb + nk], perm_b[nb + nk:]
    batch_shape = tuple(a.shape[p] for p in ax_ab)
    m_shape = tuple(a.shape[p] for p in ax_am)
    k_shape = tuple(a.shape[p] for p in ax_ak)
    n_shape = tuple(b.shape[p] for p in ax_bn)
    assert tuple(b.shape[p] for p in ax_bb) == batch_shape
    assert tuple(b.shape[p] for p in ax_bk) == k_shape

    jm, grid_m, tile_m = suffix_tile_split(m_shape, bm)
    jn, grid_n, tile_n = suffix_tile_split(n_shape, bn)
    jk, grid_k, tile_k = suffix_tile_split(k_shape, bk)
    B = math.prod(batch_shape)

    # per-native-axis roles + block shapes for a, b, and the natural output
    def spec_for(batch_axes, first_axes, first_shape, j_first,
                 second_axes, second_shape, j_second, shape, which):
        role = {}
        for i, p in enumerate(batch_axes):
            role[p] = ("batch", i)
        for i, p in enumerate(first_axes):
            role[p] = ("first", i)
        for i, p in enumerate(second_axes):
            role[p] = ("second", i)
        role_of = tuple(role[p] for p in range(len(shape)))
        block = []
        for p in range(len(shape)):
            kind, pos = role[p]
            if kind == "batch":
                block.append(1)
            elif kind == "first":
                block.append(1 if pos < j_first else first_shape[pos])
            else:
                block.append(1 if pos < j_second else second_shape[pos])
        imap = _operand_index_map(
            role_of, batch_shape, first_shape[:j_first],
            second_shape[:j_second], which,
        )
        return pl.BlockSpec(tuple(block), imap), tuple(block)

    a_spec, _ = spec_for(
        ax_ab, ax_am, m_shape, jm, ax_ak, k_shape, jk, a.shape, "a"
    )
    b_spec, _ = spec_for(
        ax_bb, ax_bk, k_shape, jk, ax_bn, n_shape, jn, b.shape, "b"
    )
    # natural output layout: (batch..., m..., n...) in role order
    out_shape = batch_shape + m_shape + n_shape
    o_batch = tuple(range(nb))
    o_m = tuple(range(nb, nb + nm))
    o_n = tuple(range(nb + nm, nb + nm + nn))
    o_spec, o_block = spec_for(
        o_batch, o_m, m_shape, jm, o_n, n_shape, jn, out_shape, "o"
    )

    # tile-local permutations: the loaded blocks keep native axis order,
    # so the operands' own perms re-order them to role order exactly as
    # the reference path's HBM transpose would.
    return pl.pallas_call(
        functools.partial(
            _fused_kernel,
            perm_a=perm_a,
            perm_b=perm_b,
            tile_m=tile_m,
            tile_n=tile_n,
            tile_k=tile_k,
            out_block=o_block,
        ),
        grid=(B, grid_m, grid_n, grid_k),
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(a, b)


# ----------------------------------------------------------------------
# epilogue megakernel: a *run* of adjacent tree GEMMs executes as one
# persistent kernel — chain intermediates live in VMEM scratch slots
# assigned by the lifetime planner's linear scan, never touching HBM
# ----------------------------------------------------------------------
def _chain_step_math(a, b, form, *, unroll_batch: bool,
                     precision: str = "fp32"):
    """One chained step on VMEM-resident values, in tree-native
    transpose-GEMM form.

    ``a``/``b`` are either fp32 arrays (real chain) or ``(re, im)`` fp32
    pairs (complex chain — the carry stays split through the whole chain;
    per-step Karatsuba, 3 real GEMMs).  ``unroll_batch=True`` issues one
    2-D MXU dot per batch cell — the exact dots (and accumulation order)
    :func:`fused_transpose_matmul` executes per grid cell, which is what
    makes the megakernel bitwise-reproducible against the unfused chain;
    ``False`` uses one batched ``dot_general`` (the off-TPU reference
    dataflow).  Returns the step output permuted to the executor's
    ``inds_out`` order — the native layout of the next step's operand.

    ``precision="bf16"`` rounds the GEMM inputs to bf16 (fp32
    accumulation).  Incoming components are first widened to fp32 — an
    exact no-op for bf16-stored carries — so the Karatsuba sums always
    run in fp32 before the single rounding at the MXU boundary, matching
    the unfused backends' cast placement exactly."""

    def gemm(x, y):
        xa = jnp.transpose(x, form.perm_a).reshape(form.B, form.M, form.K)
        yb = jnp.transpose(y, form.perm_b).reshape(form.B, form.K, form.N)
        if precision == "bf16":
            xa = xa.astype(jnp.bfloat16)
            yb = yb.astype(jnp.bfloat16)
        if unroll_batch or form.B == 1:
            out = jnp.stack(
                [
                    jnp.dot(
                        xa[i], yb[i], preferred_element_type=jnp.float32
                    )
                    for i in range(form.B)
                ]
            )
        else:
            out = jax.lax.dot_general(
                xa,
                yb,
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            )
        out = out.reshape(form.batch_shape + form.m_shape + form.n_shape)
        if form.out_perm != tuple(range(out.ndim)):
            out = jnp.transpose(out, form.out_perm)
        return out

    if isinstance(a, tuple):
        ar, ai = (c.astype(jnp.float32) for c in a)
        br, bi = (c.astype(jnp.float32) for c in b)
        p1 = gemm(ar, br)
        p2 = gemm(ai, bi)
        p3 = gemm(ar + ai, br + bi)
        return (p1 - p2, p3 - p1 - p2)
    return gemm(a.astype(jnp.float32), b.astype(jnp.float32))


def _run_chain(read_ext, forms, carry_side, *, ncomp, unroll_batch,
               store_carry=None, precisions=None):
    """Shared chain dataflow: the kernel body and the off-TPU reference
    both walk this exact sequence, so they agree step for step.
    ``store_carry(t, comps)`` routes an interior carry through its VMEM
    scratch slot (kernel) or passes it through (reference).

    ``precisions[t]`` is step ``t``'s GEMM input precision.  An interior
    carry is rounded to its *consumer's* precision before being stored
    (kernel) or carried (reference) — the chain-interior intermediate
    lives at the planned precision, and because the consumer would round
    it identically at the MXU boundary anyway, kernel and reference stay
    bitwise-identical regardless of the scratch slot's physical dtype."""
    carry = None
    for t, form in enumerate(forms):
        prec = precisions[t] if precisions is not None else "fp32"
        if t == 0:
            a, b = read_ext(), read_ext()
        else:
            ext = read_ext()
            a, b = (carry, ext) if carry_side[t] == "l" else (ext, carry)
        val = _chain_step_math(
            a, b, form, unroll_batch=unroll_batch, precision=prec
        )
        comps = val if ncomp == 2 else (val,)
        if t + 1 < len(forms):
            next_prec = (
                precisions[t + 1] if precisions is not None else "fp32"
            )
            if next_prec == "bf16":
                comps = tuple(c.astype(jnp.bfloat16) for c in comps)
            if store_carry is not None:
                comps = store_carry(t, comps)
        carry = comps if ncomp == 2 else comps[0]
    return carry if ncomp == 2 else (carry,)


def _chain_kernel(*refs, forms, carry_side, slot_ids, ncomp, n_ext,
                  precisions=None):
    ext_refs = refs[:n_ext * ncomp]
    out_refs = refs[n_ext * ncomp:n_ext * ncomp + ncomp]
    scratch = refs[n_ext * ncomp + ncomp:]
    cursor = [0]

    def read_ext():
        i = cursor[0]
        cursor[0] += 1
        vals = tuple(ext_refs[i * ncomp + c][...] for c in range(ncomp))
        return vals if ncomp == 2 else vals[0]

    def store_carry(t, comps):
        # flat store into the planner-assigned slot, then read back in
        # the carry's shape: the intermediate lives only in this VMEM
        # scratch buffer — the HBM round-trip of the unfused path never
        # happens.  Slot reuse across steps (ping-pong) is exactly the
        # linear-scan assignment certified at plan time.  A bf16-rounded
        # carry stored in a wider (shared) fp32 slot is held exactly.
        sid = slot_ids[t]
        stored = []
        for c, v in enumerate(comps):
            ref = scratch[sid * ncomp + c]
            flat = v.astype(ref.dtype).reshape(-1)
            ref[0:flat.size] = flat
            stored.append(ref[0:flat.size].reshape(v.shape))
        return tuple(stored)

    outs = _run_chain(
        read_ext, forms, carry_side, ncomp=ncomp, unroll_batch=True,
        store_carry=store_carry, precisions=precisions,
    )
    for c in range(ncomp):
        out_refs[c][...] = outs[c]


@functools.partial(
    jax.jit,
    static_argnames=(
        "forms", "carry_side", "slot_ids", "slot_elems", "complex_mode",
        "interpret", "precisions", "slot_prec",
    ),
)
def fused_chain_matmul(
    *operands: jax.Array,
    forms: tuple,
    carry_side: tuple[str, ...],
    slot_ids: tuple[int, ...],
    slot_elems: tuple[int, ...],
    complex_mode: bool = False,
    interpret: bool = False,
    precisions: tuple[str, ...] | None = None,
    slot_prec: tuple[str, ...] | None = None,
):
    """Persistent megakernel for a run of adjacent tree GEMMs.

    ``forms`` are the chain's :class:`~repro.lowering.gemm_form.GemmForm`
    steps in execution order; step ``t``'s carry operand is the previous
    step's output (``carry_side[t]`` says which side, ``""`` for step 0).
    ``operands`` are the chain's *external* inputs — step 0's pair, then
    one non-carry operand per later step — each in its tree-native
    layout.  In ``complex_mode`` every logical operand is passed as two
    fp32 components ``(re, im)`` and the kernel returns the pair; the
    carry stays component-split end to end, with each step running the
    3-real-GEMM Karatsuba.

    The whole chain executes as one grid-less ``pallas_call``: operands
    are DMA'd to VMEM once, every intermediate lives in a VMEM scratch
    slot (``slot_ids[t]`` = slot of step ``t``'s output, ``slot_elems`` =
    per-slot capacity in logical elements — both straight from the
    lifetime planner's linear-scan assignment, see
    :func:`repro.lowering.memory.chain_segment_plan`), and only the final
    output is written back — zero HBM round-trips between chained steps.
    Returns a tuple of ``ncomp`` fp32 arrays in the executor's
    ``inds_out`` order of the last step.

    ``precisions[t]`` is step ``t``'s GEMM input precision ("fp32" /
    "bf16"-input-fp32-accumulate); ``slot_prec`` gives each scratch
    slot's physical dtype — "bf16" (half the VMEM bytes) when every
    intermediate assigned to the slot is consumed at bf16.  Both default
    to all-fp32.
    """
    ncomp = 2 if complex_mode else 1
    n_ext = len(forms) + 1
    assert len(operands) == n_ext * ncomp, (len(operands), n_ext, ncomp)
    assert len(slot_ids) == len(forms) - 1, (slot_ids, len(forms))
    if precisions is not None:
        assert len(precisions) == len(forms), (precisions, len(forms))
    slot_dtypes = tuple(
        jnp.bfloat16
        if slot_prec is not None and i < len(slot_prec)
        and slot_prec[i] == "bf16"
        else jnp.float32
        for i in range(len(slot_elems))
    )
    f = forms[-1]
    natural = f.batch_shape + f.m_shape + f.n_shape
    oshape = tuple(natural[p] for p in f.out_perm)
    out = pl.pallas_call(
        functools.partial(
            _chain_kernel,
            forms=forms,
            carry_side=carry_side,
            slot_ids=slot_ids,
            ncomp=ncomp,
            n_ext=n_ext,
            precisions=precisions,
        ),
        out_shape=tuple(
            jax.ShapeDtypeStruct(oshape, jnp.float32) for _ in range(ncomp)
        ),
        scratch_shapes=[
            pltpu.VMEM((e,), dt)
            for e, dt in zip(slot_elems, slot_dtypes)
            for _ in range(ncomp)
        ],
        interpret=interpret,
    )(*operands)
    return tuple(out)


def chain_reference(
    components,
    *,
    forms: tuple,
    carry_side: tuple[str, ...],
    complex_mode: bool = False,
    precisions: tuple[str, ...] | None = None,
):
    """The megakernel's dataflow in plain jnp — same externals, same
    per-step Karatsuba on split fp32 components, same step order, same
    interior-carry precision rounding — used off-TPU where
    interpret-mode Pallas would be pure-Python slow.  Batch cells run as
    one batched ``dot_general`` (XLA fuses the whole chain into one
    program); agreement with the kernel is to fp32 tolerance, and exact
    when every step has ``B == 1``."""
    ncomp = 2 if complex_mode else 1
    cursor = [0]

    def read_ext():
        i = cursor[0]
        cursor[0] += 1
        vals = tuple(components[i * ncomp + c] for c in range(ncomp))
        return vals if ncomp == 2 else vals[0]

    return _run_chain(
        read_ext, forms, carry_side, ncomp=ncomp, unroll_batch=False,
        precisions=precisions,
    )
