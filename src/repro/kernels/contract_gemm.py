"""Tiled stem-contraction GEMM — the paper's compute hot-spot, TPU-native.

The contraction of two stem tensors is a (2^m × 2^k) @ (2^k × 2^n) GEMM
(Sec. V-A).  On Sunway the paper fights SWTT's 8×8 kernel quantization and
DMA bandwidth; the TPU analogue is MXU 128×128 tile quantization and
HBM→VMEM bandwidth.  This kernel:

  * tiles (bm × bk) @ (bk × bn) blocks into VMEM via BlockSpec — block
    shapes are chosen 128-aligned so the MXU sees full tiles,
  * walks K as the innermost (sequential) grid axis, accumulating into the
    revisited output block in fp32 (``preferred_element_type``) — the
    bf16-compute/fp32-accumulate mixed precision the paper uses on Sunway
    (fp16/fp32) mapped to the TPU-native pair,
  * leaves M as the outermost axis so slice-batched stems (executor vmap)
    stream through without re-fetching B.

Validated against ref.matmul_ref in interpret mode (this container is
CPU-only; TPU is the target).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_tiles: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with fp32 accumulation.  Dims must divide the block shape
    (ops.matmul pads); returns fp32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n),
        (bm, bk, bn),
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


# ----------------------------------------------------------------------
# fused transpose-GEMM (Sec. V): the layout permutation rides inside the
# kernel instead of materializing transposed operand copies in HBM
# ----------------------------------------------------------------------
def suffix_tile_split(shape: tuple[int, ...], target: int) -> tuple[int, int, int]:
    """Split a role group's dims into (grid prefix, tile suffix).

    Returns ``(n_prefix, grid, tile)``: the longest suffix of ``shape``
    whose product stays ``<= target`` becomes the in-kernel tile
    (``tile`` = its product); the remaining prefix axes are enumerated by
    the grid (``grid`` = their product).  Because the boundary sits on an
    axis boundary, every tile is an exact rectangular block of the
    operand's *native* layout — the fused kernel never pads.
    """
    tile = 1
    j = len(shape)
    while j > 0 and tile * shape[j - 1] <= target:
        j -= 1
        tile *= shape[j]
    grid = 1
    for d in shape[:j]:
        grid *= d
    return j, grid, tile


def _coords(idx, dims: tuple[int, ...]) -> list:
    """Row-major multi-index of flat ``idx`` over ``dims`` (traced-safe)."""
    out = []
    rem = idx
    for d in reversed(dims):
        out.append(rem % d)
        rem = rem // d
    out.reverse()
    return out


def _operand_index_map(role_of, bshape, pre_shape_1, pre_shape_2, which):
    """index_map factory for one operand in its native layout.

    ``role_of[p] = (kind, pos)`` classifies native axis ``p``; prefix
    positions take their grid coordinate, suffix positions are covered by
    a full-size block (block index 0).  ``which`` selects which two grid
    axes this operand consumes (a: (m, k); b: (k, n); out: (m, n))."""

    def index_map(b, i, j, kk):
        g1 = {"a": i, "b": kk, "o": i}[which]
        g2 = {"a": kk, "b": j, "o": j}[which]
        bc = _coords(b, bshape)
        c1 = _coords(g1, pre_shape_1)
        c2 = _coords(g2, pre_shape_2)
        out = []
        for kind, pos in role_of:
            if kind == "batch":
                out.append(bc[pos])
            elif kind == "first":
                out.append(c1[pos] if pos < len(pre_shape_1) else 0)
            else:  # "second"
                out.append(c2[pos] if pos < len(pre_shape_2) else 0)
        return tuple(out)

    return index_map


def _fused_kernel(
    a_ref, b_ref, o_ref, *, perm_a, perm_b, tile_m, tile_n, tile_k, out_block
):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # the permutation happens here, on the VMEM-resident tile: the loaded
    # blocks keep the operands' native axis order, so the HBM copies of
    # a2/b2 that the reference path materializes never exist.
    at = jnp.transpose(a_ref[...], perm_a).reshape(tile_m, tile_k)
    bt = jnp.transpose(b_ref[...], perm_b).reshape(tile_k, tile_n)
    o_ref[...] += jnp.dot(
        at, bt, preferred_element_type=jnp.float32
    ).reshape(out_block)


@functools.partial(
    jax.jit,
    static_argnames=(
        "perm_a", "perm_b", "nb", "nm", "nn", "nk", "bm", "bn", "bk",
        "interpret",
    ),
)
def fused_transpose_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    perm_a: tuple[int, ...],
    perm_b: tuple[int, ...],
    nb: int,
    nm: int,
    nn: int,
    nk: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Batched GEMM over operands in their *native* (contraction-tree)
    layouts — the paper's Sec. V fused permute-GEMM, TPU-native.

    ``perm_a`` orders ``a``'s native axes as (batch..., m..., k...) and
    ``perm_b`` orders ``b``'s as (batch..., k..., n...) — exactly the
    permutations the reference path materializes via
    ``jnp.transpose(...).reshape(...)``.  Here they stay *virtual*: the
    ``index_map`` of each BlockSpec walks the native layout so every grid
    cell DMAs an axis-aligned native block into VMEM, and the kernel
    permutes that tile in-register before the MXU dot.  Tiles are exact
    axis-suffix blocks (see :func:`suffix_tile_split`), so — unlike the
    pad-or-split reference — the fused kernel executes zero padding
    FLOPs and moves ``2*(|A|+|B|)`` fewer bytes of HBM traffic.

    ``bm/bn/bk`` are tile-size *targets*; the effective tile is the
    largest axis-suffix product per role group that fits the target.
    Returns the un-permuted natural output (batch..., m..., n...) with
    one axis per role index, accumulated in fp32 (the kernel family's
    bf16-compute / fp32-accumulate convention).
    """
    assert len(perm_a) == nb + nm + nk == a.ndim, (perm_a, nb, nm, nk, a.shape)
    assert len(perm_b) == nb + nk + nn == b.ndim, (perm_b, nb, nk, nn, b.shape)
    ax_ab, ax_am, ax_ak = perm_a[:nb], perm_a[nb:nb + nm], perm_a[nb + nm:]
    ax_bb, ax_bk, ax_bn = perm_b[:nb], perm_b[nb:nb + nk], perm_b[nb + nk:]
    batch_shape = tuple(a.shape[p] for p in ax_ab)
    m_shape = tuple(a.shape[p] for p in ax_am)
    k_shape = tuple(a.shape[p] for p in ax_ak)
    n_shape = tuple(b.shape[p] for p in ax_bn)
    assert tuple(b.shape[p] for p in ax_bb) == batch_shape
    assert tuple(b.shape[p] for p in ax_bk) == k_shape

    jm, grid_m, tile_m = suffix_tile_split(m_shape, bm)
    jn, grid_n, tile_n = suffix_tile_split(n_shape, bn)
    jk, grid_k, tile_k = suffix_tile_split(k_shape, bk)
    B = math.prod(batch_shape)

    # per-native-axis roles + block shapes for a, b, and the natural output
    def spec_for(batch_axes, first_axes, first_shape, j_first,
                 second_axes, second_shape, j_second, shape, which):
        role = {}
        for i, p in enumerate(batch_axes):
            role[p] = ("batch", i)
        for i, p in enumerate(first_axes):
            role[p] = ("first", i)
        for i, p in enumerate(second_axes):
            role[p] = ("second", i)
        role_of = tuple(role[p] for p in range(len(shape)))
        block = []
        for p in range(len(shape)):
            kind, pos = role[p]
            if kind == "batch":
                block.append(1)
            elif kind == "first":
                block.append(1 if pos < j_first else first_shape[pos])
            else:
                block.append(1 if pos < j_second else second_shape[pos])
        imap = _operand_index_map(
            role_of, batch_shape, first_shape[:j_first],
            second_shape[:j_second], which,
        )
        return pl.BlockSpec(tuple(block), imap), tuple(block)

    a_spec, _ = spec_for(
        ax_ab, ax_am, m_shape, jm, ax_ak, k_shape, jk, a.shape, "a"
    )
    b_spec, _ = spec_for(
        ax_bb, ax_bk, k_shape, jk, ax_bn, n_shape, jn, b.shape, "b"
    )
    # natural output layout: (batch..., m..., n...) in role order
    out_shape = batch_shape + m_shape + n_shape
    o_batch = tuple(range(nb))
    o_m = tuple(range(nb, nb + nm))
    o_n = tuple(range(nb + nm, nb + nm + nn))
    o_spec, o_block = spec_for(
        o_batch, o_m, m_shape, jm, o_n, n_shape, jn, out_shape, "o"
    )

    # tile-local permutations: the loaded blocks keep native axis order,
    # so the operands' own perms re-order them to role order exactly as
    # the reference path's HBM transpose would.
    return pl.pallas_call(
        functools.partial(
            _fused_kernel,
            perm_a=perm_a,
            perm_b=perm_b,
            tile_m=tile_m,
            tile_n=tile_n,
            tile_k=tile_k,
            out_block=o_block,
        ),
        grid=(B, grid_m, grid_n, grid_k),
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=interpret,
    )(a, b)
