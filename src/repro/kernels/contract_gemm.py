"""Tiled stem-contraction GEMM — the paper's compute hot-spot, TPU-native.

The contraction of two stem tensors is a (2^m × 2^k) @ (2^k × 2^n) GEMM
(Sec. V-A).  On Sunway the paper fights SWTT's 8×8 kernel quantization and
DMA bandwidth; the TPU analogue is MXU 128×128 tile quantization and
HBM→VMEM bandwidth.  This kernel:

  * tiles (bm × bk) @ (bk × bn) blocks into VMEM via BlockSpec — block
    shapes are chosen 128-aligned so the MXU sees full tiles,
  * walks K as the innermost (sequential) grid axis, accumulating into the
    revisited output block in fp32 (``preferred_element_type``) — the
    bf16-compute/fp32-accumulate mixed precision the paper uses on Sunway
    (fp16/fp32) mapped to the TPU-native pair,
  * leaves M as the outermost axis so slice-batched stems (executor vmap)
    stream through without re-fetching B.

Validated against ref.matmul_ref in interpret mode (this container is
CPU-only; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_tiles: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with fp32 accumulation.  Dims must divide the block shape
    (ops.matmul pads); returns fp32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n),
        (bm, bk, bn),
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
