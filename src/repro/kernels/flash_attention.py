"""Flash-attention forward kernel (fleet hot-spot for the LM architectures).

Grid is (batch·heads, q_tiles): each invocation owns one (bq × d) query
tile with the full K/V for that head resident in VMEM (32k × 128 × bf16 ≈
8 MB each — fits v5e's VMEM budget), streaming K in ``bk`` chunks with an
online-softmax accumulator.  Numerically stable (running max/sum), fp32
accumulation, optional causal masking, GQA handled by the ops wrapper
(K/V head broadcast before the call).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, *, bk: int, sm_scale: float, causal: bool,
    q_offset_tiles: int,
):
    # q_ref: (bq, d); k_ref/v_ref: (seq_k, d); o_ref: (bq, d)
    bq, d = q_ref.shape
    seq_k = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale
    q_tile = pl.program_id(1)
    q_start = (q_tile + q_offset_tiles) * bq

    def body(kk, carry):
        acc, m_i, l_i = carry
        ks = kk * bk
        k = k_ref[pl.ds(ks, bk), :].astype(jnp.float32)
        v = v_ref[pl.ds(ks, bk), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ks + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    n_kt = seq_k // bk
    if causal:
        # only K tiles at or before this Q tile's end participate
        n_kt_eff = jnp.minimum(
            n_kt, (q_start + bq + bk - 1) // bk
        )
    else:
        n_kt_eff = n_kt
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kt_eff, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "sm_scale", "interpret", "q_offset"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
    sm_scale: float | None = None,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q: (bh, seq_q, d); k, v: (bh, seq_k, d).  Returns (bh, seq_q, d).

    ``q_offset``: absolute position of q[0] (for causal decode where
    seq_q < seq_k); must be a multiple of bq.
    """
    bh, seq_q, d = q.shape
    _, seq_k, _ = k.shape
    assert seq_q % bq == 0 and seq_k % bk == 0 and q_offset % bq == 0
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    grid = (bh, seq_q // bq)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            bk=bk,
            sm_scale=sm_scale,
            causal=causal,
            q_offset_tiles=q_offset // bq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq_k, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq_k, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_q, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
