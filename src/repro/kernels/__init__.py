"""Pallas TPU kernels (validated in interpret mode on CPU).

contract_gemm    — tiled stem-contraction GEMM (the paper's hot-spot)
flash_attention  — fused online-softmax attention for the LM fleet
mamba2_ssd       — SSD intra-chunk kernel for mamba2/zamba2
ops              — jit'd wrappers (padding, complex Karatsuba, GQA, combine)
ref              — pure-jnp oracles

Kernel entry points are re-exported at the package root so the lowering
layer (:mod:`repro.lowering`) and tests import them without reaching
into submodules.
"""

from . import ops, ref  # noqa: F401
from .contract_gemm import (  # noqa: F401
    chain_reference,
    fused_chain_matmul,
    fused_transpose_matmul,
    suffix_tile_split,
    tiled_matmul,
)
from .flash_attention import flash_attention  # noqa: F401
from .mamba2_ssd import ssd_intra_chunk  # noqa: F401
from .ops import (  # noqa: F401
    attention,
    fused_chain,
    fused_matmul,
    matmul,
    ssd_scan,
)
