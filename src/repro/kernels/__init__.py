"""Pallas TPU kernels (validated in interpret mode on CPU).

contract_gemm    — tiled stem-contraction GEMM (the paper's hot-spot)
flash_attention  — fused online-softmax attention for the LM fleet
mamba2_ssd       — SSD intra-chunk kernel for mamba2/zamba2
ops              — jit'd wrappers (padding, complex Karatsuba, GQA, combine)
ref              — pure-jnp oracles
"""

from . import ops, ref  # noqa: F401
