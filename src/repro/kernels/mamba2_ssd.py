"""Mamba-2 SSD (state-space duality) intra-chunk kernel.

The SSD algorithm (arXiv:2405.21060) splits the selective-scan into
matmul-heavy *intra-chunk* work (quadratic in the chunk length — MXU food)
and a cheap linear *inter-chunk* state recurrence.  This kernel computes,
for one (batch·head, chunk) grid cell with chunk length L, state size S,
head dim D:

    L_mat[i,j] = exp(cum_a[i] - cum_a[j]) · 1[i ≥ j]      (decay matrix)
    Y_intra    = ((C Bᵀ) ⊙ L_mat) · (dt ⊙ X)              (L×L @ L×D)
    state_out  = Σ_j exp(cum_a[L-1] - cum_a[j]) B_j (dt_j X_j)ᵀ  (S×D)

The inter-chunk combine (carrying state with per-chunk decay and adding
C · state_in) is a short ``lax.scan`` in ops.ssd_scan — O(seq/L) steps of
O(S·D) work, negligible next to the intra-chunk matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    # shapes per grid cell: x (L, D), dt (L, 1), a (L, 1), b (L, S), c (L, S)
    L, D = x_ref.shape
    S = b_ref.shape[1]
    x = x_ref[...].astype(jnp.float32)
    dt = dt_ref[...].astype(jnp.float32)  # (L, 1)
    a = a_ref[...].astype(jnp.float32)  # (L, 1) — per-step log-decay dt*A
    b = b_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)

    cum_a = jnp.cumsum(a[:, 0])  # (L,)
    # decay matrix: exp(cum_a[i] - cum_a[j]) for i >= j else 0
    diff = cum_a[:, None] - cum_a[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    l_mat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32) * l_mat
    xdt = x * dt  # (L, D)
    y_ref[...] = jnp.dot(scores, xdt, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )
    # chunk state: (S, D) = Σ_j decay_to_end[j] · b[j]ᵀ (xdt)[j]
    decay_end = jnp.exp(cum_a[L - 1] - cum_a)  # (L,)
    st_ref[...] = jnp.dot(
        (b * decay_end[:, None]).T, xdt, preferred_element_type=jnp.float32
    ).astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(
    x: jax.Array,  # (BH, C, L, D)
    dt: jax.Array,  # (BH, C, L)
    a: jax.Array,  # (BH, C, L)  per-step log decay (dt * A_log)
    b: jax.Array,  # (BH, C, L, S)
    c: jax.Array,  # (BH, C, L, S)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (BH,C,L,D) fp32, chunk_states (BH,C,S,D) fp32)."""
    BH, C, L, D = x.shape
    S = b.shape[-1]
    grid = (BH, C)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, L, D), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, L, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, L, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, L, S), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, L, S), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, L, D), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, S, D), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, C, L, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, C, S, D), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt[..., None], a[..., None], b, c)
    return y, st
