"""Linear cross-entropy benchmarking (Eq. 1 of the paper)."""

from __future__ import annotations

import numpy as np


def linear_xeb(num_qubits: int, sample_probs: np.ndarray) -> float:
    """F_XEB = 2^n / k * Σ p_C(s_i) - 1 over k sampled bitstrings."""
    k = len(sample_probs)
    return float(2.0 ** num_qubits / k * np.sum(sample_probs) - 1.0)


def porter_thomas_expectation(num_qubits: int) -> float:
    """For an ideal Haar-random state, E[F_XEB] → 1 (large n)."""
    n = 2.0 ** num_qubits
    return float((2.0 * n / (n + 1.0)) - 1.0)


def xeb_from_amplitudes(num_qubits: int, amplitudes: np.ndarray) -> float:
    """Linear XEB of a sampled set given the samples' *amplitudes* (as
    returned by the batched open-index contraction): F = 2^n/k·Σ|a_i|^2 - 1.
    """
    return linear_xeb(num_qubits, np.abs(np.asarray(amplitudes)) ** 2)


def sample_bitstrings(
    probs: np.ndarray, k: int, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.choice(len(probs), size=k, p=probs / probs.sum())
