"""Dense statevector simulator — correctness oracle for the contraction
executor (feasible to ~20 qubits)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .circuits import Circuit


def simulate(circuit: Circuit) -> jnp.ndarray:
    """Full statevector of ``circuit`` applied to |0…0>, shape (2,)*n."""
    n = circuit.num_qubits
    psi = jnp.zeros((2,) * n, dtype=jnp.complex64)
    psi = psi.at[(0,) * n].set(1.0)
    for op in circuit.ops:
        arr = jnp.asarray(op.array())
        if len(op.qubits) == 1:
            (q,) = op.qubits
            psi = jnp.tensordot(arr, psi, axes=[[1], [q]])
            psi = jnp.moveaxis(psi, 0, q)
        else:
            a, b = op.qubits
            g = arr.reshape(2, 2, 2, 2)  # (a_out, b_out, a_in, b_in)
            psi = jnp.tensordot(g, psi, axes=[[2, 3], [a, b]])
            psi = jnp.moveaxis(psi, (0, 1), (a, b))
    return psi


def amplitude(circuit: Circuit, bitstring: str) -> complex:
    psi = simulate(circuit)
    idx = tuple(int(b) for b in bitstring)
    return complex(psi[idx])


def probabilities(circuit: Circuit) -> np.ndarray:
    psi = np.asarray(simulate(circuit)).reshape(-1)
    return np.abs(psi) ** 2
