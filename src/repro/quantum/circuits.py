"""Random quantum circuit generators and circuit → tensor-network lowering.

``sycamore_like``/``zuchongzhi_like`` follow the published RQC recipe:
each cycle applies a random single-qubit gate from {√X, √Y, √W} (never
repeating the previous gate on that qubit) to every qubit, followed by
two-qubit fSim couplers on a cycling pattern of grid edges (ABCDCDAB for
Sycamore, ABCDABCD-like for Zuchongzhi).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Sequence

import numpy as np

from ..core.tensor_network import TensorNetwork
from . import gates


@dataclasses.dataclass
class GateOp:
    name: str
    qubits: tuple[int, ...]
    params: tuple = ()

    def array(self) -> np.ndarray:
        return gates.gate_array(self.name, self.params)


@dataclasses.dataclass
class Circuit:
    num_qubits: int
    ops: list[GateOp]

    def depth_cycles(self) -> int:
        return sum(1 for op in self.ops if op.name == "cycle_marker")


def _grid_edges(rows: int, cols: int) -> dict[str, list[tuple[int, int]]]:
    """Sycamore-style A/B/C/D coupler patterns on a rows×cols grid."""

    def q(r, c):
        return r * cols + c

    pats: dict[str, list[tuple[int, int]]] = {"A": [], "B": [], "C": [], "D": []}
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:  # horizontal
                e = (q(r, c), q(r, c + 1))
                key = ("A", "B")[(r + c) % 2]
                pats[key].append(e)
            if r + 1 < rows:  # vertical
                e = (q(r, c), q(r + 1, c))
                key = ("C", "D")[(r + c) % 2]
                pats[key].append(e)
    return pats


def _random_layers(
    rows: int,
    cols: int,
    cycles: int,
    pattern_order: Sequence[str],
    seed: int,
    twoq_gate: str = "syc",
) -> Circuit:
    n = rows * cols
    rng = random.Random(seed)
    pats = _grid_edges(rows, cols)
    ops: list[GateOp] = []
    last = [None] * n
    names = list(gates.SINGLE_QUBIT_POOL)
    for cyc in range(cycles):
        for qb in range(n):
            choices = [g for g in names if g != last[qb]]
            g = rng.choice(choices)
            last[qb] = g
            ops.append(GateOp(g, (qb,)))
        pat = pattern_order[cyc % len(pattern_order)]
        for a, b in pats[pat]:
            ops.append(GateOp(twoq_gate, (a, b)))
    return Circuit(n, ops)


def sycamore_like(
    rows: int, cols: int, cycles: int, seed: int = 0
) -> Circuit:
    return _random_layers(rows, cols, cycles, "ABCDCDAB", seed)


def zuchongzhi_like(
    rows: int, cols: int, cycles: int, seed: int = 0
) -> Circuit:
    return _random_layers(rows, cols, cycles, "ABCD", seed)


def random_1d_circuit(n: int, cycles: int, seed: int = 0) -> Circuit:
    """1D chain RQC — small enough for statevector cross-checks."""
    rng = random.Random(seed)
    ops: list[GateOp] = []
    last = [None] * n
    names = list(gates.SINGLE_QUBIT_POOL)
    for cyc in range(cycles):
        for qb in range(n):
            g = rng.choice([x for x in names if x != last[qb]])
            last[qb] = g
            ops.append(GateOp(g, (qb,)))
        offset = cyc % 2
        for a in range(offset, n - 1, 2):
            ops.append(GateOp("syc", (a, a + 1)))
    return Circuit(n, ops)


# ----------------------------------------------------------------------
# circuit → tensor network
# ----------------------------------------------------------------------
def circuit_to_network(
    circuit: Circuit,
    bitstring: str | None = None,
    open_final: bool = False,
    open_qubits: Sequence[int] | None = None,
) -> tuple[TensorNetwork, list[np.ndarray]]:
    """Lower a circuit to (TensorNetwork, arrays).

    Initial state |0…0>.  If ``bitstring`` is given the final state is
    projected (closed network, scalar amplitude).  If ``open_final`` the
    final wire indices stay open (statevector-shaped output).

    ``open_qubits`` selects the *partial* projection used for batched
    correlated-amplitude sampling: the listed qubits keep their final wire
    open (one output axis each, ascending qubit order) while every other
    qubit is projected onto its ``bitstring`` value.  One contraction of
    the resulting network yields all ``2^k`` amplitudes that share the
    projected prefix — the paper's batch-per-slice sampling workload.
    """
    n = circuit.num_qubits
    seg = [0] * n  # current wire segment per qubit

    def wire(q: int) -> str:
        return f"q{q}_{seg[q]}"

    tensors: list[list[str]] = []
    arrays: list[np.ndarray] = []
    # initial |0> kets
    for q in range(n):
        tensors.append([wire(q)])
        arrays.append(np.array([1.0, 0.0], dtype=np.complex64))
    for op in circuit.ops:
        arr = op.array()
        if len(op.qubits) == 1:
            (q,) = op.qubits
            old = wire(q)
            seg[q] += 1
            new = wire(q)
            tensors.append([new, old])
            arrays.append(arr)  # (out, in)
        else:
            a, b = op.qubits
            old_a, old_b = wire(a), wire(b)
            seg[a] += 1
            seg[b] += 1
            new_a, new_b = wire(a), wire(b)
            tensors.append([new_a, new_b, old_a, old_b])
            arrays.append(arr.reshape(2, 2, 2, 2))
    open_inds: list[str] = []
    if open_qubits is not None:
        open_set = sorted(set(open_qubits))
        if any(q < 0 or q >= n for q in open_set):
            raise ValueError(f"open_qubits out of range for {n} qubits")
        if bitstring is None:
            bitstring = "0" * n
        assert len(bitstring) == n
        for q in range(n):
            if q in open_set:
                continue
            bra = np.zeros(2, dtype=np.complex64)
            bra[int(bitstring[q])] = 1.0
            tensors.append([wire(q)])
            arrays.append(bra)
        open_inds = [wire(q) for q in open_set]
    elif bitstring is not None:
        assert len(bitstring) == n
        for q in range(n):
            bra = np.zeros(2, dtype=np.complex64)
            bra[int(bitstring[q])] = 1.0
            tensors.append([wire(q)])
            arrays.append(bra)
    elif open_final:
        open_inds = [wire(q) for q in range(n)]
    return TensorNetwork(tensors, open_inds=open_inds), arrays
