"""Quantum gate tensor library (numpy, complex64).

Sycamore's native set: sqrt(X), sqrt(Y), sqrt(W) single-qubit gates and the
fSim(θ, φ) two-qubit gate (fSim(π/2, π/6) ≈ the Sycamore coupler).
Zuchongzhi uses the same fSim family.  Matrices follow arXiv:1910.11333.
"""

from __future__ import annotations

import numpy as np

_SQ2 = 1.0 / np.sqrt(2.0)


def _c64(m) -> np.ndarray:
    return np.asarray(m, dtype=np.complex64)


I2 = _c64([[1, 0], [0, 1]])
X = _c64([[0, 1], [1, 0]])
Y = _c64([[0, -1j], [1j, 0]])
Z = _c64([[1, 0], [0, -1]])
H = _c64([[_SQ2, _SQ2], [_SQ2, -_SQ2]])
S = _c64([[1, 0], [0, 1j]])
T = _c64([[1, 0], [0, np.exp(1j * np.pi / 4)]])

SQRT_X = _c64([[0.5 + 0.5j, 0.5 - 0.5j], [0.5 - 0.5j, 0.5 + 0.5j]])
SQRT_Y = _c64([[0.5 + 0.5j, -0.5 - 0.5j], [0.5 + 0.5j, 0.5 + 0.5j]])
# sqrt(W), W = (X + Y)/sqrt(2)
SQRT_W = _c64(
    [
        [0.5 + 0.5j, -np.sqrt(0.5) * 1j],
        [np.sqrt(0.5), 0.5 + 0.5j],
    ]
)


def fsim(theta: float, phi: float) -> np.ndarray:
    """fSim gate, 4x4, basis |00>,|01>,|10>,|11>."""
    c, s = np.cos(theta), np.sin(theta)
    return _c64(
        [
            [1, 0, 0, 0],
            [0, c, -1j * s, 0],
            [0, -1j * s, c, 0],
            [0, 0, 0, np.exp(-1j * phi)],
        ]
    )


CZ = _c64(np.diag([1, 1, 1, -1]))
ISWAP = _c64(
    [[1, 0, 0, 0], [0, 0, 1j, 0], [0, 1j, 0, 0], [0, 0, 0, 1]]
)
SYC = fsim(np.pi / 2, np.pi / 6)  # Sycamore coupler

SINGLE_QUBIT_POOL = {"sqrt_x": SQRT_X, "sqrt_y": SQRT_Y, "sqrt_w": SQRT_W}

GATES_1Q = {
    "i": I2,
    "x": X,
    "y": Y,
    "z": Z,
    "h": H,
    "s": S,
    "t": T,
    "sqrt_x": SQRT_X,
    "sqrt_y": SQRT_Y,
    "sqrt_w": SQRT_W,
}
GATES_2Q = {"cz": CZ, "iswap": ISWAP, "syc": SYC}


def gate_array(name: str, params: tuple = ()) -> np.ndarray:
    if name == "fsim":
        return fsim(*params)
    if name in GATES_1Q:
        return GATES_1Q[name]
    if name in GATES_2Q:
        return GATES_2Q[name]
    raise KeyError(name)


def is_two_qubit(name: str) -> bool:
    return name in GATES_2Q or name == "fsim"
