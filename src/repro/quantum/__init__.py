from . import circuits, gates, statevector, xeb  # noqa: F401
