"""Process-global metrics registry: named counters, gauges, histograms.

The numeric side of the observability layer (spans answer *where time
went*; metrics answer *how much work happened*): plan-cache and
HoistCache hits/misses/evicted bytes, slices executed, fused-chain
dispatches, executed FLOPs, ragged-padding waste, search accept/reject
counts, serving queue/compute latencies.  The registry is thread-safe,
snapshot-able as one plain dict (:func:`snapshot`) and reset-able for
tests (:func:`reset`).

Writer/snapshot consistency: every instrument mutation happens under the
registry's (reentrant) lock — the same lock :meth:`Registry.snapshot`
holds — so a snapshot is a *point-in-time* view.  In particular a
histogram can never be read torn (``count`` bumped but ``total`` not)
while another thread is mid-``observe``, and concurrent ``inc`` calls
never lose updates; this is what makes the registry safe under the
serving engine's threaded dispatch.

Cardinality: the helpers accept an optional ``label`` (e.g. a serving
family fingerprint).  Labeled series materialize as
``name{label}`` entries, and the registry caps the distinct labels per
base name (:attr:`Registry.max_labels`, default 64) — the overflow
collapses into ``name{_other}``, so per-request labels can never grow a
snapshot without bound.

The module-level helpers :func:`inc` / :func:`set_gauge` /
:func:`observe` are the instrumentation entry points: they early-return
on the shared ``REPRO_TRACE`` flag (see :mod:`repro.obs.trace`), so hot
paths stay zero-overhead with telemetry off.  Direct registry access
(``REGISTRY.counter(name)``) bypasses the gate — for tests and for the
tracer's own bookkeeping.
"""

from __future__ import annotations

import threading

from .trace import enabled

#: label value unbounded-cardinality series collapse into
OVERFLOW_LABEL = "_other"


class Counter:
    """Monotonic accumulator (``int`` or ``float`` increments)."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.RLock | None = None):
        self.value = 0
        self._lock = lock if lock is not None else threading.RLock()

    def inc(self, v=1):
        with self._lock:
            self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.RLock | None = None):
        self.value = 0
        self._lock = lock if lock is not None else threading.RLock()

    def set(self, v):
        with self._lock:
            self.value = v


class Histogram:
    """Streaming summary (count/total/min/max) — enough for wall-time
    and byte-size distributions without bucket configuration.  The four
    fields mutate atomically (one lock around the whole ``observe``), so
    a concurrent reader can never see them disagree."""

    __slots__ = ("count", "total", "min", "max", "_lock")

    def __init__(self, lock: threading.RLock | None = None):
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self._lock = lock if lock is not None else threading.RLock()

    def observe(self, v):
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def summary(self) -> dict:
        with self._lock:
            return {
                "count": self.count,
                "total": self.total,
                "min": self.min,
                "max": self.max,
                "mean": self.total / self.count if self.count else None,
            }


class Registry:
    """Thread-safe name → instrument map, one per kind.

    Instruments share the registry's reentrant lock, so snapshots and
    mutations serialize against each other (see module docstring)."""

    def __init__(self, max_labels: int = 64):
        # reentrant: snapshot() holds it while Histogram.summary() takes
        # it again through the shared instrument lock
        self._lock = threading.RLock()
        self.max_labels = int(max_labels)
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._labels: dict[str, set[str]] = {}

    def labeled(self, name: str, label) -> str:
        """Series name for ``name`` + ``label``, enforcing the per-base
        cardinality cap: the first ``max_labels`` distinct labels get
        their own series, later ones collapse into ``{_other}``."""
        if label is None:
            return name
        label = str(label)
        with self._lock:
            seen = self._labels.setdefault(name, set())
            if label not in seen:
                if len(seen) >= self.max_labels:
                    label = OVERFLOW_LABEL
                else:
                    seen.add(label)
        return f"{name}{{{label}}}"

    def counter(self, name: str, label=None) -> Counter:
        name = self.labeled(name, label)
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(self._lock)
            return c

    def gauge(self, name: str, label=None) -> Gauge:
        name = self.labeled(name, label)
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(self._lock)
            return g

    def histogram(self, name: str, label=None) -> Histogram:
        name = self.labeled(name, label)
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(self._lock)
            return h

    def snapshot(self) -> dict:
        """One plain dict of everything — JSON-serializable, suitable
        for ``PlanReport.telemetry`` and workflow artifacts.  Taken
        under the shared instrument lock: a consistent point-in-time
        view even with writers mid-flight on other threads."""
        with self._lock:
            return {
                "counters": {
                    k: c.value for k, c in sorted(self._counters.items())
                },
                "gauges": {
                    k: g.value for k, g in sorted(self._gauges.items())
                },
                "histograms": {
                    k: h.summary()
                    for k, h in sorted(self._histograms.items())
                },
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._labels.clear()


#: the process-global registry
REGISTRY = Registry()


def inc(name: str, v=1, label=None) -> None:
    """Increment counter ``name`` — no-op while telemetry is off."""
    if enabled():
        REGISTRY.counter(name, label=label).inc(v)


def set_gauge(name: str, v, label=None) -> None:
    """Set gauge ``name`` — no-op while telemetry is off."""
    if enabled():
        REGISTRY.gauge(name, label=label).set(v)


def observe(name: str, v, label=None) -> None:
    """Record one histogram observation — no-op while telemetry is off."""
    if enabled():
        REGISTRY.histogram(name, label=label).observe(v)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
