"""Process-global metrics registry: named counters, gauges, histograms.

The numeric side of the observability layer (spans answer *where time
went*; metrics answer *how much work happened*): plan-cache and
HoistCache hits/misses/evicted bytes, slices executed, fused-chain
dispatches, executed FLOPs, ragged-padding waste, search accept/reject
counts.  The registry is thread-safe, snapshot-able as one plain dict
(:func:`snapshot`) and reset-able for tests (:func:`reset`).

The module-level helpers :func:`inc` / :func:`set_gauge` /
:func:`observe` are the instrumentation entry points: they early-return
on the shared ``REPRO_TRACE`` flag (see :mod:`repro.obs.trace`), so hot
paths stay zero-overhead with telemetry off.  Direct registry access
(``REGISTRY.counter(name)``) bypasses the gate — for tests and for the
tracer's own bookkeeping.
"""

from __future__ import annotations

import threading

from .trace import enabled


class Counter:
    """Monotonic accumulator (``int`` or ``float`` increments)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, v=1):
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v):
        self.value = v


class Histogram:
    """Streaming summary (count/total/min/max) — enough for wall-time
    and byte-size distributions without bucket configuration."""

    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None

    def observe(self, v):
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def summary(self) -> dict:
        return {
            "count": self.count,
            "total": self.total,
            "min": self.min,
            "max": self.max,
            "mean": self.total / self.count if self.count else None,
        }


class Registry:
    """Thread-safe name → instrument map, one per kind."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram()
            return h

    def snapshot(self) -> dict:
        """One plain dict of everything — JSON-serializable, suitable
        for ``PlanReport.telemetry`` and workflow artifacts."""
        with self._lock:
            return {
                "counters": {
                    k: c.value for k, c in sorted(self._counters.items())
                },
                "gauges": {
                    k: g.value for k, g in sorted(self._gauges.items())
                },
                "histograms": {
                    k: h.summary()
                    for k, h in sorted(self._histograms.items())
                },
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: the process-global registry
REGISTRY = Registry()


def inc(name: str, v=1) -> None:
    """Increment counter ``name`` — no-op while telemetry is off."""
    if enabled():
        REGISTRY.counter(name).inc(v)


def set_gauge(name: str, v) -> None:
    """Set gauge ``name`` — no-op while telemetry is off."""
    if enabled():
        REGISTRY.gauge(name).set(v)


def observe(name: str, v) -> None:
    """Record one histogram observation — no-op while telemetry is off."""
    if enabled():
        REGISTRY.histogram(name).observe(v)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
