"""Span tracer: thread-local span stacks, monotonic wall, JSONL export.

Design constraints (the acceptance contract of the observability PR):

  * **Zero-overhead off path.**  ``REPRO_TRACE=0`` (the default) makes
    :func:`span` return a shared no-op context manager and makes
    :func:`sync` / :func:`instant` early-return on one boolean check.
    Instrumentation lives at the Python orchestration layer only —
    nothing is inserted into jit-traced code — so compiled artifacts and
    plan fingerprints are bitwise-identical with tracing on or off.
  * **Well-formed span trees.**  Spans nest on a thread-local stack:
    every record carries its parent's id, and per thread the intervals
    are properly nested (children inside parents, siblings
    non-overlapping) because enter/exit order is stack order.
  * **XLA profile passthrough.**  An active span also enters
    ``jax.profiler.TraceAnnotation(name)``, so the same names show up on
    the host timeline of an XLA profile when one is being captured.
  * **Sync points.**  Wall times at phase boundaries are only meaningful
    once dispatched work retires; :func:`sync` is
    ``jax.block_until_ready`` gated on the tracing flag, so enabling
    tracing adds the barriers and disabling it restores fully async
    dispatch.

Export is Chrome-trace-event JSONL (one complete-event object per
line) via :func:`dump_trace`; ``fmt="chrome"`` wraps the same events as
``{"traceEvents": [...]}`` which Perfetto / ``chrome://tracing`` open
directly.  :func:`merge_traces` concatenates per-process JSONL files
(each record carries its pid) into one timeline — the multi-process
merge step for ``contract_sharded``-style runs.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time

#: recorded spans are dropped beyond this cap (a long traced test session
#: must not grow memory without bound); drops are counted in
#: ``metrics`` under ``trace.dropped_spans``.
MAX_SPANS = 200_000


def _env_enabled() -> bool:
    v = os.environ.get("REPRO_TRACE", "0")
    if v not in ("0", "1"):
        raise ValueError(f"REPRO_TRACE={v!r} not in ('0', '1')")
    return v == "1"


_enabled = _env_enabled()
_lock = threading.Lock()
_records: list[SpanRecord] = []
_ids = itertools.count(1)
_tls = threading.local()
_jax = None  # lazily imported once; obs must stay importable without jax


def enabled() -> bool:
    """Whether tracing is currently on (``REPRO_TRACE`` at import time,
    overridable via :func:`set_enabled` / :class:`enabled_scope`)."""
    return _enabled


def set_enabled(on: bool) -> None:
    global _enabled
    _enabled = bool(on)


class enabled_scope:
    """Temporarily force tracing on/off (``None`` leaves it unchanged) —
    the implementation of the API layer's per-call ``telemetry=``
    toggle.  Process-global, like the flag itself: overlapping scopes
    from concurrent threads see last-writer-wins, the documented
    limitation of a per-call toggle on a process-global tracer."""

    def __init__(self, on: bool | None):
        self.on = on
        self._prev = None

    def __enter__(self):
        if self.on is not None:
            self._prev = _enabled
            set_enabled(self.on)
        return self

    def __exit__(self, *exc):
        if self._prev is not None:
            set_enabled(self._prev)
        return False


@dataclasses.dataclass
class SpanRecord:
    """One finished span (flat record; the tree is in ``parent_id``)."""

    span_id: int
    parent_id: int  # 0 = top-level span of its thread
    name: str
    cat: str
    t_start: float  # time.perf_counter seconds
    t_end: float
    thread: int
    pid: int
    attrs: dict

    @property
    def dur_s(self) -> float:
        return self.t_end - self.t_start

    def event(self) -> dict:
        """Chrome trace 'complete' event (Perfetto-compatible)."""
        args = dict(self.attrs)
        args["span_id"] = self.span_id
        args["parent_id"] = self.parent_id
        return {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": self.t_start * 1e6,
            "dur": self.dur_s * 1e6,
            "pid": self.pid,
            "tid": self.thread,
            "args": args,
        }


class _Noop:
    """Shared do-nothing span/annotation for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _Noop()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class _Span:
    __slots__ = (
        "name", "cat", "attrs", "span_id", "parent_id", "t0", "_ann",
    )

    def __init__(self, name: str, cat: str, attrs: dict):
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def set(self, **attrs):
        """Attach attributes to a live span (measured values only become
        known mid-span, e.g. a cache hit discovered after the lookup)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        global _jax
        st = _stack()
        self.parent_id = st[-1].span_id if st else 0
        self.span_id = next(_ids)
        st.append(self)
        self._ann = None
        if _jax is None:
            try:
                import jax

                _jax = jax
            except Exception:  # pragma: no cover - jax is a hard dep here
                _jax = False
        if _jax:
            self._ann = _jax.profiler.TraceAnnotation(self.name)
            self._ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        st = _stack()
        # tolerate exits out of stack order (a generator holding a span
        # across yields): unwind to this span if present
        if self in st:
            while st and st[-1] is not self:
                st.pop()
            st.pop()
        rec = SpanRecord(
            span_id=self.span_id,
            parent_id=self.parent_id,
            name=self.name,
            cat=self.cat,
            t_start=self.t0,
            t_end=t1,
            thread=threading.get_ident(),
            pid=os.getpid(),
            attrs=self.attrs,
        )
        with _lock:
            if len(_records) < MAX_SPANS:
                _records.append(rec)
            else:
                from . import metrics  # local: avoid import cycle at init

                metrics.REGISTRY.counter("trace.dropped_spans").inc(1)
        return False


def span(name: str, cat: str = "span", **attrs):
    """Context manager recording one span.  No-op (shared stub, no
    allocation beyond the kwargs dict) when tracing is off."""
    if not _enabled:
        return _NOOP
    return _Span(name, cat, attrs)


def traced(name: str | None = None, cat: str = "fn"):
    """Decorator form of :func:`span` (checks the flag per call, so a
    decorated function stays zero-overhead while tracing is off)."""
    import functools

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _enabled:
                return fn(*args, **kwargs)
            with _Span(label, cat, {}):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def annotate(name: str):
    """XLA-profile-only annotation (``jax.profiler.TraceAnnotation``):
    used inside kernel dispatch where a wall-clock span would time
    tracing, not execution.  No-op when tracing is off."""
    global _jax
    if not _enabled:
        return _NOOP
    if _jax is None:
        try:
            import jax

            _jax = jax
        except Exception:  # pragma: no cover
            _jax = False
    if not _jax:
        return _NOOP
    return _jax.profiler.TraceAnnotation(name)


def sync(x):
    """Phase-boundary sync point: ``jax.block_until_ready`` when tracing
    is on (span walls then measure retired work, not dispatch), identity
    when off (async dispatch untouched)."""
    if not _enabled:
        return x
    global _jax
    if _jax is None:
        try:
            import jax

            _jax = jax
        except Exception:  # pragma: no cover
            _jax = False
    if _jax:
        _jax.block_until_ready(x)
    return x


def instant(name: str, cat: str = "instant", **attrs) -> None:
    """Zero-duration event (structured log records ride on these)."""
    if not _enabled:
        return
    t = time.perf_counter()
    st = _stack()
    rec = SpanRecord(
        span_id=next(_ids),
        parent_id=st[-1].span_id if st else 0,
        name=name,
        cat=cat,
        t_start=t,
        t_end=t,
        thread=threading.get_ident(),
        pid=os.getpid(),
        attrs=attrs,
    )
    with _lock:
        if len(_records) < MAX_SPANS:
            _records.append(rec)


def get_spans() -> list[SpanRecord]:
    """Finished spans recorded so far (snapshot copy)."""
    with _lock:
        return list(_records)


def reset() -> None:
    """Drop all recorded spans (open spans on any stack still record on
    exit)."""
    with _lock:
        _records.clear()


def summary() -> dict:
    """Per-name aggregates: ``{name: {count, total_s, max_s}}``."""
    out: dict[str, dict] = {}
    for rec in get_spans():
        agg = out.setdefault(
            rec.name, {"count": 0, "total_s": 0.0, "max_s": 0.0}
        )
        agg["count"] += 1
        agg["total_s"] += rec.dur_s
        agg["max_s"] = max(agg["max_s"], rec.dur_s)
    return out


def dump_trace(path: str, fmt: str = "jsonl") -> int:
    """Write all recorded spans to ``path``; returns the event count.

    ``fmt="jsonl"`` (default): one Chrome-trace complete-event object
    per line — greppable, appendable, mergeable across processes.
    ``fmt="chrome"``: the same events wrapped as
    ``{"traceEvents": [...]}`` — open directly in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing``.  A JSONL file is
    converted losslessly by wrapping its lines in a JSON array.
    """
    events = [rec.event() for rec in get_spans()]
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        if fmt == "jsonl":
            for ev in events:
                f.write(json.dumps(ev) + "\n")
        elif fmt == "chrome":
            json.dump({"traceEvents": events}, f)
        else:
            raise ValueError(f"unknown trace format {fmt!r}")
    return len(events)


def merge_traces(paths, out_path: str) -> int:
    """Merge per-process JSONL traces into one JSONL timeline.

    Each event already carries its producer's ``pid``, so merging is
    concatenation; Perfetto renders distinct pids as distinct process
    tracks.  This is the span-merging step for multi-process
    ``contract_sharded`` runs: every process dumps its own file, one
    merge yields the cluster timeline.  Returns the merged event count.
    """
    events: list[dict] = []
    for p in paths:
        with open(p) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    events.sort(key=lambda ev: ev.get("ts", 0.0))
    d = os.path.dirname(out_path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(out_path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    return len(events)
