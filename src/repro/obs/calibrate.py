"""Model-vs-measured calibration: per-node wall against the refiner model.

The refiner chooses backends by ``modeled_time_s`` (an F(M,N,K)
efficiency model over GEMM shapes), the slicer trusts
``modeled_node_time`` (Eq. 4 cost algebra at modeled bandwidth), and the
lifetime planner certifies live-set peaks — but until this module nothing
ever *checked* those models against real hardware.  :func:`calibrate_plan`
executes a plan's steps eagerly, one at a time, with a
``block_until_ready`` fence around each, and joins the measured walls
with the modeled per-slice times into a per-backend-class table
(``pallas`` / ``pallas_fused`` / ``chain`` / ``dot`` / ``einsum``; under
mixed precision, non-fp32 steps split into their own rows, e.g.
``pallas[bf16]`` / ``chain[mixed]`` — bf16 runs against a different MXU
roofline, so its measured/modeled ratio is a separate signal).

The measured/modeled ratio per class is the feedback signal the
ROADMAP's adaptive refiner and work-stealing scheduler need: a class
with ratio ≫ 1 means the model flatters that backend and the refiner's
choices are suspect on this machine; ratios drifting apart across
classes mean the crossover thresholds need re-tuning.

Caveats by construction: eager per-step execution measures kernels
*without* XLA's cross-step fusion, so absolute walls sit above the jitted
path — the *ratios between classes* are the calibrated signal, not the
totals.  First-call compile time is excluded via warmup.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class CalibrationRow:
    """One executed step (or fused chain) of the plan."""

    node: int  # tree node id of the step output (chain: its out node)
    backend: str  # pallas | pallas_fused | dot | einsum | chain
    measured_s: float  # min-over-repeat eager wall, block_until_ready
    modeled_s: float  # refiner / cost-model per-slice seconds
    flops: float  # modeled real-multiply FLOPs of the step (per slice)
    precision: str = "fp32"  # operand precision (chain: "mixed" if split)

    @property
    def cls(self) -> str:
        """Calibration class: the backend, qualified by precision when
        the step does not run at full fp32 (``pallas[bf16]``,
        ``chain[mixed]``, …) — bf16 steps hit a different roofline, so
        folding them into the fp32 rows would skew both ratios."""
        if self.precision == "fp32":
            return self.backend
        return f"{self.backend}[{self.precision}]"

    @property
    def ratio(self) -> float:
        return self.measured_s / self.modeled_s if self.modeled_s else float("inf")


@dataclasses.dataclass
class CalibrationReport:
    rows: list[CalibrationRow]
    backend: str  # the plan's execution backend ("einsum" | "gemm")
    num_steps: int
    peak_bytes: int  # certified naive live-set peak (lowering/memory.py)
    peak_bytes_hoisted: int  # certified prologue/epilogue peak

    def ratio_by_class(self) -> dict[str, dict]:
        """Per backend class: total measured, total modeled, their ratio,
        and the step count — the headline calibration table."""
        agg: dict[str, dict] = {}
        for r in self.rows:
            a = agg.setdefault(
                r.cls,
                {"count": 0, "measured_s": 0.0, "modeled_s": 0.0},
            )
            a["count"] += 1
            a["measured_s"] += r.measured_s
            a["modeled_s"] += r.modeled_s
        for a in agg.values():
            a["ratio"] = (
                a["measured_s"] / a["modeled_s"]
                if a["modeled_s"]
                else float("inf")
            )
        return agg

    def table(self) -> str:
        """Markdown model-vs-measured table per backend class."""
        lines = [
            "| class | steps | measured (s) | modeled (s) | meas/model |",
            "|---|---|---|---|---|",
        ]
        for cls, a in sorted(self.ratio_by_class().items()):
            lines.append(
                f"| {cls} | {a['count']} | {a['measured_s']:.3e} "
                f"| {a['modeled_s']:.3e} | {a['ratio']:.2f} |"
            )
        return "\n".join(lines)

    def summary(self) -> dict:
        """JSON-serializable form (trajectory records, CI artifacts)."""
        return {
            "backend": self.backend,
            "num_steps": self.num_steps,
            "peak_bytes": self.peak_bytes,
            "peak_bytes_hoisted": self.peak_bytes_hoisted,
            "by_class": self.ratio_by_class(),
        }


def _time_call(fn, repeat: int) -> tuple[float, object]:
    """Min-over-repeat eager wall of ``fn()`` with a device fence; one
    untimed warmup call first so backend compilation (Pallas kernels
    compile on first dispatch) never pollutes the measurement."""
    import jax

    out = jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, repeat)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def calibrate_plan(plan, arrays, slice_id: int = 0, repeat: int = 2):
    """Execute one slice of ``plan`` step-by-step (eagerly, fenced) and
    join each step's measured wall with its modeled per-slice time.

    Honors the plan's fused-chain dispatch (``_chain_dispatch["naive"]``)
    so chain steps are measured as the single ``apply_chain`` call they
    execute as, and classed ``"chain"`` with the chain's modeled time
    (sum of member specs minus the modeled HBM traffic saving).  Returns
    a :class:`CalibrationReport`.
    """
    import jax.numpy as jnp
    from jax import lax

    from ..core.merging import TPU_HBM_BW, modeled_node_time
    from ..obs import trace

    # slice the leaves for the concrete slice assignment
    svals = [(slice_id >> p) & 1 for p in range(plan.num_sliced)]
    env: dict[int, object] = {}
    for i in range(len(arrays)):
        a = jnp.asarray(arrays[i])
        for axis, spos in plan.leaf_specs[i]:
            a = lax.index_in_dim(a, svals[spos], axis=axis, keepdims=False)
        env[i] = a

    chains = plan._chain_dispatch.get("naive", {})
    n_sub = 1 << plan.num_sliced
    rows: list[CalibrationRow] = []
    k = 0
    while k < len(plan.steps):
        ch = chains.get(k)
        if ch is not None:
            from ..lowering import gemm_form

            specs = [plan.schedule.specs[p] for p in ch.positions]
            operands = [env[n] for n in ch.external_nodes]
            with trace.span("calib.node", cat="calib", node=ch.out_node):
                measured, out = _time_call(
                    lambda: gemm_form.apply_chain(ch, specs, operands),
                    repeat,
                )
            env[ch.out_node] = out
            modeled = (
                sum(s.modeled_time_s for s in specs)
                - ch.hbm_bytes_saved / TPU_HBM_BW
            )
            flops = sum(s.form.flops for s in specs)
            precs = {getattr(s, "precision", "fp32") for s in specs}
            rows.append(
                CalibrationRow(
                    node=ch.out_node,
                    backend="chain",
                    measured_s=measured,
                    modeled_s=max(modeled, 0.0),
                    flops=flops,
                    precision=(
                        precs.pop() if len(precs) == 1 else "mixed"
                    ),
                )
            )
            k += ch.n_steps
            continue
        st = plan.steps[k]
        a, b = env[st.lhs], env[st.rhs]
        if plan.schedule is None:
            expr = st.expr
            with trace.span("calib.node", cat="calib", node=st.out):
                measured, out = _time_call(
                    lambda: jnp.einsum(expr, a, b), repeat
                )
            modeled = (
                modeled_node_time(plan.tree, st.out, plan.smask) / n_sub
            )
            cls = "einsum"
            flops = 0.0
            prec = "fp32"
        else:
            from ..lowering import gemm_form

            spec = plan.schedule.specs[k]
            with trace.span("calib.node", cat="calib", node=st.out):
                measured, out = _time_call(
                    lambda: gemm_form.apply(spec, a, b), repeat
                )
            modeled = spec.modeled_time_s
            cls = spec.backend
            flops = spec.form.flops
            prec = getattr(spec, "precision", "fp32")
        env[st.out] = out
        rows.append(
            CalibrationRow(
                node=st.out,
                backend=cls,
                measured_s=measured,
                modeled_s=modeled,
                flops=flops,
                precision=prec,
            )
        )
        k += 1

    mem = plan.memory_plan()
    return CalibrationReport(
        rows=rows,
        backend=plan.backend,
        num_steps=len(plan.steps),
        peak_bytes=mem.peak_bytes,
        peak_bytes_hoisted=mem.peak_bytes_hoisted,
    )
