"""Observability: span tracing, metrics, structured logging, calibration.

The measurement substrate for every perf claim the reproduction makes
(the paper's 308.6 Pflops / 96.1 s Sycamore headlines are *measurement*
claims — Sec. VI).  Three parts:

  * :mod:`repro.obs.trace` — low-overhead span tracer: context-manager /
    decorator spans on a thread-local stack, monotonic wall clocks,
    optional ``jax.block_until_ready`` sync points at phase boundaries,
    ``jax.profiler.TraceAnnotation`` passthrough (spans show up in XLA
    profiles), JSONL export readable by Perfetto.
  * :mod:`repro.obs.metrics` — process-global named counters / gauges /
    histograms (plan-cache and HoistCache hits/misses/evicted bytes,
    slices executed, chains fused, executed FLOPs, ragged-padding
    waste; the multi-host scheduler adds per-host queue depth gauges
    ``sched.queue_depth.h<h>``, the ``sched.steals`` counter, the
    ``sched.steal_latency_s`` histogram — drain-to-claim latency of
    each successful steal — and the elastic store's
    ``elastic.ranges_completed`` / ``elastic.claims_reclaimed``),
    snapshot-able as a dict and reset-able for tests.
  * :mod:`repro.obs.calibrate` — joins per-node measured wall against
    the refiner's modeled times and the lifetime planner's certified
    peaks into a model-vs-measured table per backend class — the
    feedback signal the adaptive refiner and work-stealing scheduler
    need (ROADMAP).

Everything is gated by ``REPRO_TRACE={0,1}`` (default off).  The off
path is no-op stubs at the Python orchestration layer — nothing is ever
inserted into jitted programs, so plan fingerprints and compiled
artifacts are bitwise-unchanged whether tracing is on or off.
"""

from __future__ import annotations

from . import calibrate, log, metrics, trace  # noqa: F401
from .calibrate import CalibrationReport, calibrate_plan  # noqa: F401
from .trace import (  # noqa: F401
    annotate,
    dump_trace,
    enabled,
    enabled_scope,
    get_spans,
    merge_traces,
    set_enabled,
    span,
    sync,
)


def telemetry_summary() -> dict:
    """Compact snapshot of the current telemetry state — what
    ``PlanReport.telemetry`` carries when a ``telemetry=``/``REPRO_TRACE``
    run asks for it: the full metrics snapshot plus per-span-name
    count/total-wall aggregates (never the raw span list — that is what
    :func:`repro.obs.trace.dump_trace` is for)."""
    return {"metrics": metrics.snapshot(), "spans": trace.summary()}


def reset() -> None:
    """Clear all recorded spans and metrics (tests, between benchmark
    ablation arms).  Does not change whether tracing is enabled."""
    trace.reset()
    metrics.reset()
