"""Structured logger for launch-layer status lines.

The launch scripts (`train.py`, `sweep.py`, `serve.py`, `dryrun.py`)
used bare ``print``; this routes them through one level-filtered logger
while keeping the stdout text **byte-identical** — the sweep-resume
parser greps ``sweep.py``'s last stdout line, so the message is printed
verbatim (no timestamp/level prefix) whenever its level passes the
threshold.

``REPRO_LOG_LEVEL`` ∈ {DEBUG, INFO, WARNING, ERROR} (default INFO) sets
the threshold and is read per call so tests can flip it without
re-imports.  Each emitted line also records a structured
:func:`repro.obs.trace.instant` event (cat ``"log"``) carrying the
level and any keyword fields — on traced runs the log stream lands in
the same JSONL timeline as the spans.
"""

from __future__ import annotations

import os
import sys

from . import trace

LEVELS = {"DEBUG": 10, "INFO": 20, "WARNING": 30, "ERROR": 40}
DEFAULT_LEVEL = "INFO"


def _threshold() -> int:
    v = os.environ.get("REPRO_LOG_LEVEL", DEFAULT_LEVEL).upper()
    return LEVELS.get(v, LEVELS[DEFAULT_LEVEL])


def log(level: str, msg: str, **fields) -> None:
    """Emit ``msg`` verbatim to stdout when ``level`` passes the
    ``REPRO_LOG_LEVEL`` threshold; always leave a structured instant
    event when tracing is on."""
    trace.instant(msg, cat="log", level=level, **fields)
    if LEVELS[level] >= _threshold():
        print(msg, flush=True)
        sys.stdout.flush()


def debug(msg: str, **fields) -> None:
    log("DEBUG", msg, **fields)


def info(msg: str, **fields) -> None:
    log("INFO", msg, **fields)


def warning(msg: str, **fields) -> None:
    log("WARNING", msg, **fields)


def error(msg: str, **fields) -> None:
    log("ERROR", msg, **fields)
