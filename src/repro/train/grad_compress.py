"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 quantization with error feedback: each worker keeps the quantization
residual and adds it back before the next round, so the compressed
all-reduce is unbiased over time (the standard EF-SGD recipe).  At the
16×16-per-pod scale the ICI all-reduces stay uncompressed (cheap); the
2-pod DCN hop is the bandwidth cliff this targets — 4× fewer bytes than
fp32, 2× fewer than bf16.

``compressed_psum`` expresses the collective jax-natively via shard_map
over the pod axis so it composes with the in-pod pjit program.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compress_with_feedback(
    grads: Any, residuals: Any
) -> tuple[Any, Any, Any]:
    """Returns (quantized, scales, new_residuals)."""

    def one(g, r):
        g = g.astype(F32) + r
        q, s = quantize(g)
        return q, s, g - dequantize(q, s)

    flat = jax.tree.map(one, grads, residuals)
    qs = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    ss = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    rs = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    return qs, ss, rs


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads)


def compressed_psum(
    grads: Any, residuals: Any, mesh, axis: str = "pod"
) -> tuple[Any, Any]:
    """All-reduce ``grads`` over ``axis`` in int8 with error feedback.

    Each participant quantizes (with its residual), the int8 payload is
    psum-ed (values fit int32 accumulation re-expressed in f32 here since
    XLA psum on int8 would overflow — we widen to bf16 on the wire, still
    2× smaller than f32), then de-scaled by the max scale.
    """
    from jax.experimental.shard_map import shard_map

    def body(g, r):
        q, s, r2 = compress_with_feedback(g, r)
        # wire format: int8 payload + per-tensor scale; psum over pods
        def reduce_one(qq, sc):
            s_max = jax.lax.pmax(sc, axis)
            contrib = dequantize(qq, sc).astype(jnp.bfloat16)
            return jax.lax.psum(contrib, axis).astype(F32), s_max

        red = jax.tree.map(reduce_one, q, s)
        summed = jax.tree.map(
            lambda t: t[0], red,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
        )
        return summed, r2

    spec = jax.sharding.PartitionSpec()
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
        check_rep=False,
    )(grads, residuals)
