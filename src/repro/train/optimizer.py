"""AdamW with warmup+cosine schedule, decoupled weight decay, global-norm
clipping, and optional int8-quantized moments (8-bit-Adam-style) so the
405B optimizer state fits v5e HBM.

Hand-rolled (no optax in this environment) but with the production
surface: ``init / update`` pure functions over pytrees, fp32 master
moments, decay masking for 1-D params (norms, biases).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # float32 | int8


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * frac


def _decayable(path: tuple, leaf: jax.Array) -> bool:
    return leaf.ndim >= 2


# --------------------------------------------------------------------
# int8 moment quantization (per-tensor absmax scaling + fp32 scale)
# --------------------------------------------------------------------
def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def init(cfg: OptimizerConfig, params) -> dict:
    if cfg.moment_dtype == "int8":
        zeros_q = jax.tree.map(
            lambda p: (jnp.zeros(p.shape, jnp.int8), jnp.zeros((), F32)),
            params,
        )
        return {
            "m": zeros_q,
            "v": jax.tree.map(
                lambda p: (jnp.zeros(p.shape, jnp.int8), jnp.zeros((), F32)),
                params,
            ),
            "count": jnp.zeros((), jnp.int32),
        }
    z = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves)
    )


def update(
    cfg: OptimizerConfig, grads, state: dict, params
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, count)
    int8 = cfg.moment_dtype == "int8"

    bc1 = 1.0 - cfg.b1 ** count.astype(F32)
    bc2 = 1.0 - cfg.b2 ** count.astype(F32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    is_q = lambda x: isinstance(x, tuple) and len(x) == 2
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q) if int8 else jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q) if int8 else jax.tree.leaves(state["v"])
    paths = [
        p for p, _ in jax.tree_util.tree_flatten_with_path(grads)[0]
    ]

    new_p, new_m, new_v = [], [], []
    for path, g, p, m, v in zip(paths, flat_g, flat_p, flat_m, flat_v):
        g = g.astype(F32) * clip
        m_f = _dequantize(*m) if int8 else m
        v_f = _dequantize(*v) if int8 else v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        if cfg.weight_decay and _decayable(path, p):
            upd = upd + cfg.weight_decay * p.astype(F32)
        new_p.append((p.astype(F32) - lr * upd).astype(p.dtype))
        new_m.append(_quantize(m_f) if int8 else m_f)
        new_v.append(_quantize(v_f) if int8 else v_f)

    params2 = jax.tree.unflatten(tdef, new_p)
    state2 = {
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "count": count,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params2, state2, metrics


def opt_state_logical(defs, cfg: OptimizerConfig):
    """Logical sharding tree for the optimizer state (moments shard exactly
    like their parameters — ZeRO-3)."""
    from ..parallel.sharding import ParamDef, is_def

    if cfg.moment_dtype == "int8":
        mom = jax.tree.map(
            lambda d: (d.logical, ()), defs, is_leaf=is_def
        )
    else:
        mom = jax.tree.map(lambda d: d.logical, defs, is_leaf=is_def)
    return {"m": mom, "v": mom, "count": ()}


def opt_state_abstract(defs, cfg: OptimizerConfig):
    from ..parallel.sharding import is_def

    if cfg.moment_dtype == "int8":
        mom = lambda d: (
            jax.ShapeDtypeStruct(d.shape, jnp.int8),
            jax.ShapeDtypeStruct((), F32),
        )
    else:
        mom = lambda d: jax.ShapeDtypeStruct(d.shape, F32)
    return {
        "m": jax.tree.map(mom, defs, is_leaf=is_def),
        "v": jax.tree.map(mom, defs, is_leaf=is_def),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
