"""Train / serve step builders shared by the launcher and the dry-run.

``make_train_step(model, opt_cfg)`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for jit with explicit
shardings; ``abstract_state``/``state_logical`` provide the matching
ShapeDtypeStruct / logical-sharding trees so the dry-run can lower the
exact production program without allocating anything.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..parallel.sharding import ParamDef, abstract_params, is_def, param_specs
from . import optimizer as opt


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array


def make_train_step(model, opt_cfg: opt.OptimizerConfig) -> Callable:
    def train_step(state: TrainState, batch):
        def loss_fn(params):
            loss, metrics = model.loss(params, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params
        )
        params2, opt2, opt_metrics = opt.update(
            opt_cfg, grads, state.opt, state.params
        )
        new_state = TrainState(params2, opt2, state.step + 1)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    return train_step


def init_state(model, opt_cfg: opt.OptimizerConfig, key) -> TrainState:
    from ..parallel.sharding import init_params

    params = init_params(model.param_defs(), key)
    return TrainState(params, opt.init(opt_cfg, params), jnp.zeros((), jnp.int32))


def abstract_state(model, opt_cfg: opt.OptimizerConfig) -> TrainState:
    defs = model.param_defs()
    return TrainState(
        abstract_params(defs),
        opt.opt_state_abstract(defs, opt_cfg),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def state_logical(model, opt_cfg: opt.OptimizerConfig) -> TrainState:
    defs = model.param_defs()
    return TrainState(
        param_specs(defs),
        opt.opt_state_logical(defs, opt_cfg),
        (),
    )


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------
def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, cache, tokens, pos, mrope_positions=None):
        return model.decode_step(params, cache, tokens, pos, mrope_positions)

    return decode_step
