"""Fig. 10 + Sec. VI-A — slicing overhead O(B,S) (Eq. 4).

Reports geometric/harmonic mean overhead per circuit for:
  greedy baseline → sliceFinder (Alg. 1) → + tree tuning (Alg. 2).
Paper headline: overhead 1.255 on the contraction path used for Sycamore
(vs Cotengra 431 single-shot / Alibaba 4)."""

from __future__ import annotations

import math

from repro.core.slicing import find_slices
from repro.core.tuning import tuning_slice_finder

from .common import network_for, trees_for


def _geo(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _har(xs):
    return len(xs) / sum(1.0 / x for x in xs)


def run(circuits=("syc-12", "syc-16", "syc-20", "zn-16"),
        n_trees: int = 6) -> list[str]:
    rows = []
    for name in circuits:
        tn, _ = network_for(name)
        trees = trees_for(tn, n_trees)
        ov = {"greedy": [], "lifetime": [], "tuned": []}
        for i, tree in enumerate(trees):
            target = max(tree.width() - 4, 8)
            sg = find_slices(tree, target, method="greedy", repeats=4, seed=i)
            ov["greedy"].append(tree.slicing_overhead(sg))
            sl = find_slices(tree, target, method="lifetime")
            ov["lifetime"].append(tree.slicing_overhead(sl))
            res = tuning_slice_finder(tree, target, max_rounds=8)
            ov["tuned"].append(res.tree.slicing_overhead(res.smask))
        rows.append(
            f"fig10_{name}_geomean,{_geo(ov['lifetime']):.3f},"
            f"greedy={_geo(ov['greedy']):.3f};tuned={_geo(ov['tuned']):.3f}"
        )
        rows.append(
            f"fig10_{name}_harmean,{_har(ov['lifetime']):.3f},"
            f"greedy={_har(ov['greedy']):.3f};tuned={_har(ov['tuned']):.3f}"
        )
    # best single overhead on the biggest circuit (paper: 1.255)
    tn, _ = network_for("syc-20")
    best = float("inf")
    for t in trees_for(tn, 4):
        res = tuning_slice_finder(t, max(t.width() - 4, 8), max_rounds=10)
        best = min(best, res.tree.slicing_overhead(res.smask))
    rows.append(f"fig10_best_overhead_syc20,{best:.3f},paper=1.255")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
