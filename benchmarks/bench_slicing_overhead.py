"""Fig. 10 + Sec. VI-A — slicing overhead O(B,S) (Eq. 4), modeled AND
measured.

Reports geometric/harmonic mean overhead per circuit for:
  greedy baseline → sliceFinder (Alg. 1) → + tree tuning (Alg. 2).
Paper headline: overhead 1.255 on the contraction path used for Sycamore
(vs Cotengra 431 single-shot / Alibaba 4).

The hoisting section turns Eq. 4 from a planner metric into a runtime
measurement: for each circuit it reports the naive executed-FLOPs
overhead (== Eq. 4) next to the two-phase hoisted one (prologue once +
epilogue per slice, see :mod:`repro.lowering.partition`), and — on the
CPU-tractable instance — *wall-clock* naive vs hoisted execution per
backend.  Records are appended to ``experiments/hoisting/trajectory.
json`` and rendered by ``benchmarks.make_tables``.

The memory section (:func:`memory_rows`) does the same for the
lifetime-based buffer planner: width-proxy vs peak-aware slicing set
sizes, planned live-set peaks, the fused-kernel transpose-bytes credit,
and measured wall-clock of the peak-mode mask on the tractable instance
(records under ``experiments/memory/trajectory.json``)."""

from __future__ import annotations

import math

import numpy as np

from repro.core.executor import ContractionPlan
from repro.core.slicing import find_slices, peak_budget_for_width
from repro.core.tensor_network import popcount
from repro.core.tuning import tuning_slice_finder
from repro.lowering.memory import plan_memory
from repro.lowering.partition import partition_tree
from repro.lowering.refiner import refine_tree_schedule

from .common import append_trajectory, network_for, timer, trees_for


def _geo(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _har(xs):
    return len(xs) / sum(1.0 / x for x in xs)


def run(circuits=("syc-12", "syc-16", "syc-20", "zn-16"),
        n_trees: int = 6) -> list[str]:
    rows = []
    for name in circuits:
        tn, _ = network_for(name)
        trees = trees_for(tn, n_trees)
        ov = {"greedy": [], "lifetime": [], "tuned": []}
        for i, tree in enumerate(trees):
            target = max(tree.width() - 4, 8)
            sg = find_slices(tree, target, method="greedy", repeats=4, seed=i)
            ov["greedy"].append(tree.slicing_overhead(sg))
            sl = find_slices(tree, target, method="lifetime")
            ov["lifetime"].append(tree.slicing_overhead(sl))
            res = tuning_slice_finder(tree, target, max_rounds=8)
            ov["tuned"].append(res.tree.slicing_overhead(res.smask))
        rows.append(
            f"fig10_{name}_geomean,{_geo(ov['lifetime']):.3f},"
            f"greedy={_geo(ov['greedy']):.3f};tuned={_geo(ov['tuned']):.3f}"
        )
        rows.append(
            f"fig10_{name}_harmean,{_har(ov['lifetime']):.3f},"
            f"greedy={_har(ov['greedy']):.3f};tuned={_har(ov['tuned']):.3f}"
        )
    # best single overhead on the biggest circuit (paper: 1.255)
    tn, _ = network_for("syc-20")
    best = float("inf")
    for t in trees_for(tn, 4):
        res = tuning_slice_finder(t, max(t.width() - 4, 8), max_rounds=10)
        best = min(best, res.tree.slicing_overhead(res.smask))
    rows.append(f"fig10_best_overhead_syc20,{best:.3f},paper=1.255")
    rows.extend(hoisting_rows())
    rows.extend(memory_rows())
    return rows


def hoisting_rows(
    modeled_circuits=("syc-16", "syc-20"),
    measured_circuit: str = "syc-12",
    backends=("einsum", "gemm"),
    trajectory_dir: str = "experiments/hoisting",
) -> list[str]:
    """Naive vs two-phase hoisted execution: executed-FLOPs overhead on
    the paper circuits (|S| >= 4), wall-clock on the CPU-tractable one.

    Wall-clock is reported twice per backend: *cold* re-materializes the
    slice-invariant prologue (first request of a circuit family) and
    *warm* serves it from the plan's hoist cache (steady-state serving).

    Two execution paths are measured.  On the vmapped-scan path the
    speedup is expectedly ~1.0x on XLA: slice-invariant ops are
    unbatched under ``vmap`` and hoisted out of the scan by the
    compiler's loop-invariant code motion, so two-phase execution makes
    that reclamation *guaranteed by construction* (and portable to paths
    the compiler cannot see across) rather than faster here.  The
    per-slice driver (``contract_resumable`` — independent jit calls,
    the paper's explicit subtask loop, no cross-call LICM possible) is
    where the same split buys measurable wall-clock.
    """
    rows: list[str] = []
    records: list[dict] = []
    # -------- executed-FLOPs overhead, paper instances (no execution).
    # Two memory targets per circuit: at W-4 tuning leaves little
    # invariant waste; at W-8 (deeper slicing, the paper's regime) the
    # hoisted path reclaims a measurable FLOP fraction.
    for name in modeled_circuits:
        tn, _ = network_for(name)
        tree = trees_for(tn, 1)[0]
        for shrink in (4, 8):
            res = tuning_slice_finder(
                tree, max(tree.width() - shrink, 8), max_rounds=8
            )
            n_sliced = popcount(res.smask)
            part = partition_tree(res.tree, res.smask)
            naive = res.tree.slicing_overhead(res.smask)
            hoisted = part.hoisted_overhead()
            rows.append(
                f"hoist_{name}_w{shrink}_overhead,{hoisted:.3f},"
                f"naive_eq4={naive:.3f};"
                f"inv_frac={part.invariant_fraction:.2e};"
                f"slices={n_sliced}"
            )
            records.append({
                "workload": f"{name} (W-{shrink})",
                "kind": "modeled",
                "num_sliced": n_sliced,
                "invariant_fraction": part.invariant_fraction,
                "invariant_nodes": len(part.invariant_nodes),
                "total_nodes": len(part.invariant_nodes)
                + len(part.epilogue_nodes),
                "naive_overhead": naive,
                "hoisted_overhead": hoisted,
            })
    # -------- measured wall-clock, tractable instance, both backends
    tn, arrays = network_for(measured_circuit)
    tree = trees_for(tn, 1)[0]
    res = tuning_slice_finder(tree, max(tree.width() - 4, 8), max_rounds=8)
    n_sliced = popcount(res.smask)
    part = partition_tree(res.tree, res.smask)
    for backend in backends:
        plan = ContractionPlan(res.tree, res.smask, backend=backend)
        ref, t_naive = timer(
            lambda: np.asarray(
                plan.contract_all(arrays, slice_batch=4, hoist=False)
            ),
            repeat=2,
        )

        def hoisted_cold():
            plan._hoist_cache.clear()  # force prologue re-materialization
            return np.asarray(
                plan.contract_all(arrays, slice_batch=4, hoist=True)
            )

        got, t_cold = timer(hoisted_cold, repeat=2)
        assert np.allclose(got, ref, atol=1e-5)  # sanity: modes agree
        _, t_warm = timer(
            lambda: np.asarray(
                plan.contract_all(arrays, slice_batch=4, hoist=True)
            ),
            repeat=2,
        )
        # the per-slice driver: one jit call per subtask, so invariant
        # recomputation is real unless explicitly hoisted
        from repro.core.distributed import contract_resumable

        _, t_ps_naive = timer(
            lambda: contract_resumable(
                plan, arrays, chunk=16, hoist=False
            )[0],
            repeat=2,
        )
        got_ps, t_ps_hoist = timer(
            lambda: contract_resumable(plan, arrays, chunk=16, hoist=True)[0],
            repeat=2,
        )
        assert np.allclose(got_ps, ref, atol=1e-5)
        rows.append(
            f"hoist_measured_{measured_circuit}_{backend}_ms,"
            f"{t_cold*1e3:.1f},naive={t_naive*1e3:.1f}ms;"
            f"warm={t_warm*1e3:.1f}ms;"
            f"perslice={t_ps_hoist*1e3:.1f}ms;"
            f"perslice_naive={t_ps_naive*1e3:.1f}ms;"
            f"perslice_speedup={t_ps_naive/t_ps_hoist:.2f}x"
        )
        records.append({
            "workload": measured_circuit,
            "kind": "measured",
            "backend": backend,
            "wall_perslice_naive_s": t_ps_naive,
            "wall_perslice_hoisted_s": t_ps_hoist,
            "speedup_perslice": t_ps_naive / t_ps_hoist,
            "num_sliced": n_sliced,
            "invariant_fraction": part.invariant_fraction,
            "invariant_nodes": len(part.invariant_nodes),
            "total_nodes": len(part.invariant_nodes)
            + len(part.epilogue_nodes),
            "naive_overhead": res.tree.slicing_overhead(res.smask),
            "hoisted_overhead": part.hoisted_overhead(),
            "wall_naive_s": t_naive,
            "wall_hoisted_cold_s": t_cold,
            "wall_hoisted_warm_s": t_warm,
            "speedup_cold": t_naive / t_cold,
            "speedup_warm": t_naive / t_warm,
        })
    append_trajectory(records, trajectory_dir)
    return rows


def memory_rows(
    modeled_circuits=("syc-16", "syc-20"),
    measured_circuit: str = "syc-12",
    n_trees: int = 3,
    trajectory_dir: str = "experiments/memory",
) -> list[str]:
    """Lifetime-based memory planning: width-proxy vs peak-aware slicing
    (|S|, planned live-set peaks) and the fused-kernel transpose-bytes
    credit, modeled on the paper instances; wall-clock on the
    CPU-tractable one.

    The peak-aware slicer's |S| reduction multiplies straight into
    ``contract_all`` wall-clock (half the sliced indices = a quarter of
    the subtasks), so the measured section times the PR-3 hoisted
    baseline (width-mode slicing) against the same executor running the
    peak-mode mask.  Peaks are planned (exact live-set algebra,
    property-tested against brute force); on the measured instance the
    *residency delta* of the peak-mode run — live device bytes added by
    it, sampled via ``jax.live_arrays`` before/after — is recorded as a
    steady-state footprint observation (CPU jax exposes no in-flight
    peak counter; fused kernels execute via the interpret-mode emulator
    on CPU, so their bandwidth win is likewise reported as modeled
    bytes, not wall-clock).
    """
    import jax

    rows: list[str] = []
    records: list[dict] = []
    for name in modeled_circuits + (measured_circuit,):
        measured = name == measured_circuit
        tn, arrays = network_for(name)
        for i, tree in enumerate(trees_for(tn, n_trees)):
            target = max(tree.width() - 4, 8)
            S_w = find_slices(tree, target, method="lifetime")
            S_p = find_slices(tree, target, method="lifetime", mode="peak")
            mem_w = plan_memory(tree, S_w)
            mem_p = plan_memory(tree, S_p)
            # fused-kernel transpose credit for the peak-mode schedule
            # (planner-side refinement — syc-16/20 are planning-only)
            sched = refine_tree_schedule(tree, S_p)
            rec = {
                "workload": f"{name} t{i}",
                "kind": "modeled",
                "target_dim": target,
                "budget_bytes": max(
                    peak_budget_for_width(target), mem_w.peak_bytes
                ),
                "num_sliced_width": popcount(S_w),
                "num_sliced_peak": popcount(S_p),
                "peak_bytes_width": mem_w.peak_bytes,
                "peak_bytes_peak": mem_p.peak_bytes,
                "peak_bytes_hoisted_peak": mem_p.peak_bytes_hoisted,
                "buffer_slots": mem_p.buffer_slots,
                "transpose_bytes_eliminated":
                    sched.transpose_bytes_eliminated(),
                "transpose_bytes_paid": sched.transpose_bytes(),
            }
            if measured and i == 0:
                plan_w = ContractionPlan(tree, S_w)
                plan_p = ContractionPlan(tree, S_p)
                ref, t_w = timer(
                    lambda: np.asarray(
                        plan_w.contract_all(arrays, slice_batch=4, hoist=True)
                    ),
                    repeat=2,
                )
                # residency attributable to the peak-mode run: live device
                # bytes added by it (result, hoisted-frontier cache,
                # compiled constants).  CPU jax exposes no in-flight
                # peak counter, so this is steady-state residency — the
                # in-flight bound is the *planned* peak above, which is
                # exact by construction (property-tested).
                live_before = sum(a.nbytes for a in jax.live_arrays())
                got, t_p = timer(
                    lambda: np.asarray(
                        plan_p.contract_all(arrays, slice_batch=4, hoist=True)
                    ),
                    repeat=2,
                )
                live_delta = (
                    sum(a.nbytes for a in jax.live_arrays()) - live_before
                )
                assert np.allclose(got, ref, atol=1e-5)  # masks agree
                rec.update({
                    "kind": "measured",
                    "wall_width_s": t_w,
                    "wall_peak_s": t_p,
                    "speedup_peak_over_width": t_w / t_p,
                    "measured_resident_delta_bytes": int(live_delta),
                })
                rows.append(
                    f"memory_measured_{name}_ms,{t_p*1e3:.1f},"
                    f"width={t_w*1e3:.1f}ms;"
                    f"speedup={t_w/t_p:.2f}x;"
                    f"slices={popcount(S_w)}->{popcount(S_p)};"
                    f"resident_delta_bytes={int(live_delta)}"
                )
            records.append(rec)
            rows.append(
                f"memory_{name}_t{i}_peak_bytes,{mem_p.peak_bytes},"
                f"width_peak={mem_w.peak_bytes};"
                f"S={popcount(S_w)}->{popcount(S_p)};"
                f"tb_elim={sched.transpose_bytes_eliminated():.3e}"
            )
    append_trajectory(records, trajectory_dir)
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
