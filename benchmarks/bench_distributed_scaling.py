"""Multi-host slice scheduling — static uniform split vs LPT + stealing.

The paper's Sec. V-D process parallelism splits slice ids uniformly;
this benchmark measures what that costs when per-slice walls are ragged
(the cost model is uniform in expectation, reality is not).  Two parts:

  * **measured scheduling walls** on syc-12 / zn-12 with a synthetic
    ragged cost overlay (a heavy head region — the shape that hurts a
    contiguous split most) plus deterministic ±25% modeled-vs-true
    noise: per-host worker threads drain a shared
    :class:`~repro.distributed.scheduler.SliceScheduler` (sleeping each
    range's true cost), once with the paper's static uniform assignment
    (no stealing) and once with LPT + tail stealing.  The acceptance bar
    is the steal arm beating the static arm ≥1.2× in wall clock;
  * **a real amplitude execution** on the CPU-tractable instance through
    :func:`~repro.distributed.multihost.contract_multihost` with the
    overlapped chunked :class:`CollectiveTransport` (world size 1 — same
    code path as an N-process run), checked against ``contract_all`` and
    recording the genuine ``overlap_fraction`` + the ``PlanReport`` row.

Records append to ``experiments/distributed/trajectory.json`` and render
via ``benchmarks.make_tables``.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core.distributed import SliceRangeCheckpoint
from repro.core.slicing import find_slices
from repro.core.tensor_network import popcount
from repro.distributed import LocalArbiter, SliceScheduler, simulate
from repro.launch.mesh import multi_host_mesh
from repro.quantum.circuits import circuit_to_network, random_1d_circuit

from .common import append_trajectory, network_for, trees_for

HOSTS = 4
HEAVY = 7.0  # extra cost multiplier on the heavy head region
NOISE = 0.25  # true cost = modeled * (1 ± NOISE), deterministic per range
TARGET_BUSY_S = 0.25  # per-host sleep budget per arm (keeps CI fast)


def _ragged_costs(n: int) -> np.ndarray:
    c = np.ones(n)
    c[: max(1, n // 8)] = 1.0 + HEAVY
    return c


def _true_cost(start: int, end: int, costs: np.ndarray) -> float:
    """Modeled cost of the range with deterministic ±NOISE 'measurement'
    error (Knuth-hash fraction of the start id — no RNG state)."""
    frac = ((start * 2654435761) % 1000) / 1000.0
    return float(costs[start:end].sum()) * (1.0 - NOISE + 2 * NOISE * frac)


def _measured_wall(
    missing, costs, policy: str, steal: bool, scale: float
) -> tuple[float, SliceScheduler]:
    """Wall clock of HOSTS worker threads draining one shared scheduler,
    sleeping each range's true cost — the transport-free measurement of
    scheduling quality alone."""
    sched = SliceScheduler(missing, HOSTS, costs, policy=policy)
    arbiter = LocalArbiter()

    def work(h):
        while True:
            rng = sched.next_range(h, arbiter, steal=steal)
            if rng is None:
                return
            time.sleep(_true_cost(rng.start, rng.end, costs) * scale)

    threads = [
        threading.Thread(target=work, args=(h,)) for h in range(HOSTS)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, sched


def scheduling_rows(circuits=("syc-12", "zn-12")):
    rows = []
    records = []
    for name in circuits:
        tn, _ = network_for(name)
        tree = trees_for(tn, 1)[0]
        target = max(tree.width() - 6, 8)
        S = find_slices(tree, target, method="lifetime")
        n = 1 << popcount(S)
        # range count bounded so the python-side loop stays benchmarkable
        sb = max(1, n // 512)
        missing = SliceRangeCheckpoint(n, set(), 0.0).missing(sb)
        costs = _ragged_costs(n)
        scale = TARGET_BUSY_S * HOSTS / float(costs.sum())

        wall_static, sched_s = _measured_wall(
            missing, costs, "uniform", False, scale
        )
        wall_steal, sched_d = _measured_wall(
            missing, costs, "lpt", True, scale
        )
        speedup = wall_static / wall_steal
        # modeled mirror: virtual-time makespans of both arms (uniform
        # assignment without stealing is just its initial imbalance —
        # nothing moves; the LPT+steal arm replays via simulate())
        sim_steal = simulate(
            SliceScheduler(missing, HOSTS, costs, policy="lpt"),
            cost_scale=lambda s, e: _true_cost(s, e, costs),
        )
        rows.append(
            f"dist_{name}_static,{wall_static*1e6:.0f},"
            f"imbalance={sched_s.realized_imbalance():.2f}"
        )
        rows.append(
            f"dist_{name}_steal,{wall_steal*1e6:.0f},"
            f"imbalance={sched_d.realized_imbalance():.2f}"
            f";steals={sched_d.steal_count};speedup={speedup:.2f}"
        )
        records.append(
            {
                "kind": "scheduling",
                "workload": name,
                "n_slices": n,
                "slice_batch": sb,
                "hosts": HOSTS,
                "heavy_factor": 1.0 + HEAVY,
                "noise": NOISE,
                "wall_static_s": wall_static,
                "wall_steal_s": wall_steal,
                "speedup": speedup,
                "schedule_imbalance_static": sched_s.realized_imbalance(),
                "schedule_imbalance": sched_d.realized_imbalance(),
                "initial_imbalance_static": sched_s.initial_imbalance,
                "initial_imbalance_lpt": sched_d.initial_imbalance,
                "modeled_imbalance_steal": sim_steal.imbalance,
                "steal_count": sched_d.steal_count,
            }
        )
    return rows, records


def execution_rows():
    """Real sliced amplitude through contract_multihost + the overlapped
    collective transport (world size 1 exercises the identical code path
    an N-process launch runs)."""
    from repro.core.api import plan_compiled
    from repro.core.executor import simplify_network
    from repro.distributed import contract_multihost

    c = random_1d_circuit(10, 8, seed=3)
    tn, arrays = circuit_to_network(c, bitstring="0" * 10)
    tn, arrays = simplify_network(tn, arrays)
    plan, report = plan_compiled(tn, target_dim=4)
    ref = np.asarray(plan.contract_all(arrays, slice_batch=4))

    t0 = time.perf_counter()
    res = contract_multihost(
        plan,
        arrays,
        slice_batch=4,
        transport="collective",
        mesh=multi_host_mesh(),
        reduce_rounds=4,
        reduce_chunks=2,
        report=report,
    )
    wall = time.perf_counter() - t0
    err = float(np.max(np.abs(np.asarray(res.value) - ref)))
    assert err < 1e-4, err
    rows = [
        f"dist_exec_1d10,{wall*1e6:.0f},"
        f"overlap={res.overlap_fraction:.2f};slices={res.n_slices}"
    ]
    records = [
        {
            "kind": "execution",
            "workload": "rqc-1d-10",
            "n_slices": res.n_slices,
            "executed_slices": res.executed_slices,
            "padded_slices": res.padded_slices,
            "wall_s": wall,
            "max_abs_err": err,
            "schedule_imbalance": report.schedule_imbalance,
            "steal_count": report.steal_count,
            "overlap_fraction": report.overlap_fraction,
            "report_row": report.row(),
        }
    ]
    return rows, records


def run(trajectory_dir: str = "experiments/distributed"):
    rows, records = scheduling_rows()
    erows, erecords = execution_rows()
    rows += erows
    records += erecords
    append_trajectory(records, trajectory_dir)
    return rows
