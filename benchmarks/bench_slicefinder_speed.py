"""Fig. 8 — sliceFinder search time vs Cotengra-style repeated greedy.

The paper reports 100-200x planner speedups.  Both implementations here
share the same bitmask substrate, so the ratio isolates the algorithmic
difference (single lifetime pass vs repeated full-cost greedy)."""

from __future__ import annotations

import math

from repro.core.slicing import find_slices
from repro.core.tensor_network import popcount

from .common import network_for, timer, trees_for


def run(n_trees: int = 20, circuit: str = "syc-16") -> list[str]:
    tn, _ = network_for(circuit)
    trees = trees_for(tn, n_trees)
    rows = []
    ratios = []
    t_life_tot = t_greedy_tot = 0.0
    for i, tree in enumerate(trees):
        target = max(tree.width() - 4, 8)
        s_l, t_life = timer(
            find_slices, tree, target, method="lifetime", repeat=3
        )
        s_g, t_greedy = timer(
            find_slices, tree, target, method="greedy", repeats=16,
            temperature=0.2, seed=i,
        )
        ratios.append(t_greedy / max(t_life, 1e-9))
        t_life_tot += t_life
        t_greedy_tot += t_greedy
    geo = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    rows.append(
        f"fig8_slicefinder_us,{t_life_tot / n_trees * 1e6:.1f},"
        f"greedy16_us={t_greedy_tot / n_trees * 1e6:.1f}"
    )
    rows.append(f"fig8_speedup_geomean,{geo:.1f},paper=100-200x")
    rows.extend(plan_search_rows(circuit=circuit))
    return rows


def plan_search_rows(circuit: str = "syc-16", max_evals: int = 16) -> list[str]:
    """Planner-wall rows for the anytime co-optimizer: the in-place
    lifetime slicer is what keeps one full (tree, S) evaluation — move +
    re-slice + partition + certified peak — in the tens of milliseconds,
    so an entire anytime search costs a handful of one-shot plans."""
    from repro.core.pathfinder import random_greedy_tree
    from repro.optimize import oneshot_plan, plan_search

    from .common import timer as _timer

    tn, _ = network_for(circuit)
    w0 = random_greedy_tree(tn, repeats=8, seed=0).width()
    target = max(w0 - 4, 8)
    _, t_one = _timer(oneshot_plan, tn, target, seed=0)
    res, t_search = _timer(
        plan_search, tn, target, max_evals=max_evals, num_workers=4, seed=0
    )
    per_eval = t_search / max(1, res.evaluations)
    return [
        f"fig8_plansearch_per_eval_us,{per_eval * 1e6:.1f},"
        f"evals={res.evaluations};oneshot_us={t_one * 1e6:.1f};"
        f"search_vs_oneshot={t_search / max(t_one, 1e-9):.1f}x"
    ]


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
