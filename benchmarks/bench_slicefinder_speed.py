"""Fig. 8 — sliceFinder search time vs Cotengra-style repeated greedy.

The paper reports 100-200x planner speedups.  Both implementations here
share the same bitmask substrate, so the ratio isolates the algorithmic
difference (single lifetime pass vs repeated full-cost greedy)."""

from __future__ import annotations

import math

from repro.core.slicing import find_slices
from repro.core.tensor_network import popcount

from .common import network_for, timer, trees_for


def run(n_trees: int = 20, circuit: str = "syc-16") -> list[str]:
    tn, _ = network_for(circuit)
    trees = trees_for(tn, n_trees)
    rows = []
    ratios = []
    t_life_tot = t_greedy_tot = 0.0
    for i, tree in enumerate(trees):
        target = max(tree.width() - 4, 8)
        s_l, t_life = timer(
            find_slices, tree, target, method="lifetime", repeat=3
        )
        s_g, t_greedy = timer(
            find_slices, tree, target, method="greedy", repeats=16,
            temperature=0.2, seed=i,
        )
        ratios.append(t_greedy / max(t_life, 1e-9))
        t_life_tot += t_life
        t_greedy_tot += t_greedy
    geo = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    rows.append(
        f"fig8_slicefinder_us,{t_life_tot / n_trees * 1e6:.1f},"
        f"greedy16_us={t_greedy_tot / n_trees * 1e6:.1f}"
    )
    rows.append(f"fig8_speedup_geomean,{geo:.1f},paper=100-200x")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
