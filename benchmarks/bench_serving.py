"""Contraction-serving benchmark — the EngineServer under tenant traffic.

Three measurements on the multi-tenant engine
(:class:`repro.engine.server.EngineServer`):

  * **cold vs warm** — per circuit family, the first burst pays planning
    (cold); later bursts hit the compiled-plan cache and run warm.  The
    p50/p99 split quantifies what the plan cache buys a serving
    deployment (the refactor's acceptance bar: warm p50 at least 5x
    below cold).
  * **batched vs serial** — 8 concurrent amplitude tenants whose
    bitstrings differ on 3 qubits: served coalesced (one open-qubit
    batch contraction answers all 8) vs through a ``max_batch=1`` server
    (one scalar contraction each).  Bar: batched at least 2x the req/s.
  * **Poisson mixed traffic** — open-loop arrivals (exponential
    inter-arrival gaps) of amplitude + sampling requests across all
    families, the steady-state p50/p99/req/s a tenant actually sees.

Standalone runs append trajectory records for ``benchmarks.make_tables``:

    PYTHONPATH=src python -m benchmarks.bench_serving \
        --trajectory experiments/serving
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.engine import AmplitudeRequest, EngineServer, SampleRequest
from repro.quantum.circuits import random_1d_circuit, sycamore_like

from .common import append_trajectory

FAMILIES = {
    "syc-3x3x8": (lambda: sycamore_like(3, 3, 8, seed=41), 10),
    "syc-3x4x8": (lambda: sycamore_like(3, 4, 8, seed=42), 8),
    "rand1d-10x8": (lambda: random_1d_circuit(10, 8, seed=43), 10),
}
VARY = 3  # qubits the burst's bitstrings differ on (coalescible)
TENANTS = 8
WARM_BURSTS = 3


def _quantiles(lat: list[float]) -> dict:
    q = np.quantile(np.asarray(lat), [0.5, 0.99])
    return {"p50_s": float(q[0]), "p99_s": float(q[1])}


def _amp_requests(circuit, target_dim, n, seed):
    nq = circuit.num_qubits
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        bits = ["0"] * nq
        for j, b in enumerate(rng.integers(0, 2, size=VARY)):
            bits[nq - VARY + j] = str(int(b))
        reqs.append(
            AmplitudeRequest(circuit, "".join(bits), target_dim=target_dim)
        )
    return reqs


def _burst(srv, reqs):
    tickets = [srv.submit(r) for r in reqs]
    t0 = time.perf_counter()
    for t in tickets:
        t.result(timeout=600)
    wall = time.perf_counter() - t0
    return [t.total_s for t in tickets], wall


def cold_warm_rows() -> list[dict]:
    recs = []
    with EngineServer(max_batch=TENANTS, max_open=VARY,
                      max_queue=256) as srv:
        for name, (make, td) in FAMILIES.items():
            circuit = make()
            mixed = _amp_requests(circuit, td, TENANTS - 1, seed=0) + [
                SampleRequest(circuit, num_samples=512, target_dim=td)
            ]
            cold_lat, cold_wall = _burst(srv, mixed)
            warm_lat, warm_wall = [], 0.0
            for b in range(WARM_BURSTS):
                lat, wall = _burst(
                    srv,
                    _amp_requests(circuit, td, TENANTS - 1, seed=b + 1)
                    + [
                        SampleRequest(
                            circuit, num_samples=512, target_dim=td,
                            seed=b + 1,
                        )
                    ],
                )
                warm_lat += lat
                warm_wall += wall
            cold_q, warm_q = _quantiles(cold_lat), _quantiles(warm_lat)
            recs.append(
                {
                    "kind": "cold_warm",
                    "family": name,
                    "tenants": TENANTS,
                    "cold_p50_s": cold_q["p50_s"],
                    "cold_p99_s": cold_q["p99_s"],
                    "cold_req_per_s": len(mixed) / cold_wall,
                    "warm_p50_s": warm_q["p50_s"],
                    "warm_p99_s": warm_q["p99_s"],
                    "warm_req_per_s": len(mixed) * WARM_BURSTS / warm_wall,
                    "warm_p50_speedup": cold_q["p50_s"] / warm_q["p50_s"],
                }
            )
        stats = srv.stats()
    recs.append(
        {
            "kind": "server_stats",
            "phase": "cold_warm",
            **{
                k: stats[k]
                for k in (
                    "completed", "coalesced", "groups",
                    "warm_groups", "cold_groups",
                )
            },
        }
    )
    return recs


def batching_rows() -> list[dict]:
    """8 concurrent amplitude tenants, warm plans: coalesced batch vs a
    ``max_batch=1`` server that contracts one scalar per request."""
    name = "syc-3x3x8"
    make, td = FAMILIES[name]
    circuit = make()
    reqs = _amp_requests(circuit, td, TENANTS, seed=7)

    def run(max_batch):
        with EngineServer(max_batch=max_batch, max_open=VARY,
                          max_queue=256) as srv:
            _burst(srv, reqs)  # warm the family + traces
            best = float("inf")
            for _ in range(3):
                lat, wall = _burst(srv, reqs)
                if wall < best:
                    best, best_lat = wall, lat
            coalesced = srv.stats()["coalesced"]
        return best_lat, best, coalesced

    lat_b, wall_b, co_b = run(max_batch=TENANTS)
    lat_s, wall_s, co_s = run(max_batch=1)
    return [
        {
            "kind": "batching",
            "family": name,
            "tenants": TENANTS,
            "batched_req_per_s": TENANTS / wall_b,
            "serial_req_per_s": TENANTS / wall_s,
            "batched_coalesced": co_b,
            "serial_coalesced": co_s,
            **{f"batched_{k}": v for k, v in _quantiles(lat_b).items()},
            **{f"serial_{k}": v for k, v in _quantiles(lat_s).items()},
            "throughput_gain": wall_s / wall_b,
        }
    ]


def poisson_rows(n_requests: int = 48, rate_hz: float = 200.0,
                 seed: int = 3) -> list[dict]:
    """Open-loop Poisson arrivals of mixed amplitude/sampling traffic
    across all (pre-warmed) families."""
    rng = np.random.default_rng(seed)
    fams = [(name, make(), td) for name, (make, td) in FAMILIES.items()]
    with EngineServer(max_batch=TENANTS, max_open=VARY,
                      max_queue=1024) as srv:
        for _, circuit, td in fams:
            # warm every plan the mixed load will hit: the scalar
            # amplitude network (singleton groups), the coalesced
            # open-window batch, and the sampling batch network
            _burst(srv, _amp_requests(circuit, td, 1, seed=4))
            _burst(
                srv,
                _amp_requests(circuit, td, 4, seed=5)
                + [SampleRequest(circuit, num_samples=32, target_dim=td)],
            )
        tickets = []
        t0 = time.perf_counter()
        for i in range(n_requests):
            name, circuit, td = fams[i % len(fams)]
            if i % 6 == 5:
                req = SampleRequest(
                    circuit, num_samples=256, target_dim=td, seed=i
                )
            else:
                req = _amp_requests(circuit, td, 1, seed=100 + i)[0]
            tickets.append(srv.submit(req))
            time.sleep(float(rng.exponential(1.0 / rate_hz)))
        for t in tickets:
            t.result(timeout=600)
        wall = time.perf_counter() - t0
        stats = srv.stats()
    lat = [t.total_s for t in tickets]
    batched = sum(1 for t in tickets if t.batched)
    return [
        {
            "kind": "poisson",
            "families": len(fams),
            "requests": n_requests,
            "offered_rate_hz": rate_hz,
            "req_per_s": n_requests / wall,
            **_quantiles(lat),
            "mean_queue_s": float(np.mean([t.queue_s for t in tickets])),
            "batched_fraction": batched / n_requests,
            "groups": stats["groups"],
            "coalesced": stats["coalesced"],
        }
    ]


def _records() -> list[dict]:
    return cold_warm_rows() + batching_rows() + poisson_rows()


def run() -> list[str]:
    rows = []
    for r in _records():
        if r["kind"] == "cold_warm":
            rows.append(
                f"serving_coldwarm_{r['family']},{r['warm_p50_s']*1e6:.0f},"
                f"cold_p50_s={r['cold_p50_s']:.3f};"
                f"warm_p50_speedup={r['warm_p50_speedup']:.1f};"
                f"warm_req_per_s={r['warm_req_per_s']:.1f}"
            )
        elif r["kind"] == "batching":
            rows.append(
                f"serving_batching,{r['batched_p50_s']*1e6:.0f},"
                f"batched_req_per_s={r['batched_req_per_s']:.1f};"
                f"serial_req_per_s={r['serial_req_per_s']:.1f};"
                f"gain={r['throughput_gain']:.2f}"
            )
        elif r["kind"] == "poisson":
            rows.append(
                f"serving_poisson,{r['p50_s']*1e6:.0f},"
                f"req_per_s={r['req_per_s']:.1f};p99_s={r['p99_s']:.3f};"
                f"batched_fraction={r['batched_fraction']:.2f}"
            )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trajectory", default=None,
                    help="append records under this directory "
                         "(e.g. experiments/serving)")
    args = ap.parse_args()
    recs = _records()
    for r in recs:
        if r["kind"] == "cold_warm":
            print(
                f"{r['family']}: cold p50 {r['cold_p50_s']*1e3:.0f} ms -> "
                f"warm p50 {r['warm_p50_s']*1e3:.1f} ms "
                f"({r['warm_p50_speedup']:.1f}x), "
                f"warm {r['warm_req_per_s']:.0f} req/s"
            )
        elif r["kind"] == "batching":
            print(
                f"batching x{r['tenants']}: coalesced "
                f"{r['batched_req_per_s']:.0f} req/s vs serial "
                f"{r['serial_req_per_s']:.0f} req/s "
                f"({r['throughput_gain']:.2f}x)"
            )
        elif r["kind"] == "poisson":
            print(
                f"poisson {r['requests']} req @ {r['offered_rate_hz']:.0f} Hz"
                f": p50 {r['p50_s']*1e3:.1f} ms, p99 {r['p99_s']*1e3:.1f} ms,"
                f" {r['req_per_s']:.0f} req/s, "
                f"{r['batched_fraction']*100:.0f}% batched"
            )
    if args.trajectory:
        append_trajectory(recs, args.trajectory)


if __name__ == "__main__":
    main()
