"""Batch-sampling throughput — the paper's flagship workload (Sec. VI:
one million correlated samples in 96.1 s).

One sliced contraction with k open output qubits yields 2^k correlated
amplitudes; sampling bitstrings from the batch is then nearly free.  We
measure, per open-qubit count k:

  * contraction wall time for the full batch (the dominant cost),
  * end-to-end samples/second for a fixed draw count (contract + sample),
  * the per-amplitude-engine equivalent rate for contrast (the batch's
    whole point: amortize one contraction over the entire sample set).

Standalone runs can persist a JSON record for ``benchmarks.make_tables``:

    PYTHONPATH=src python -m benchmarks.bench_sampling_throughput \
        --json experiments/sampling/throughput.json
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core import sample_bitstrings, simulate_amplitude
from repro.quantum.circuits import sycamore_like

from .common import timer

CIRCUIT = dict(rows=4, cols=4, cycles=10, seed=0)
NUM_SAMPLES = 10_000
OPEN_COUNTS = (2, 4, 6)
TARGET_DIM = 12


def _records() -> list[dict]:
    circ = sycamore_like(**CIRCUIT)
    nq = circ.num_qubits
    recs = []
    # per-amplitude contrast: one scalar amplitude through the full engine
    _, t_single = timer(
        lambda: simulate_amplitude(circ, "0" * nq, target_dim=TARGET_DIM),
        repeat=2,
    )
    for k in OPEN_COUNTS:
        open_q = tuple(range(nq - k, nq))
        res, t_batch = timer(
            lambda oq=open_q: sample_bitstrings(
                circ,
                num_samples=NUM_SAMPLES,
                open_qubits=oq,
                target_dim=TARGET_DIM,
            ),
            repeat=2,
        )
        recs.append(
            {
                "k_open": k,
                "batch_size": res.batch.size,
                "num_slices": 1 << res.report.num_sliced,
                "wall_s": t_batch,
                "samples_per_s": NUM_SAMPLES / t_batch,
                "amps_per_s": res.batch.size / t_batch,
                "per_amp_engine_amps_per_s": 1.0 / t_single,
                "xeb": res.xeb,
            }
        )
    return recs


def run() -> list[str]:
    rows = []
    for r in _records():
        rows.append(
            f"sampling_k{r['k_open']},{r['wall_s']*1e6:.0f},"
            f"samples_per_s={r['samples_per_s']:.0f};"
            f"batch={r['batch_size']};slices={r['num_slices']};"
            f"batch_amps_per_s={r['amps_per_s']:.1f};"
            f"single_amps_per_s={r['per_amp_engine_amps_per_s']:.1f}"
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write records to this JSON path")
    args = ap.parse_args()
    recs = _records()
    for r in recs:
        print(
            f"sampling_k{r['k_open']},{r['wall_s']*1e6:.0f},"
            f"samples_per_s={r['samples_per_s']:.0f}"
        )
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"circuit": CIRCUIT, "num_samples": NUM_SAMPLES,
                       "records": recs}, f, indent=2)


if __name__ == "__main__":
    main()
