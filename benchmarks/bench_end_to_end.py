"""Sec. VI-B — end-to-end contraction: paper-faithful pipeline vs greedy
baseline, measured on the real executor (CPU), plus the projected
single-chip TPU time from the F-surface model for the planner's output,
and the epilogue-megakernel ablation (REPRO_MEGAKERNEL on/off on the
lowered GEMM schedule: fused-chain counts, modeled HBM bytes saved, and
the measured contract_all wall both ways).

The paper's headline (304 s → 149.2 s on 107,520 Sunway nodes) is a
planner+efficiency product; at our scale we report the same decomposition:
  time = C(B)·O(B,S) / (peak · efficiency)
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import plan_contraction
from repro.core.executor import ContractionPlan
from repro.core.merging import modeled_tree_time

from .common import append_trajectory, network_for, timer


def run(circuit: str = "syc-12") -> list[str]:
    tn, arrays = network_for(circuit)
    rows = []
    results = {}
    # slice to width-3: a few slices, the stem-dominant regime the paper
    # targets (deep slicing of small circuits is planner-hostile for every
    # method and CPU-hostile for the executor)
    plans = {}
    for label, kw in (
        ("greedy_base", dict(method="greedy", tune=False, merge=False)),
        ("paper_faithful", dict(method="lifetime", tune=True, merge=True)),
    ):
        tree, smask, report = plan_contraction(
            tn, max(tree_width(tn) - 3, 10), seed=0, **kw
        )
        plans[label] = (tree, smask, report)
    for label, (tree, smask, report) in plans.items():
        plan = ContractionPlan(tree, smask)
        val, t = timer(
            lambda: np.asarray(plan.contract_all(arrays, slice_batch=4)),
            repeat=2,
        )
        results[label] = complex(val)
        # memory columns: planned live-set peak (lifetime buffer plan) and
        # the fused-kernel transpose-bytes credit of the lowered schedule
        mem = plan.memory_plan()
        from repro.lowering.refiner import refine_tree_schedule

        sched = refine_tree_schedule(tree, smask)
        rows.append(
            f"e2e_{label}_ms,{t*1e3:.1f},"
            f"overhead={report.slicing_overhead:.3f};"
            f"slices={report.num_sliced};"
            f"tpu_model_s={modeled_tree_time(tree, smask):.3e};"
            f"peak_bytes={mem.peak_bytes};"
            f"peak_bytes_hoisted={mem.peak_bytes_hoisted};"
            f"tb_elim={sched.transpose_bytes_eliminated():.3e}"
        )
    assert abs(results["greedy_base"] - results["paper_faithful"]) < 1e-4, (
        "pipelines disagree on the amplitude!"
    )
    rows.extend(megakernel_rows(circuit, plans["paper_faithful"], arrays))
    rows.extend(telemetry_rows())
    return rows


def megakernel_rows(
    circuit: str,
    plan_tuple,
    arrays,
    trajectory_dir: str = "experiments/megakernel",
) -> list[str]:
    """Epilogue-megakernel ablation on the paper-faithful plan: the same
    lowered GEMM schedule executed with the fusion-boundary pass off and
    on (REPRO_MEGAKERNEL={0,1}), values asserted equal, chain statistics
    from the ChainPlan, and the measured contract_all wall both ways —
    appended to the trajectory history ``make_tables`` renders."""
    tree, smask, report = plan_tuple
    saved = os.environ.get("REPRO_MEGAKERNEL")
    walls, vals = {}, {}
    chain_summary = None
    hbm_saved = {}
    try:
        for mega in ("0", "1"):
            os.environ["REPRO_MEGAKERNEL"] = mega
            plan = ContractionPlan(tree, smask, backend="gemm")
            val, t = timer(
                lambda: np.asarray(plan.contract_all(arrays, slice_batch=4)),
                repeat=2,
            )
            walls[mega], vals[mega] = t, complex(val)
            if mega == "1":
                assert plan.chain_plan is not None, "fusion pass did not run"
                chain_summary = plan.chain_plan.summary()
                hbm_saved = chain_summary["hbm_bytes_saved"]
    finally:
        if saved is None:
            os.environ.pop("REPRO_MEGAKERNEL", None)
        else:
            os.environ["REPRO_MEGAKERNEL"] = saved
    assert abs(vals["0"] - vals["1"]) < 1e-4, (
        "megakernel on/off disagree on the amplitude!"
    )
    record = {
        "workload": circuit,
        "num_sliced": report.num_sliced,
        "fused_chains": chain_summary["multi_step_chains"],
        "max_chain_len": chain_summary["max_chain_len"],
        "chain_peak_bytes": chain_summary["max_live_bytes"],
        "vmem_budget": chain_summary["vmem_budget"],
        "hbm_bytes_saved": hbm_saved,
        "wall_megakernel_off_s": walls["0"],
        "wall_megakernel_on_s": walls["1"],
        "speedup": walls["0"] / walls["1"] if walls["1"] else None,
    }
    append_trajectory([record], trajectory_dir)
    return [
        f"e2e_megakernel_off_ms,{walls['0']*1e3:.1f},"
        f"chains=0;chain_saved=0",
        f"e2e_megakernel_on_ms,{walls['1']*1e3:.1f},"
        f"chains={chain_summary['multi_step_chains']};"
        f"max_len={chain_summary['max_chain_len']};"
        f"chain_peak={chain_summary['max_live_bytes']};"
        + "chain_saved="
        + ";".join(
            f"{seg}:{int(v)}" for seg, v in sorted(hbm_saved.items())
        ),
    ]


def precision_rows(
    circuit: str = "syc-12",
    target_dim: int = 18,
    fidelity_tol: float = 0.05,
    trajectory_dir: str = "experiments/precision",
) -> list[str]:
    """Mixed-precision ablation on the pinned plan: the same network
    planned at fp32 and under REPRO_PRECISION=auto semantics
    (``precision="auto"`` at the given XEB budget), comparing modeled
    two-phase time, modeled HBM traffic, slice count, bf16 step counts,
    the measured contract_all wall, and the measured Linear-XEB delta on
    the open-batch amplitudes — appended to the trajectory history
    ``make_tables`` renders.

    Pins ``REPRO_MEGAKERNEL=1`` / ``REPRO_FUSED_GEMM=1`` like the CI
    gate: the ablation is about the precision dimension, not the other
    lowering switches."""
    from repro.core import plan_compiled, sample_bitstrings
    from repro.quantum.xeb import xeb_from_amplitudes

    from .common import CIRCUITS

    tn, arrays = network_for(circuit)
    circ = CIRCUITS[circuit]()
    saved = {
        k: os.environ.get(k) for k in ("REPRO_MEGAKERNEL", "REPRO_FUSED_GEMM")
    }
    os.environ["REPRO_MEGAKERNEL"] = "1"
    os.environ["REPRO_FUSED_GEMM"] = "1"
    stats, xebs = {}, {}
    try:
        for label, prec in (("fp32", "fp32"), ("auto", "auto")):
            plan, report = plan_compiled(
                tn, target_dim, backend="gemm", use_cache=False,
                slicing_mode="peak", precision=prec,
                fidelity_tol=fidelity_tol,
            )
            val, wall = timer(
                lambda: np.asarray(plan.contract_all(arrays, slice_batch=8)),
                repeat=2,
            )
            n_slices = 1 << plan.num_sliced
            epi = sum(
                plan.schedule.specs[k].modeled_time_s
                for k in plan.epilogue_idx
            ) * n_slices
            stats[label] = {
                "amp": complex(val),
                "wall_s": wall,
                "num_sliced": plan.num_sliced,
                "modeled_time_s": report.modeled_time_hoisted_s,
                "modeled_epilogue_s": epi,
                "hbm_bytes": plan.schedule.hbm_traffic_bytes() * n_slices,
                "peak_bytes": report.peak_bytes,
                "precision_counts": plan.schedule.precision_counts(),
                "predicted_amp_error": report.predicted_amp_error,
            }
            res = sample_bitstrings(
                circ, num_samples=128,
                open_qubits=tuple(range(circ.num_qubits - 4,
                                        circ.num_qubits)),
                target_dim=target_dim, seed=1, backend="gemm",
                use_cache=False, slicing_mode="peak", slice_batch=4,
                precision=prec, fidelity_tol=fidelity_tol,
            )
            xebs[label] = xeb_from_amplitudes(
                circ.num_qubits, np.asarray(res.batch.amplitudes).ravel()
            )
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    f32, aut = stats["fp32"], stats["auto"]
    rel_err = abs(aut["amp"] - f32["amp"]) / abs(f32["amp"])
    assert rel_err <= fidelity_tol, (
        f"auto amplitude drifted {rel_err:.3g} > tol {fidelity_tol}"
    )
    record = {
        "workload": circuit,
        "fidelity_tol": fidelity_tol,
        "fp32": {k: v for k, v in f32.items() if k != "amp"},
        "auto": {k: v for k, v in aut.items() if k != "amp"},
        "amp_rel_err": rel_err,
        "xeb_fp32": xebs["fp32"],
        "xeb_auto": xebs["auto"],
        "xeb_delta": xebs["auto"] - xebs["fp32"],
        "modeled_epilogue_speedup": (
            f32["modeled_epilogue_s"] / aut["modeled_epilogue_s"]
            if aut["modeled_epilogue_s"] else None
        ),
    }
    append_trajectory([record], trajectory_dir)
    rows = []
    for label in ("fp32", "auto"):
        s = stats[label]
        counts = ";".join(
            f"{k}:{v}" for k, v in sorted(s["precision_counts"].items())
        )
        rows.append(
            f"e2e_precision_{label}_ms,{s['wall_s']*1e3:.1f},"
            f"slices={s['num_sliced']};"
            f"model_s={s['modeled_time_s']:.3e};"
            f"epilogue_s={s['modeled_epilogue_s']:.3e};"
            f"hbm_bytes={s['hbm_bytes']:.3e};"
            f"counts={counts};"
            f"xeb={xebs[label]:.4f}"
        )
    rows.append(
        f"e2e_precision_delta,{rel_err:.3e},"
        f"xeb_delta={record['xeb_delta']:.4f};"
        f"epilogue_speedup={record['modeled_epilogue_speedup']:.2f};"
        f"tol={fidelity_tol}"
    )
    return rows


def telemetry_rows(
    circuits=("syc-12", "zn-12"),
    trajectory_dir: str = "experiments/obs",
) -> list[str]:
    """Observability ablation on the paper workloads: tracer overhead
    (the same compiled artifact executed untraced and traced,
    min-over-repeat) and the model-vs-measured calibration ratio per
    backend class on the lowered GEMM schedule — appended to the
    trajectory history ``make_tables`` renders.

    Plans are sliced to width ≤ 19 so per-slice tensors stay CPU-sized
    on every workload (zn-12 is width-30 — a full-width contraction is
    hours on CPU).  Small slice counts (≤ 128) measure the full vmapped
    scan; larger ones measure a 16-slice subset of the per-slice
    resumable path via a pre-completed checkpoint — the path where the
    tracer wraps every slice range, i.e. the worst case for overhead."""
    import repro.obs as obs
    from repro.core.distributed import (
        SliceRangeCheckpoint,
        contract_resumable,
    )
    from repro.obs import trace

    import jax
    import jax.numpy as jnp

    rows, records = [], []
    prev = trace.enabled()
    try:
        for circuit in circuits:
            tn, arrays = network_for(circuit)
            tree, smask, report = plan_contraction(
                tn, max(min(tree_width(tn) - 3, 19), 10), seed=0,
                method="lifetime", tune=True, merge=True,
            )
            plan = ContractionPlan(tree, smask)
            n_slices = 1 << report.num_sliced
            if n_slices <= 128:
                path = "scan"
                run_once = lambda: np.asarray(
                    plan.contract_all(arrays, slice_batch=4)
                )
            else:
                path = "resumable[0:16)"
                out_shape = jax.eval_shape(
                    lambda: plan.contract_slice(list(arrays), jnp.int32(0))
                )

                def run_once():
                    state = SliceRangeCheckpoint(
                        n_slices,
                        set(range(16, n_slices)),
                        np.zeros(out_shape.shape, out_shape.dtype),
                    )
                    val, _ = contract_resumable(
                        plan, arrays, chunk=4, state=state
                    )
                    return np.asarray(val)

            warm = run_once()  # compile outside both arms
            trace.set_enabled(False)
            val_off, wall_off = timer(run_once, repeat=2)
            trace.set_enabled(True)
            obs.reset()
            val_on, wall_on = timer(run_once, repeat=2)
            assert val_off.tobytes() == val_on.tobytes() == warm.tobytes()
            # calibration on the lowered GEMM schedule so the table
            # covers the refiner's backend classes, not just einsum
            gemm_plan = ContractionPlan(tree, smask, backend="gemm")
            cal = obs.calibrate_plan(gemm_plan, arrays, repeat=1)
            ratio = wall_on / wall_off if wall_off else None
            records.append({
                "workload": circuit,
                "num_sliced": report.num_sliced,
                "path": path,
                "wall_untraced_s": wall_off,
                "wall_traced_s": wall_on,
                "overhead_ratio": ratio,
                "calibration": cal.summary(),
            })
            rows.append(
                f"obs_overhead_{circuit}_ms,{wall_on*1e3:.1f},"
                f"untraced_ms={wall_off*1e3:.1f};ratio={ratio:.3f};"
                f"path={path}"
            )
            for cls, agg in sorted(cal.ratio_by_class().items()):
                rows.append(
                    f"obs_calibration_{circuit}_{cls},"
                    f"{agg['measured_s']*1e6:.1f},"
                    f"steps={agg['count']};"
                    f"modeled_s={agg['modeled_s']:.3e};"
                    f"meas_model={agg['ratio']:.2f}"
                )
    finally:
        trace.set_enabled(prev)
        obs.reset()
    append_trajectory(records, trajectory_dir)
    return rows


def tree_width(tn) -> int:
    from repro.core.pathfinder import random_greedy_tree

    return random_greedy_tree(tn, repeats=4, seed=0).width()


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
