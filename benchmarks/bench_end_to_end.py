"""Sec. VI-B — end-to-end contraction: paper-faithful pipeline vs greedy
baseline, measured on the real executor (CPU), plus the projected
single-chip TPU time from the F-surface model for the planner's output.

The paper's headline (304 s → 149.2 s on 107,520 Sunway nodes) is a
planner+efficiency product; at our scale we report the same decomposition:
  time = C(B)·O(B,S) / (peak · efficiency)
"""

from __future__ import annotations

import numpy as np

from repro.core import plan_contraction
from repro.core.executor import ContractionPlan
from repro.core.merging import modeled_tree_time

from .common import network_for, timer


def run(circuit: str = "syc-12") -> list[str]:
    tn, arrays = network_for(circuit)
    rows = []
    results = {}
    # slice to width-3: a few slices, the stem-dominant regime the paper
    # targets (deep slicing of small circuits is planner-hostile for every
    # method and CPU-hostile for the executor)
    plans = {}
    for label, kw in (
        ("greedy_base", dict(method="greedy", tune=False, merge=False)),
        ("paper_faithful", dict(method="lifetime", tune=True, merge=True)),
    ):
        tree, smask, report = plan_contraction(
            tn, max(tree_width(tn) - 3, 10), seed=0, **kw
        )
        plans[label] = (tree, smask, report)
    for label, (tree, smask, report) in plans.items():
        plan = ContractionPlan(tree, smask)
        val, t = timer(
            lambda: np.asarray(plan.contract_all(arrays, slice_batch=4)),
            repeat=2,
        )
        results[label] = complex(val)
        # memory columns: planned live-set peak (lifetime buffer plan) and
        # the fused-kernel transpose-bytes credit of the lowered schedule
        mem = plan.memory_plan()
        from repro.lowering.refiner import refine_tree_schedule

        sched = refine_tree_schedule(tree, smask)
        rows.append(
            f"e2e_{label}_ms,{t*1e3:.1f},"
            f"overhead={report.slicing_overhead:.3f};"
            f"slices={report.num_sliced};"
            f"tpu_model_s={modeled_tree_time(tree, smask):.3e};"
            f"peak_bytes={mem.peak_bytes};"
            f"peak_bytes_hoisted={mem.peak_bytes_hoisted};"
            f"tb_elim={sched.transpose_bytes_eliminated():.3e}"
        )
    assert abs(results["greedy_base"] - results["paper_faithful"]) < 1e-4, (
        "pipelines disagree on the amplitude!"
    )
    return rows


def tree_width(tn) -> int:
    from repro.core.pathfinder import random_greedy_tree

    return random_greedy_tree(tn, repeats=4, seed=0).width()


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
