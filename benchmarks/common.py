"""Shared benchmark utilities: the circuit pool mirroring the paper's
syc-m / zn-m instances (scaled to CPU-planner size — the planner algebra
is identical at any scale; its inputs are graphs, not arrays)."""

from __future__ import annotations

import json
import os
import time

from repro.core.contraction_tree import ContractionTree
from repro.core.executor import simplify_network
from repro.core.pathfinder import greedy_ssa_path
from repro.quantum.circuits import (
    circuit_to_network,
    sycamore_like,
    zuchongzhi_like,
)

CIRCUITS = {
    "syc-8": lambda: sycamore_like(4, 5, 8, seed=0),
    "syc-12": lambda: sycamore_like(4, 5, 12, seed=0),
    "syc-16": lambda: sycamore_like(4, 5, 16, seed=0),
    "syc-20": lambda: sycamore_like(4, 5, 20, seed=0),
    "zn-12": lambda: zuchongzhi_like(4, 6, 12, seed=0),
    "zn-16": lambda: zuchongzhi_like(4, 6, 16, seed=0),
}


def network_for(name: str):
    circ = CIRCUITS[name]()
    tn, arrays = circuit_to_network(circ, bitstring="0" * circ.num_qubits)
    return simplify_network(tn, arrays)


def trees_for(tn, n_trees: int, seed0: int = 0):
    """A pool of distinct contraction trees (mixed temperatures), like the
    paper's '100 different contraction trees'."""
    temps = [0.0, 0.2, 0.5, 1.0]
    out = []
    for i in range(n_trees):
        path = greedy_ssa_path(tn, seed=seed0 + i, temperature=temps[i % 4])
        out.append(ContractionTree.from_ssa_path(tn, path))
    return out


def timer(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def append_trajectory(records: list[dict], trajectory_dir: str) -> None:
    """Append timestamped records to ``<trajectory_dir>/trajectory.json``
    (the per-subsystem benchmark history rendered by ``make_tables``).

    Tolerates a missing/corrupt file and writes atomically (tmp +
    ``os.replace``) so an interrupted run can't truncate the history.
    A corrupt/unreadable file is backed up to ``trajectory.json.bak``
    (never silently overwritten) and the history restarts fresh."""
    from repro.obs import log as obs_log

    os.makedirs(trajectory_dir, exist_ok=True)
    path = os.path.join(trajectory_dir, "trajectory.json")
    trajectory = {"records": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, dict) and isinstance(
                loaded.get("records"), list
            ):
                trajectory = loaded
            else:
                raise ValueError("unexpected trajectory.json structure")
        except (json.JSONDecodeError, OSError, ValueError) as e:
            bak = path + ".bak"
            try:
                os.replace(path, bak)
            except OSError:
                bak = "<unmovable>"
            obs_log.warning(
                f"corrupt trajectory history {path}: {e}; "
                f"backed up to {bak}, starting fresh",
                path=path,
                backup=bak,
                error=str(e),
            )
    now = time.time()
    for r in records:
        r.setdefault("unix_time", now)
    trajectory["records"].extend(records)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trajectory, f, indent=2)
    os.replace(tmp, path)
